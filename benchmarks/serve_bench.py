"""Serving-layer benchmark: micro-batch coalescing throughput/latency
sweep vs the one-query-at-a-time baseline (DESIGN.md §7).

Prints the same ``name,us_per_call,derived`` CSV rows as run.py:

    serve/serial_qps           the no-coalescing floor (16 blocking
                               clients behind a lock, L=1 per call)
    serve/qps@batch=N          closed-loop QPS at max_batch=N
    serve/p50_ms@batch=N       per-query median latency
    serve/p99_ms@batch=N
    serve/speedup@batch=8      coalesced / serial (acceptance: >= 2x)
    serve/recompiles           engine programs traced across the whole
                               sweep (acceptance: <= log2(max_batch)+1)

plus the closed-loop *overload* scenario (DESIGN.md §7.3): demand is
pushed far past a deterministic searcher's capacity, first through the
legacy unbounded FIFO queue, then with the scheduling layer on
(bounded pending queue + per-request deadlines):

    serve/overload_fifo_p99_ms   what unbounded queueing does to tails
    serve/overload_sched_p99_ms  p99 of *served* requests (acceptance:
                                 <= the SLO — overload must not leak
                                 into the latency of admitted work)
    serve/overload_shed_rate     fraction refused/expired with a typed
                                 error (acceptance: > 0 — the layer
                                 sheds instead of queueing)
    serve/sched_bit_identity     scheduling on (no pressure) vs legacy
                                 positional results (acceptance: exact)

The sweep warms every L-bucket program first, so rows measure steady
state; the recompile row shows what the L-bucket cache held compilation
to across every batch size served.

Usage: PYTHONPATH=src python benchmarks/serve_bench.py [--docs 4000]
"""
from __future__ import annotations

import argparse
import math
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine, SearchResult
from repro.distributed.meshctx import single_device_ctx
from repro.serve import (DeadlineExceeded, OverloadError, Query,
                         QueryOptions, SearchService)

# overload scenario knobs: a deterministic 5ms/batch searcher at
# max_batch=4 caps capacity at ~800 q/s; 48 closed-loop clients demand
# far more, so FIFO queueing stretches waits to ~(48/4)*5ms while the
# scheduled run bounds the pending set at 12 and sheds the rest
OVERLOAD_CLIENTS = 48
OVERLOAD_REQUESTS = 20
OVERLOAD_BATCH = 4
OVERLOAD_SERVICE_MS = 5.0
OVERLOAD_MAX_PENDING = 12
OVERLOAD_DEADLINE_MS = 25.0
OVERLOAD_SLO_MS = 40.0


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


class _SlowSearcher:
    """Deterministic stand-in for the engine: every batch costs exactly
    ``service_ms`` of wall time, so the overload rows measure the
    scheduler, not scoring noise."""

    def __init__(self, service_ms, top_k=4):
        self.service_s = service_ms / 1e3
        self.top_k = top_k

    def search(self, qi, qv):
        time.sleep(self.service_s)
        L = qi.shape[0]
        return SearchResult(np.zeros((L, self.top_k), np.int64),
                            np.zeros((L, self.top_k), np.float32))


def _overload_run(svc, options):
    """Closed-loop overload: every client immediately re-submits when
    its previous request resolves (served, shed, or expired). Returns
    (served latencies [s], n_shed, n_expired)."""
    lats = [[] for _ in range(OVERLOAD_CLIENTS)]
    shed = [0] * OVERLOAD_CLIENTS
    expired = [0] * OVERLOAD_CLIENTS
    qi = np.array([3, 7, 11], np.int32)
    qv = np.array([1.0, 2.0, 1.0], np.float32)

    def client(tid):
        for _ in range(OVERLOAD_REQUESTS):
            t0 = time.perf_counter()
            try:
                svc.submit(Query(qi, qv), options=options).result()
                lats[tid].append(time.perf_counter() - t0)
            except OverloadError:
                shed[tid] += 1
            except DeadlineExceeded:
                expired[tid] += 1

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(OVERLOAD_CLIENTS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return (np.concatenate([np.asarray(l) for l in lats if l]),
            sum(shed), sum(expired))


def _run_clients(n_clients, n_requests, do_query):
    lats = [[] for _ in range(n_clients)]

    def client(tid):
        rng = np.random.default_rng(1000 + tid)
        for _ in range(n_requests):
            t0 = time.perf_counter()
            do_query(rng)
            lats[tid].append(time.perf_counter() - t0)

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return np.concatenate([np.asarray(l) for l in lats]), \
        time.perf_counter() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=4_000)
    ap.add_argument("--vocab", type=int, default=20_000)
    ap.add_argument("--nnz", type=int, default=60)
    ap.add_argument("--nnz-pad", type=int, default=64)
    ap.add_argument("--query-nnz", type=int, default=48)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--no-gate", action="store_true",
                    help="report the acceptance row but never exit "
                         "nonzero on it (CI perf-smoke runs on tiny "
                         "shared runners where the speedup gate is "
                         "noise; there the bench should fail only on "
                         "crash)")
    args = ap.parse_args()

    cfg = SearchConfig(name="serve-bench", vocab_size=args.vocab,
                       avg_nnz_per_doc=args.nnz, nnz_pad=args.nnz_pad,
                       top_k=16)
    corpus = corpus_lib.synthesize(args.docs, args.vocab, args.nnz,
                                   args.nnz_pad, seed=0)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(), backend="jnp")

    def draw(rng):
        return corpus_lib.make_query(corpus, int(rng.integers(args.docs)),
                                     args.query_nnz)

    # warm every L bucket once so all rows are steady-state
    wrng = np.random.default_rng(7)
    L = 1
    while L <= args.max_batch:
        qs = [draw(wrng) for _ in range(L)]
        eng.search(np.stack([q[0] for q in qs]),
                   np.stack([q[1] for q in qs]))
        L *= 2

    # -- serial baseline: one L=1 call at a time ------------------------
    lock = threading.Lock()

    def serial_query(rng):
        qi, qv = draw(rng)
        with lock:
            eng.search(qi[None], qv[None])

    lats, wall = _run_clients(args.clients, args.requests, serial_query)
    serial_qps = lats.size / wall
    _row("serve/serial_qps", wall / lats.size * 1e6, f"{serial_qps:.1f}")

    # -- coalesced sweep ------------------------------------------------
    qps_at = {}
    batch = 1
    while batch <= args.max_batch:
        with SearchService(eng, max_batch=batch, max_delay_ms=1.0) as svc:
            def svc_query(rng):
                qi, qv = draw(rng)
                svc.submit(qi, qv).result()

            lats, wall = _run_clients(args.clients, args.requests, svc_query)
            qps = lats.size / wall
            qps_at[batch] = qps
            _row(f"serve/qps@batch={batch}", wall / lats.size * 1e6,
                 f"{qps:.1f}")
            _row(f"serve/p50_ms@batch={batch}", 0.0,
                 f"{np.percentile(lats, 50) * 1e3:.2f}")
            _row(f"serve/p99_ms@batch={batch}", 0.0,
                 f"{np.percentile(lats, 99) * 1e3:.2f}")
            _row(f"serve/occupancy@batch={batch}", 0.0,
                 f"{svc.stats.mean_occupancy:.2f}")
        batch *= 2

    speedup = qps_at[args.max_batch] / serial_qps
    _row(f"serve/speedup@batch={args.max_batch}", 0.0, f"{speedup:.2f}")
    n_traces = eng.compile_stats["n_traces"]
    bound = int(math.log2(args.max_batch)) + 1
    _row("serve/recompiles", 0.0, f"{n_traces} (bound {bound})")
    ok = speedup >= 2.0 and n_traces <= bound
    print(f"serve/acceptance,{0.0:.1f},"
          f"{'PASS' if ok else 'FAIL'} (speedup {speedup:.2f}x >= 2x, "
          f"{n_traces} traces <= {bound})")
    if not ok and not args.no_gate:
        sys.exit(1)

    # -- overload: FIFO baseline vs the scheduling layer ----------------
    # baseline: unbounded queue, no deadlines — overload becomes tail
    # latency for everyone (every request waits out the whole backlog)
    with SearchService(_SlowSearcher(OVERLOAD_SERVICE_MS),
                       max_batch=OVERLOAD_BATCH, max_delay_ms=1.0) as svc:
        fifo_lats, _, _ = _overload_run(svc, options=None)
    fifo_p99 = float(np.percentile(fifo_lats, 99) * 1e3)
    _row("serve/overload_fifo_p99_ms", 0.0, f"{fifo_p99:.1f}")

    # scheduled: bounded pending queue + per-request deadlines — the
    # same demand sheds at the door, and what IS served stays fast
    with SearchService(_SlowSearcher(OVERLOAD_SERVICE_MS),
                       max_batch=OVERLOAD_BATCH, max_delay_ms=1.0,
                       max_pending=OVERLOAD_MAX_PENDING) as svc:
        opts = QueryOptions(deadline_ms=OVERLOAD_DEADLINE_MS)
        sched_lats, n_shed, n_expired = _overload_run(svc, options=opts)
    total = OVERLOAD_CLIENTS * OVERLOAD_REQUESTS
    sched_p99 = float(np.percentile(sched_lats, 99) * 1e3)
    shed_rate = (n_shed + n_expired) / total
    _row("serve/overload_sched_p99_ms", 0.0, f"{sched_p99:.1f}")
    _row("serve/overload_shed_rate", 0.0,
         f"{shed_rate:.3f} ({n_shed} shed + {n_expired} expired / {total})")

    # bit-identity: scheduling without pressure changes nothing
    rng = np.random.default_rng(23)
    ident = True
    with SearchService(eng, max_batch=4, max_delay_ms=1.0) as svc:
        for _ in range(8):
            qi, qv = draw(rng)
            legacy = eng.search_typed(Query(qi[None], qv[None]))
            resp = svc.submit(Query(qi, qv), options=QueryOptions(
                deadline_ms=60_000.0)).result()
            ident &= bool(np.array_equal(resp.doc_ids, legacy.doc_ids[0])
                          and np.array_equal(resp.scores,
                                             legacy.scores[0]))
    _row("serve/sched_bit_identity", 0.0, "exact" if ident else "DIVERGED")

    ok2 = (sched_p99 <= OVERLOAD_SLO_MS and shed_rate > 0.0
           and sched_p99 < fifo_p99 and ident)
    print(f"serve/overload_acceptance,{0.0:.1f},"
          f"{'PASS' if ok2 else 'FAIL'} "
          f"(sched p99 {sched_p99:.1f}ms <= SLO {OVERLOAD_SLO_MS:.0f}ms "
          f"< fifo p99 {fifo_p99:.1f}ms, shed rate {shed_rate:.3f} > 0, "
          f"bit-identity {'exact' if ident else 'DIVERGED'})")
    if not ok2 and not args.no_gate:
        sys.exit(1)


if __name__ == "__main__":
    main()
