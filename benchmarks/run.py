"""Benchmark harness — the unified entry for every suite in the tree.

With no arguments: one function per paper table/figure, printing
``name,us_per_call,derived`` CSV rows (measured numbers are CPU — this
container; TPU-pod numbers are roofline projections from
paper_projection.py, with the paper's own figures for comparison. See
EXPERIMENTS.md §Paper-claims).

``--suite`` reaches every tier bench from one command and ``--json``
emits one combined BENCH report (the ci_smoke schema, DESIGN.md §13):

    # every suite, full configs, one combined json
    PYTHONPATH=src python benchmarks/run.py --suite all --json BENCH.json

    # a subset, tiny CI-smoke configs
    PYTHONPATH=src python benchmarks/run.py --suite storage,serve --tiny

Suites: paper (this file's tables/figures), storage (cold/warm slab
cache + skip-rate), serve (micro-batch sweep), cluster (shard sweep),
ingest (write path). Tier benches run as subprocesses so each gets a
fresh jax runtime; their CSV rows are echoed and collected.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.ci_smoke import (SUITE_SCRIPTS, TINY, make_env, new_report,
                                 run_script)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import paper_projection as proj
from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.core import stream_format as sf
from repro.distributed.meshctx import single_device_ctx
from repro.kernels import ops as kops


def _time(fn, n=5, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------
def bench_fig13_docs_per_sec():
    """Fig. 13: document match throughput. Measured: CPU engine (the
    'in-memory CPU' configuration (3) analogue). Projected: TPU pod at
    paper sparsity. Paper: 10.35M docs/s (BlueDBM), 13M docs/s (24-thread
    in-memory)."""
    cfg = SearchConfig(name="bench", vocab_size=141_000, avg_nnz_per_doc=60,
                       nnz_pad=64, doc_tile=128, top_k=16,
                       block_docs=128, block_query=512)
    n_docs = 50_000
    corpus = corpus_lib.synthesize(n_docs, cfg.vocab_size,
                                   cfg.avg_nnz_per_doc, cfg.nnz_pad, seed=1)
    ctx = single_device_ctx()
    eng = PatternSearchEngine(corpus, cfg, ctx, backend="jnp")
    qi, qv = corpus_lib.make_query(corpus, 7, cfg.max_query_nnz)

    us = _time(lambda: eng.search(qi[None], qv[None]), n=3)
    cpu_rate = n_docs / (us / 1e6)
    _row("fig13/engine_cpu_1worker_docs_per_sec", us, f"{cpu_rate:.3e}")

    p0 = proj.project(nnz_pad=128, query_tile=2048, l_queries=1)
    _row("fig13/tpu_pod_paper_faithful_docs_per_sec", 0.0,
         f"{p0.docs_per_sec_pod:.3e} ({p0.bound}-bound; "
         f"{p0.speedup_vs_paper():.0f}x paper's 10.35M/s)")
    p1 = proj.project(nnz_pad=64, query_tile=128, l_queries=1, val_bytes=2)
    _row("fig13/tpu_pod_optimized_packed_docs_per_sec", 0.0,
         f"{p1.docs_per_sec_pod:.3e} ({p1.bound}-bound; Fig.8-packed HBM "
         f"corpus; {p1.speedup_vs_paper():.0f}x paper)")
    return cpu_rate


# ---------------------------------------------------------------------------
def bench_table1_power():
    """Table 1: power. Not measurable here; report the projected docs/J on
    v5e (assumed 200 W/chip) vs the paper's 10.35M docs/s / 120 W."""
    p = proj.project(nnz_pad=64, query_tile=512, l_queries=1)
    paper_eff = proj.PAPER_DOCS_PER_SEC / proj.PAPER_WATTS
    _row("table1/paper_docs_per_joule", 0.0, f"{paper_eff:.3e}")
    _row("table1/tpu_projected_docs_per_joule", 0.0,
         f"{p.docs_per_joule:.3e} ({p.docs_per_joule/paper_eff:.0f}x; "
         f"assumes {proj.ASSUMED_CHIP_WATTS:.0f}W/chip)")


# ---------------------------------------------------------------------------
def bench_table2_scalability():
    """Table 2: kernels 8->20, query batch 1->3: the L-query batching that
    lifts arithmetic intensity. We sweep L and report where the bound flips
    (paper: 10.35M -> 27M docs/s estimated)."""
    for L in (1, 3, 8, 16):
        p = proj.project(nnz_pad=64, query_tile=128, l_queries=L,
                         val_bytes=2)
        _row(f"table2/L={L}_pairs_per_sec_pod", 0.0,
             f"{p.docs_per_sec_pod * L:.3e} ({p.bound}-bound, "
             f"{p.flops_per_doc:.0f} flops/doc)")
    # measured CPU analogue: batched vs single-query scoring time
    cfg = SearchConfig(name="b2", vocab_size=20_000, avg_nnz_per_doc=40,
                       nnz_pad=64, top_k=8, block_docs=128, block_query=256)
    corpus = corpus_lib.synthesize(20_000, cfg.vocab_size,
                                   cfg.avg_nnz_per_doc, cfg.nnz_pad, seed=2)
    ctx = single_device_ctx()
    eng = PatternSearchEngine(corpus, cfg, ctx, backend="jnp")
    qs = [corpus_lib.make_query(corpus, i, cfg.max_query_nnz)
          for i in (1, 2, 3)]
    qi = np.stack([q[0] for q in qs])
    qv = np.stack([q[1] for q in qs])
    us3 = _time(lambda: eng.search(qi, qv), n=3)
    us1 = _time(lambda: eng.search(qi[:1], qv[:1]), n=3)
    _row("table2/cpu_batch3_vs_1_speedup", us3,
         f"{3 * us1 / us3:.2f}x effective")


# ---------------------------------------------------------------------------
def bench_sec5c_partial_products():
    """Sec V.C: partial products/sec at 0.04% sparsity (paper: 13M pp/s =
    8.2M docs x 483M words in 0.8s)."""
    from repro.kernels import ref as kref
    cfg = SearchConfig(name="pp", vocab_size=141_000, avg_nnz_per_doc=60,
                       nnz_pad=64, top_k=8)
    corpus = corpus_lib.synthesize(30_000, cfg.vocab_size,
                                   cfg.avg_nnz_per_doc, cfg.nnz_pad, seed=3)
    qi, qv = corpus_lib.make_query(corpus, 11, 2048)
    mi, mv = kops.merge_queries(qi[None], qv[None])
    pp = int(kref.partial_product_count(
        jnp.asarray(corpus.ids), jnp.asarray(corpus.vals), jnp.asarray(mi),
        jnp.asarray(mv), cfg.vocab_size))
    ctx = single_device_ctx()
    eng = PatternSearchEngine(corpus, cfg, ctx, backend="jnp")
    us = _time(lambda: eng.search(qi[None], qv[None]), n=3)
    cpu_pp_rate = pp / (us / 1e6)
    _row("sec5c/cpu_partial_products_per_sec", us, f"{cpu_pp_rate:.3e}")
    p = proj.project(nnz_pad=64, query_tile=512, l_queries=1)
    tpu_pp = proj.partial_products_per_sec(p.docs_per_sec_pod)
    _row("sec5c/tpu_projected_pp_per_sec", 0.0,
         f"{tpu_pp:.3e} ({tpu_pp/proj.PAPER_PP_PER_SEC:.0f}x paper's 13M/s)")


# ---------------------------------------------------------------------------
def bench_fig8_stream_format():
    """Fig. 8 format: encode/decode throughput + bandwidth saving."""
    rng = np.random.default_rng(0)
    docs = [(d, [(int(w), int(rng.integers(1, 50)))
                 for w in np.sort(rng.choice(141_000, 60, replace=False))])
            for d in range(5000)]
    stream = sf.encode(docs)
    us = _time(lambda: sf.decode_to_ell(stream, 64), n=3)
    rate = stream.nbytes / (us / 1e6) / 1e9
    saving = 1 - sf.stream_bytes(docs) / sf.uci_bytes(docs)
    _row("fig8/decode_to_ell_GBps", us, f"{rate:.2f}")
    _row("fig8/bandwidth_saving_vs_uci", 0.0,
         f"{saving*100:.1f}% (paper claims ~50%)")


# ---------------------------------------------------------------------------
def bench_kernel_sparse_match():
    """Pallas kernel (interpret mode on CPU) vs jnp gather path."""
    cfg = SearchConfig(name="k", vocab_size=10_000, avg_nnz_per_doc=40,
                       nnz_pad=64)
    corpus = corpus_lib.synthesize(4096, cfg.vocab_size, 40, 64, seed=4)
    qi, qv = corpus_lib.make_query(corpus, 5, 512)
    mi, mv = kops.merge_queries(qi[None], qv[None])
    mi = np.pad(mi, (0, 512 - mi.size), constant_values=-2)
    mv = np.pad(mv, ((0, 512 - mv.shape[0]), (0, 0)))
    ids, vals = jnp.asarray(corpus.ids), jnp.asarray(corpus.vals)
    mij, mvj = jnp.asarray(mi), jnp.asarray(mv)

    def jnp_path():
        kops.correlate(ids, vals, mij, mvj, backend="jnp",
                       vocab_size=cfg.vocab_size).block_until_ready()

    us = _time(jnp_path, n=5)
    _row("kernel/jnp_gather_docs_per_sec", us, f"{4096/(us/1e6):.3e}")

    def pallas_path():
        kops.correlate(ids, vals, mij, mvj, backend="pallas",
                       block_docs=128, block_query=512).block_until_ready()

    us2 = _time(pallas_path, n=2, warmup=1)
    _row("kernel/pallas_interpret_docs_per_sec", us2,
         f"{4096/(us2/1e6):.3e} (interpret mode: correctness only)")


def paper_main() -> None:
    """The in-process paper tables/figures (the legacy CSV surface)."""
    print("name,us_per_call,derived")
    bench_fig8_stream_format()
    bench_fig13_docs_per_sec()
    bench_table1_power()
    bench_table2_scalability()
    bench_sec5c_partial_products()
    bench_kernel_sparse_match()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default=None, metavar="TAGS",
                    help="comma list of "
                         f"{','.join(SUITE_SCRIPTS)} or 'all' "
                         "(default: paper benches in-process)")
    ap.add_argument("--json", metavar="PATH", dest="json_out",
                    help="write every suite's rows to one combined "
                         "BENCH json (ci_smoke schema); without "
                         "--suite this runs ALL suites at full config")
    ap.add_argument("--tiny", action="store_true",
                    help="run each suite at the CI-smoke tiny config "
                         "instead of its full defaults")
    args = ap.parse_args()

    if args.suite is None and not args.json_out:
        if args.tiny:
            ap.error("--tiny only applies to the suite runner; pass "
                     "--suite (and/or --json) with it")
        paper_main()            # back-compat: plain CSV on stdout
        return

    tags = list(SUITE_SCRIPTS) if args.suite in (None, "all") \
        else [t.strip() for t in args.suite.split(",") if t.strip()]
    unknown = [t for t in tags if t not in SUITE_SCRIPTS]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; "
                 f"pick from {list(SUITE_SCRIPTS)}")

    env = make_env()
    report = new_report()
    failed = []
    for tag in tags:
        if tag == "paper":
            argv = []           # a bare run.py prints the paper CSV
        else:
            argv = TINY[tag] if args.tiny else []
        print(f"== {tag} ==")
        entry = run_script(tag, argv, env=env, echo_rows=True)
        report["benches"][tag] = entry
        if entry["returncode"] != 0:
            failed.append(tag)
            sys.stderr.write(entry.get("stderr_tail", ""))
        print(f"[{tag}] {'ok' if entry['returncode'] == 0 else 'CRASH'} "
              f"in {entry['wall_s']:.1f}s, {len(entry['rows'])} rows")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=1)
        n_rows = sum(len(b["rows"]) for b in report["benches"].values())
        print(f"wrote {args.json_out} ({n_rows} rows)")
    if failed:
        sys.exit(f"benchmark crash in: {', '.join(failed)}")


if __name__ == "__main__":
    main()
