"""Storage-tier benchmark: segments/sec through the flash path and
vocabulary-filter skip-rate vs query sparsity (DESIGN.md §13).

Prints the same ``name,us_per_call,derived`` CSV rows as run.py.

The skip-rate sweep is the storage tier's headline: the paper's
in-storage filter wins by never moving non-matching data, and the
segment vocabulary filter is the same lever at store scope — sparser
(fewer-word) queries overlap fewer segments and skip more of the store.
The corpus here is clustered (documents drawn from per-topic vocabulary
bands, one band group per segment) the way real corpora are (tenants,
languages, protein families); a fully-mixed corpus degrades to
skip-rate 0 and the streaming throughput row is then the floor.

Usage: PYTHONPATH=src python benchmarks/storage_bench.py [--docs 20000]
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.obs import Obs
from repro.storage import FlashSearchSession, FlashStore


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _clustered_docs(n_docs, vocab_size, n_topics, nnz, rng):
    """Per-topic vocabulary bands -> docs list grouped by topic."""
    band = vocab_size // n_topics
    docs = []
    for i in range(n_docs):
        topic = (i * n_topics) // n_docs     # contiguous topic runs
        words = rng.choice(np.arange(topic * band, (topic + 1) * band),
                           min(nnz, band), replace=False)
        docs.append((i, sorted((int(w), int(rng.integers(1, 30)))
                               for w in words)))
    return docs


def _query(docs, idx, q_nnz, max_query_nnz):
    qi = np.full((1, max_query_nnz), -1, np.int32)
    qv = np.zeros((1, max_query_nnz), np.float32)
    pairs = docs[idx][1][:q_nnz]
    for j, (w, c) in enumerate(pairs):
        qi[0, j] = w
        qv[0, j] = c
    return qi, qv


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20_000)
    ap.add_argument("--docs-per-segment", type=int, default=1_000)
    ap.add_argument("--topics", type=int, default=20)
    ap.add_argument("--vocab", type=int, default=141_000)
    ap.add_argument("--nnz", type=int, default=60)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--keep", help="persist the store at this path")
    ap.add_argument("--obs-gate-pct", type=float, default=2.0,
                    help="max tolerated warm-median overhead of the "
                         "always-on metrics layer vs Obs.disabled()")
    ap.add_argument("--min-cores", type=int, default=8,
                    help="enforce the overhead gate only on hosts with "
                         "at least this many cores (shared runners are "
                         "too noisy for a 2%% latency gate)")
    ap.add_argument("--fused-gate-speedup", type=float, default=1.0,
                    help="min fused-vs-unfused warm speedup; enforced "
                         "only on TPU (CPU runs the fused kernel in "
                         "interpret mode — correct, not fast) and with "
                         "at least --min-cores cores")
    args = ap.parse_args()

    cfg = SearchConfig(name="storage-bench", vocab_size=args.vocab,
                       avg_nnz_per_doc=args.nnz, nnz_pad=64, top_k=16,
                       block_docs=128, block_query=512)
    rng = np.random.default_rng(0)
    docs = _clustered_docs(args.docs, args.vocab, args.topics, args.nnz, rng)

    root = args.keep or os.path.join(tempfile.mkdtemp(), "store")
    t0 = time.perf_counter()
    store = FlashStore.create(root, vocab_size=args.vocab,
                              docs_per_segment=args.docs_per_segment)
    store.append_docs(docs)
    build_s = time.perf_counter() - t0
    nbytes = sum(seg.nbytes for seg in store.segments())
    _row("storage/build_docs_per_sec", build_s * 1e6,
         f"{args.docs / build_s:.0f}")
    _row("storage/store_MB", 0.0, f"{nbytes / 1e6:.1f}")

    # cache disabled here: these rows measure the *disk* streaming path
    # (mmap read + ELL decode + upload per segment, every query)
    sess = FlashSearchSession(store, cfg, cache_bytes=0)

    # -- streaming throughput: a dense query that hits every segment ---
    dense = np.concatenate([np.asarray(d[1], np.int64)[:, 0]
                            for d in docs[:: args.docs // 64]])
    qi = np.full((1, cfg.max_query_nnz), -1, np.int32)
    qv = np.zeros((1, cfg.max_query_nnz), np.float32)
    uw = np.unique(dense)[:cfg.max_query_nnz]
    qi[0, :uw.size] = uw.astype(np.int32)
    qv[0, :uw.size] = 1.0
    sess.search(qi, qv)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(args.repeats):
        sess.search(qi, qv)
    dt = (time.perf_counter() - t0) / args.repeats
    st = sess.last_stats
    _row("storage/segments_per_sec", dt * 1e6 / max(st.segments_scored, 1),
         f"{st.segments_scored / dt:.1f}")
    _row("storage/stream_docs_per_sec", dt * 1e6,
         f"{st.docs_scored / dt:.0f}")
    _row("storage/stream_MBps", dt * 1e6, f"{nbytes / dt / 1e6:.1f}")

    # -- skip-rate vs query sparsity -----------------------------------
    for q_nnz in (1, 4, 16, 64):
        rates, lat = [], []
        for trial in range(5):
            idx = int(rng.integers(args.docs))
            tqi, tqv = _query(docs, idx, q_nnz, cfg.max_query_nnz)
            t0 = time.perf_counter()
            sess.search(tqi, tqv)
            lat.append(time.perf_counter() - t0)
            rates.append(sess.last_stats.skip_rate)
        _row(f"storage/skip_rate@qnnz={q_nnz}", np.mean(lat) * 1e6,
             f"{np.mean(rates):.3f}")

    sess.close()

    # -- cold vs warm: the §4.2 device slab cache ----------------------
    # Same dense query (every segment survives the filter). Cold reps
    # clear the cache first, so each pays disk + decode + upload at
    # steady-state compile; warm reps hit the cache for every segment.
    # The split is the headline of the planning/cache layer: first-query
    # vs steady-state latency on an unchanged corpus.
    csess = FlashSearchSession(FlashStore.open(root), cfg)
    csess.search(qi, qv)                     # warmup / compile
    cold, warm = [], []
    for _ in range(max(args.repeats, 2)):
        csess.slab_cache.clear()
        t0 = time.perf_counter()
        csess.search(qi, qv)
        cold.append(time.perf_counter() - t0)
    assert csess.last_stats.cache_hits == 0   # cleared: all disk
    csess.search(qi, qv)                     # repopulated above; now warm
    for _ in range(max(args.repeats, 2)):
        t0 = time.perf_counter()
        csess.search(qi, qv)
        warm.append(time.perf_counter() - t0)
    st = csess.last_stats
    cold_ms, warm_ms = np.mean(cold) * 1e3, np.mean(warm) * 1e3
    _row("storage/cold_query_ms", np.mean(cold) * 1e6, f"{cold_ms:.2f}")
    _row("storage/warm_query_ms", np.mean(warm) * 1e6, f"{warm_ms:.2f}")
    _row("storage/warm_speedup", 0.0, f"{cold_ms / warm_ms:.2f}x")
    _row("storage/warm_cache_hit_rate", 0.0,
         f"{st.cache_hit_rate:.3f} ({st.cache_hits}/"
         f"{st.cache_hits + st.cache_misses} slabs, "
         f"{csess.slab_cache.nbytes / 1e6:.1f} MB resident)")

    # -- fused decode+match+top-k backend (§12): cold/warm over the
    # same store, bit-identity vs the staged path, and the
    # fused-vs-unfused warm speedup gate. The gate is a performance
    # statement, so it only votes on compiled TPU programs; on CPU the
    # same kernel runs in Pallas interpret mode — the correctness half
    # (bit-identical results) is asserted unconditionally.
    ell_res = csess.search(qi, qv)
    fsess = FlashSearchSession(FlashStore.open(root), cfg,
                               backend="pallas_fused")
    fres = fsess.search(qi, qv)              # warmup / compile
    np.testing.assert_array_equal(fres.doc_ids, ell_res.doc_ids)
    np.testing.assert_array_equal(fres.scores, ell_res.scores)
    fcold, fwarm = [], []
    for _ in range(max(args.repeats, 2)):
        fsess.slab_cache.clear()
        t0 = time.perf_counter()
        fsess.search(qi, qv)
        fcold.append(time.perf_counter() - t0)
    fsess.search(qi, qv)                     # repopulate; now warm
    for _ in range(max(args.repeats, 2)):
        t0 = time.perf_counter()
        fsess.search(qi, qv)
        fwarm.append(time.perf_counter() - t0)
    fsess.close()
    fcold_ms, fwarm_ms = np.mean(fcold) * 1e3, np.mean(fwarm) * 1e3
    _row("storage/fused_cold_query_ms", np.mean(fcold) * 1e6,
         f"{fcold_ms:.2f}")
    _row("storage/fused_warm_query_ms", np.mean(fwarm) * 1e6,
         f"{fwarm_ms:.2f} (bit-identical to the staged warm result)")
    speedup = warm_ms / fwarm_ms
    cores = os.cpu_count() or 1
    on_tpu = jax.default_backend() == "tpu"
    if cores >= args.min_cores and on_tpu:
        fused_ok = speedup >= args.fused_gate_speedup
        fdetail = (f"{'PASS' if fused_ok else 'FAIL'} (gate >="
                   f"{args.fused_gate_speedup:g}x: fused={fwarm_ms:.2f}ms "
                   f"staged={warm_ms:.2f}ms)")
    else:
        fused_ok = True
        why = (f"{jax.default_backend()} backend runs the fused kernel in "
               "interpret mode" if not on_tpu
               else f"host has {cores} cores < {args.min_cores}")
        fdetail = f"SKIP gate: {why}"
    _row("storage/fused_vs_unfused_speedup", 0.0, f"{speedup:.2f}x {fdetail}")

    # -- per-stage latency (§8): every query above ran under the
    # process-default registry, so its stage histograms already cover
    # the disk-streaming, skip-sweep, cold, and warm passes
    for name, labels, kind, m in csess.obs.registry.items():
        if name == "stage_ms" and kind == "histogram" and m.count:
            _row(f"storage/stage_ms@{labels['stage']}", m.p50 * 1e3,
                 f"p50={m.p50:.3f}ms p95={m.p95:.3f}ms n={m.count}")
    csess.close()

    # -- tracing-off overhead gate (§8): warm-path medians with the
    # always-on metrics layer vs Obs.disabled() (the instrumentation
    # floor). Tracing itself is off in both — that is the shipped
    # default whose cost the <2% budget bounds. The "on" bundle also
    # serves a live TelemetryServer that a background thread scrapes
    # (~20 Hz, far hotter than any real Prometheus interval) throughout
    # the timed loop, so the same <2% band now prices the §8.5 live
    # plane: windowed twins + concurrent /metrics rendering included.
    import threading
    import urllib.request

    from repro.obs.server import TelemetryServer

    reps = max(args.repeats * 4, 12)
    on_obs = Obs()
    gsess = {tag: FlashSearchSession(FlashStore.open(root), cfg, obs=bundle)
             for tag, bundle in (("on", on_obs), ("off", Obs.disabled()))}
    for s in gsess.values():                 # compile + populate caches
        s.search(qi, qv)
        s.search(qi, qv)
    telemetry = TelemetryServer(on_obs)
    scrape_stop = threading.Event()
    scrapes = [0]

    def scraper():
        url = telemetry.url("/metrics")
        while not scrape_stop.is_set():
            with urllib.request.urlopen(url) as resp:
                resp.read()
            scrapes[0] += 1
            scrape_stop.wait(0.05)

    scrape_thread = threading.Thread(target=scraper, daemon=True)
    scrape_thread.start()
    ts = {"on": [], "off": []}
    for rep in range(reps):                  # interleave + alternate order
        for tag in (("on", "off") if rep % 2 else ("off", "on")):
            t0 = time.perf_counter()
            gsess[tag].search(qi, qv)
            ts[tag].append(time.perf_counter() - t0)
    scrape_stop.set()
    scrape_thread.join(timeout=5)
    telemetry.close()
    medians = {tag: float(np.median(v)) for tag, v in ts.items()}
    for s in gsess.values():
        s.close()
    overhead = (medians["on"] - medians["off"]) / medians["off"] * 100
    cores = os.cpu_count() or 1
    if cores >= args.min_cores:
        ok = overhead < args.obs_gate_pct
        verdict = "PASS" if ok else "FAIL"
        detail = (f"{verdict} (gate <{args.obs_gate_pct:g}%: on="
                  f"{medians['on'] * 1e3:.3f}ms off="
                  f"{medians['off'] * 1e3:.3f}ms)")
    else:
        ok = True
        detail = (f"SKIP gate: host has {cores} cores < {args.min_cores} "
                  f"(measured on={medians['on'] * 1e3:.3f}ms "
                  f"off={medians['off'] * 1e3:.3f}ms)")
    _row("storage/obs_overhead_pct", 0.0, f"{overhead:.2f}% {detail}")

    if not args.keep:
        shutil.rmtree(os.path.dirname(root), ignore_errors=True)
    if not (ok and fused_ok):
        sys.exit(1)


if __name__ == "__main__":
    main()
