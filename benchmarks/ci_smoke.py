"""CI perf-smoke driver: run the storage, serving, and ingest benchmarks
in a tiny configuration, collect their CSV rows, and write them to a
single ``BENCH_ci.json`` that CI uploads as a workflow artifact
(DESIGN.md §10).

The point is the *trajectory*: every CI run leaves one machine-readable
snapshot of the perf counters, so a regression shows up as a step in
the artifact series long before anyone reruns the full benchmarks. On
shared CI runners absolute numbers are noise, so this driver fails only
when a benchmark crashes — acceptance gates (speedup floors, recompile
bounds) stay in the benchmarks themselves for real hardware
(``serve_bench`` runs here with ``--no-gate``).

Usage: PYTHONPATH=src python benchmarks/ci_smoke.py [--out BENCH_ci.json]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import time

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))

# tiny configurations: the goal is rows-in-minutes on a 2-core runner,
# not statistically meaningful numbers
TINY = [
    ("storage", "storage_bench.py",
     ["--docs", "3000", "--docs-per-segment", "300", "--vocab", "20000",
      "--topics", "10", "--repeats", "1"]),
    ("serve", "serve_bench.py",
     ["--docs", "1500", "--vocab", "10000", "--clients", "4",
      "--requests", "8", "--max-batch", "4", "--no-gate"]),
    ("ingest", "ingest_bench.py",
     ["--docs", "2000", "--append-docs", "600", "--docs-per-segment",
      "250", "--seal-docs", "100", "--vocab", "10000", "--repeats", "5"]),
]


def _parse_rows(stdout: str):
    """``name,us_per_call,derived`` lines -> row dicts (anything else on
    stdout is commentary and skipped)."""
    rows = []
    for line in stdout.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) != 3 or "/" not in parts[0]:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append({"name": parts[0], "us_per_call": us,
                     "derived": parts[2]})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ci.json")
    args = ap.parse_args()

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(BENCH_DIR, "..", "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    report = {
        "schema": "repro-bench-v1",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "cpus": os.cpu_count()},
        "benches": {},
    }
    failed = []
    for tag, script, argv in TINY:
        cmd = [sys.executable, os.path.join(BENCH_DIR, script)] + argv
        t0 = time.perf_counter()
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env)
        wall = time.perf_counter() - t0
        rows = _parse_rows(proc.stdout)
        report["benches"][tag] = {
            "cmd": " ".join(cmd[1:]),
            "returncode": proc.returncode,
            "wall_s": round(wall, 2),
            "rows": rows,
        }
        status = "ok" if proc.returncode == 0 else "CRASH"
        print(f"[{tag}] {status} in {wall:.1f}s, {len(rows)} rows")
        if proc.returncode != 0:
            failed.append(tag)
            sys.stderr.write(proc.stdout[-2000:] + proc.stderr[-4000:])

    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out} "
          f"({sum(len(b['rows']) for b in report['benches'].values())} rows)")
    if failed:
        sys.exit(f"benchmark crash in: {', '.join(failed)}")


if __name__ == "__main__":
    main()
