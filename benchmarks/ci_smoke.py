"""CI perf-smoke driver: run the storage, serving, and ingest benchmarks
in a tiny configuration, collect their CSV rows, and write them to a
single ``BENCH_ci.json`` that CI uploads as a workflow artifact
(DESIGN.md §13).

The point is the *trajectory*: every CI run leaves one machine-readable
snapshot of the perf counters — including the storage bench's
cold-vs-warm slab-cache split (§4.2), so a cache regression shows up as
a step in the warm-query series — long before anyone reruns the full
benchmarks. On shared CI runners absolute numbers are noise, so this
driver fails only when a benchmark crashes (acceptance gates stay in
the benchmarks themselves for real hardware; ``serve_bench`` runs here
with ``--no-gate``).

This module is import-light on purpose: ``benchmarks/run.py --suite``
(the unified entry that also reaches the cluster and paper benches)
reuses ``parse_rows`` / ``run_script`` / ``new_report`` from here.

``--check PATH`` validates an existing report instead of running the
benches: the storage bench must have exported its per-stage latency
rows, a passing (or explicitly skipped) tracing-off overhead gate
(DESIGN.md §8), and the fused-backend cold/warm rows with a
non-failing fused-vs-unfused speedup gate (DESIGN.md §12) — CI's
perf-smoke job runs this right after the smoke pass so a
silently-dropped row fails the build.

Usage: PYTHONPATH=src python benchmarks/ci_smoke.py [--out BENCH_ci.json]
       PYTHONPATH=src python benchmarks/ci_smoke.py --check BENCH_ci.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import time

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))

# tag -> benchmark script reachable from the unified entry
SUITE_SCRIPTS = {
    "paper": "run.py",
    "storage": "storage_bench.py",
    "serve": "serve_bench.py",
    "cluster": "cluster_bench.py",
    "ingest": "ingest_bench.py",
    "recall": "recall_bench.py",
}

# tiny configurations: the goal is rows-in-minutes on a 2-core runner,
# not statistically meaningful numbers
TINY = {
    "storage": ["--docs", "3000", "--docs-per-segment", "300", "--vocab",
                "20000", "--topics", "10", "--repeats", "1"],
    "serve": ["--docs", "1500", "--vocab", "10000", "--clients", "4",
              "--requests", "8", "--max-batch", "4", "--no-gate"],
    "cluster": ["--docs", "2000", "--docs-per-segment", "250", "--vocab",
                "10000", "--shards", "1", "2", "--clients", "4",
                "--requests", "4", "--max-batch", "4"],
    "ingest": ["--docs", "2000", "--append-docs", "600", "--docs-per-segment",
               "250", "--seal-docs", "100", "--vocab", "10000",
               "--repeats", "5"],
    # --min-cores 999: the speedup half of the recall gate never votes
    # in the tiny config (numbers are noise here); the recall half is
    # deterministic and stays enforced
    "recall": ["--docs", "2000", "--docs-per-segment", "400", "--vocab",
               "15000", "--queries", "4", "--repeats", "1",
               "--min-cores", "999"],
    "paper": [],
}

# the smoke subset CI runs on every change (cluster and paper stay
# reachable via ``run.py --suite all`` — too slow for every commit)
CI_TAGS = ("storage", "serve", "ingest", "recall")


def make_env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(BENCH_DIR, "..", "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def parse_rows(stdout: str):
    """``name,us_per_call,derived`` lines -> row dicts (anything else on
    stdout is commentary and skipped)."""
    rows = []
    for line in stdout.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) != 3 or "/" not in parts[0]:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append({"name": parts[0], "us_per_call": us,
                     "derived": parts[2]})
    return rows


def run_script(tag: str, argv, env=None, echo_rows: bool = False) -> dict:
    """Run one benchmark script as a subprocess and return its report
    entry ({cmd, returncode, wall_s, rows, [stderr_tail]})."""
    script = SUITE_SCRIPTS[tag]
    cmd = [sys.executable, os.path.join(BENCH_DIR, script)] + list(argv)
    t0 = time.perf_counter()
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          env=env or make_env())
    wall = time.perf_counter() - t0
    rows = parse_rows(proc.stdout)
    if echo_rows:
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    entry = {"cmd": " ".join(cmd[1:]), "returncode": proc.returncode,
             "wall_s": round(wall, 2), "rows": rows}
    if proc.returncode != 0:
        entry["stderr_tail"] = (proc.stdout[-2000:] + proc.stderr[-4000:])
    return entry


def new_report() -> dict:
    return {
        "schema": "repro-bench-v1",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "cpus": os.cpu_count()},
        "benches": {},
    }


def check_report(path: str) -> list:
    """Validate an existing BENCH json's observability rows; returns the
    list of problems (empty = ok)."""
    with open(path) as f:
        report = json.load(f)
    problems = []
    rows = {r["name"]: r
            for b in report.get("benches", {}).values()
            for r in b.get("rows", [])}
    stages = [n for n in rows if n.startswith("storage/stage_ms@")]
    if len(stages) < 3:
        problems.append(f"expected >=3 storage/stage_ms@* rows, got "
                        f"{sorted(stages)}")
    gate = rows.get("storage/obs_overhead_pct")
    if gate is None:
        problems.append("missing storage/obs_overhead_pct row")
    elif "FAIL" in gate["derived"]:
        problems.append(f"overhead gate failed: {gate['derived']}")
    # fused-backend rows (DESIGN.md §12): the cold/warm split must be in
    # every snapshot, and the fused-vs-unfused speedup gate — which
    # SKIPs off-TPU or below the core floor — must not read FAIL
    for name in ("storage/fused_cold_query_ms",
                 "storage/fused_warm_query_ms"):
        if name not in rows:
            problems.append(f"missing {name} row")
    fgate = rows.get("storage/fused_vs_unfused_speedup")
    if fgate is None:
        problems.append("missing storage/fused_vs_unfused_speedup row")
    elif "FAIL" in fgate["derived"]:
        problems.append(f"fused speedup gate failed: {fgate['derived']}")
    # approximate-tier rows (DESIGN.md §15): the exact baseline, at
    # least one recall@10 point of the candidate sweep, and a
    # non-failing recall/QPS gate must be in every snapshot
    if "recall/exact_query_ms" not in rows:
        problems.append("missing recall/exact_query_ms row")
    recalls = [n for n in rows if n.startswith("recall/recall_at_10@")]
    if not recalls:
        problems.append("expected >=1 recall/recall_at_10@c=* row, got none")
    rgate = rows.get("recall/gate")
    if rgate is None:
        problems.append("missing recall/gate row")
    elif "FAIL" in rgate["derived"]:
        problems.append(f"recall gate failed: {rgate['derived']}")
    return problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ci.json")
    ap.add_argument("--check", metavar="PATH",
                    help="validate an existing report's observability "
                         "rows instead of running the benches")
    args = ap.parse_args()

    if args.check:
        problems = check_report(args.check)
        for p in problems:
            print(f"[check] {p}")
        if problems:
            sys.exit(f"{args.check}: {len(problems)} problem(s)")
        print(f"[check] {args.check}: observability rows ok")
        return

    env = make_env()
    report = new_report()
    failed = []
    for tag in CI_TAGS:
        entry = run_script(tag, TINY[tag], env=env)
        report["benches"][tag] = entry
        status = "ok" if entry["returncode"] == 0 else "CRASH"
        print(f"[{tag}] {status} in {entry['wall_s']:.1f}s, "
              f"{len(entry['rows'])} rows")
        if entry["returncode"] != 0:
            failed.append(tag)
            sys.stderr.write(entry["stderr_tail"])

    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out} "
          f"({sum(len(b['rows']) for b in report['benches'].values())} rows)")
    if failed:
        sys.exit(f"benchmark crash in: {', '.join(failed)}")


if __name__ == "__main__":
    main()
