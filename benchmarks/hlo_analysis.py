"""Static analyzer for post-SPMD HLO text: FLOPs, collective bytes, dot
traffic — *while-loop aware*.

``compiled.cost_analysis()`` counts a while body ONCE, but every layer scan
(and remat backward) is a while loop, so its numbers undercount by ~L x.
This parser rebuilds per-computation costs and multiplies while bodies by
their trip counts (recovered from the canonical induction-variable compare
constant in the condition computation).

Used by benchmarks/roofline.py; validated in tests/test_hlo_analysis.py
against programs with known FLOP counts.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_instr_line(line: str):
    """Robust instruction parse: handles tuple types containing spaces,
    '=' inside /*index=N*/ comments, and nested parens."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":  # tuple type: scan balanced parens
        depth = 0
        j = i
        while j < len(line):
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i:j + 1]
        k = j + 1
    else:
        j = line.find(" ", i)
        if j < 0:
            return None
        type_str = line[i:j]
        k = j
    mo = _OPCODE_RE.match(line, k)
    if not mo:
        return None
    return name, type_str, mo.group(1), line[mo.end():]
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    dot_bytes: float = 0.0        # every dot operand charged per execution
    dot_bytes_once: float = 0.0   # while bodies charged once ("read-once"
    #                               HBM model: streamed stacked weights =
    #                               whole array once per loop; VMEM-resident
    #                               flash tiles not re-charged per kv block)

    def add(self, other: "Cost", mult: float = 1.0, bytes_mult: float = 1.0):
        self.flops += other.flops * mult
        self.dot_bytes += other.dot_bytes * mult
        self.dot_bytes_once += other.dot_bytes_once * bytes_mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def parse_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = mc.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_instr_line(line)
        if parsed:
            name, type_str, opcode, rest = parsed
            comps[cur].append(Instr(name=name, type_str=type_str,
                                    opcode=opcode, rest=rest))
    return comps


def _dot_flops(instr: Instr, symtab: Dict[str, str]) -> Tuple[float, float]:
    """(flops, hbm_bytes) for a dot. flops = 2 * prod(result) * K."""
    out_dims = shape_dims(instr.type_str) or []
    out_elems = math.prod(out_dims) if out_dims else 1
    # contraction size from lhs shape + lhs_contracting_dims
    ops = _OPERAND_RE.findall(instr.rest)
    lhs_type = symtab.get(ops[0], "") if ops else ""
    lhs_dims = shape_dims(lhs_type) or []
    mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    k = 1
    if mcd and lhs_dims:
        for d in mcd.group(1).split(","):
            if d:
                k *= lhs_dims[int(d)]
    flops = 2.0 * out_elems * k
    bytes_ = shape_bytes(instr.type_str)
    for o in ops[:2]:
        bytes_ += shape_bytes(symtab.get(o, ""))
    return flops, bytes_


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LEAD_INT_RE = re.compile(r"^(\d+)\)")


def _trip_count(while_rest: str, cond_instrs: List[Instr]) -> int:
    """Prefer XLA's known_trip_count backend_config; fall back to the
    compare constant in the canonical condition computation."""
    m = _TRIP_RE.search(while_rest)
    if m:
        return int(m.group(1))
    best = 1
    for ins in cond_instrs:
        if ins.opcode == "constant":
            mi = _LEAD_INT_RE.match(ins.rest.strip())
            if mi:
                best = max(best, int(mi.group(1)))
    return best


class HloCostModel:
    """XLA:CPU legalizes bf16 compute to f32, so collectives that are bf16
    at the jaxpr level (verified: MoE all_to_all, residual psums) appear as
    f32 in the dry-run HLO. When a collective operand is produced by a
    fusion that converts from bf16 (or feeds one), we charge bf16 bytes —
    matching what the TPU backend would move (bf16_correction)."""

    def __init__(self, text: str, bf16_correction: bool = True):
        self.bf16_correction = bf16_correction
        self.comps = parse_computations(text)
        self._memo: Dict[str, Cost] = {}
        # entry = computation containing ROOT with name matching ENTRY; take
        # the one named like 'main' or the last parsed with 'ENTRY'
        self.entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_RE.match(line)
                if m:
                    self.entry = m.group(1)
        if self.entry is None:  # fall back: biggest computation
            self.entry = max(self.comps, key=lambda c: len(self.comps[c]))

    def cost_of(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()  # cycle guard
        total = Cost()
        instrs = self.comps.get(comp, [])
        symtab = {i.name: i.type_str for i in instrs}
        for ins in instrs:
            if ins.opcode == "dot":
                f, b = _dot_flops(ins, symtab)
                total.flops += f
                total.dot_bytes += b
                total.dot_bytes_once += b
            elif ins.opcode.rstrip("-start").rstrip("-done") in COLLECTIVES \
                    or ins.opcode in COLLECTIVES:
                base = ins.opcode.replace("-start", "").replace("-done", "")
                if base in COLLECTIVES and not ins.opcode.endswith("-done"):
                    ops = _OPERAND_RE.findall(ins.rest)
                    defs = {i.name: i for i in instrs}
                    b = 0
                    for o in ops:
                        if o in symtab:
                            ob = shape_bytes(symtab[o])
                            # XLA:CPU legalizes bf16->f32; all large
                            # collectives in this framework are logically
                            # bf16 (grads, activations, dispatch, FSDP
                            # gathers — verified at jaxpr level), so charge
                            # bf16 for big f32 ops / proven-bf16 producers.
                            if self.bf16_correction and "f32" in symtab[o] \
                                    and (ob > 64 * 1024 * 1024 or
                                         self._is_legalized_bf16(defs.get(o))):
                                ob //= 2
                            b += ob
                    if b == 0:  # operands may be parameters; use result
                        b = shape_bytes(ins.type_str)
                    total.coll_bytes[base] = total.coll_bytes.get(base, 0) + b
            elif ins.opcode == "while":
                mb = _BODY_RE.search(ins.rest)
                mc = _COND_RE.search(ins.rest)
                cond_instrs = self.comps.get(mc.group(1), []) if mc else []
                trips = _trip_count(ins.rest, cond_instrs)
                if mb and mb.group(1) in self.comps:
                    total.add(self.cost_of(mb.group(1)), mult=max(trips, 1),
                              bytes_mult=1.0)
            elif ins.opcode in ("fusion", "call", "conditional",
                                "async-start", "custom-call", "map",
                                "reduce", "sort", "scatter", "select-and-scatter"):
                for m in _CALLS_RE.finditer(ins.rest):
                    if m.group(1) in self.comps:
                        total.add(self.cost_of(m.group(1)))
                # fused computations referenced via calls= handled above;
                # custom-call matmuls (oneDNN) estimated from shapes
                if ins.opcode == "custom-call" and "matmul" in ins.rest.lower():
                    out_dims = shape_dims(ins.type_str) or []
                    ops = _OPERAND_RE.findall(ins.rest)
                    lhs_dims = shape_dims(symtab.get(ops[0], "")) if ops \
                        else None
                    if out_dims and lhs_dims:
                        k = lhs_dims[-1]
                        total.flops += 2.0 * math.prod(out_dims) * k
        self._memo[comp] = total
        return total

    def _is_legalized_bf16(self, d: Optional[Instr]) -> bool:
        """Producer is a fusion/convert whose computation round-trips
        through bf16 -> the value is logically bf16."""
        if d is None:
            return False
        if d.opcode == "convert":
            return True
        if d.opcode == "fusion":
            for m in _CALLS_RE.finditer(d.rest):
                for ins in self.comps.get(m.group(1), []):
                    if ins.opcode == "convert" and "bf16" in ins.type_str:
                        return True
                    if ins.opcode == "convert" and "bf16" in ins.rest:
                        return True
        return False

    def entry_cost(self) -> Cost:
        return self.cost_of(self.entry)


def analyze(text: str) -> Dict[str, float]:
    model = HloCostModel(text)
    c = model.entry_cost()
    out = {"flops": c.flops, "dot_bytes": c.dot_bytes,
           "dot_bytes_once": c.dot_bytes_once,
           "collective_bytes": c.total_coll_bytes}
    for k, v in c.coll_bytes.items():
        out[f"coll_{k}"] = v
    return out
