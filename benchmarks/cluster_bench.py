"""Cluster-tier benchmark: shard-count sweep over one corpus behind the
scatter/gather router (DESIGN.md §5, §13).

Prints the same ``name,us_per_call,derived`` CSV rows as run.py:

    cluster/qps@shards=N       closed-loop QPS, C clients through the
                               coalescing service, N-shard cluster
    cluster/p50_ms@shards=N    per-query latency
    cluster/p99_ms@shards=N
    cluster/skip_rate@shards=N aggregate vocab-filter skip rate over a
                               narrow-query probe set
    cluster/speedup@shards=N   QPS vs the 1-shard cluster
    cluster/compile_per_shard  max engine traces of any shard
                               (acceptance: <= log2(max_batch)+1, §7.2)

Acceptance: the per-shard compile bound always holds; the >= 2x QPS at
4 shards bound is enforced only on hosts with >= 8 cores — shard
strong-scaling is capped by cores, and concurrent jax CPU dispatch
*loses* to serial execution on small hosts (the router's worker pool
adapts the same way), so on a small host the row reports the measured
ratio and the criterion is SKIPped rather than failed.

Usage: PYTHONPATH=src python benchmarks/cluster_bench.py [--docs 12000]
"""
from __future__ import annotations

import argparse
import math
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.cluster import FlashClusterSession, build_sharded_store
from repro.configs.paper_search import SearchConfig
from repro.launch.search_serve import run_clients


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _banded_docs(n_docs, n_topics, vocab, nnz, rng):
    """Topic-banded corpus: doc i draws from vocabulary band
    i*topics//n — range sharding keeps bands contiguous, so narrow
    queries exercise per-shard in-storage pruning."""
    band = vocab // n_topics
    docs = []
    for i in range(n_docs):
        topic = (i * n_topics) // n_docs
        words = rng.choice(np.arange(topic * band, (topic + 1) * band),
                           nnz, replace=False)
        docs.append((i, sorted((int(w), int(rng.integers(1, 30)))
                               for w in words)))
    return docs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=12_000)
    ap.add_argument("--vocab", type=int, default=20_000)
    ap.add_argument("--topics", type=int, default=16)
    ap.add_argument("--nnz", type=int, default=48)
    ap.add_argument("--nnz-pad", type=int, default=64)
    ap.add_argument("--docs-per-segment", type=int, default=750)
    ap.add_argument("--shards", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--min-speedup", type=float, default=2.0)
    ap.add_argument("--min-cores", type=int, default=8,
                    help="enforce the speedup bound only at >= this many "
                         "host cores (strong scaling is core-capped)")
    args = ap.parse_args()

    cfg = SearchConfig(name="cluster-bench", vocab_size=args.vocab,
                       avg_nnz_per_doc=args.nnz, nnz_pad=args.nnz_pad,
                       top_k=16)
    rng = np.random.default_rng(0)
    docs = _banded_docs(args.docs, args.topics, args.vocab, args.nnz, rng)

    def draw(r):
        """Mixed workload: mostly broad cross-band queries (every shard
        scores), some narrow in-band ones (most segments pruned)."""
        d = docs[int(r.integers(args.docs))][1]
        qi = np.full(cfg.max_query_nnz, -1, np.int32)
        qv = np.zeros(cfg.max_query_nnz, np.float32)
        for j, (w, c) in enumerate(d):
            qi[j] = w
            qv[j] = c
        if r.random() < 0.75:            # broaden: touch other bands too
            extra = np.sort(r.choice(args.vocab, 32, replace=False))
            qi[len(d):len(d) + 32] = extra.astype(np.int32)
            qv[len(d):len(d) + 32] = 0.01
        return qi, qv

    tmp = tempfile.mkdtemp(prefix="cluster-bench-")
    qps_at, skip_at, worst_traces = {}, {}, 0
    try:
        for n_shards in args.shards:
            root = os.path.join(tmp, f"shards-{n_shards}")
            cluster = build_sharded_store(
                root, docs, n_shards=n_shards, replicas=args.replicas,
                policy="range", vocab_size=args.vocab,
                docs_per_segment=args.docs_per_segment)
            with FlashClusterSession(cluster, cfg) as sess:
                svc = sess.service(max_batch=args.max_batch,
                                   max_delay_ms=2.0)
                # warm every L-bucket program per shard (steady state)
                wrng = np.random.default_rng(7)
                L = 1
                while L <= args.max_batch:
                    qs = [draw(wrng) for _ in range(L)]
                    sess.search(np.stack([q[0] for q in qs]),
                                np.stack([q[1] for q in qs]))
                    L *= 2

                def do_query(r):
                    qi, qv = draw(r)
                    svc.submit(qi, qv).result()

                lats, wall = run_clients(args.clients, args.requests,
                                         do_query)
                qps = lats.size / wall
                qps_at[n_shards] = qps
                _row(f"cluster/qps@shards={n_shards}",
                     wall / lats.size * 1e6, f"{qps:.1f}")
                _row(f"cluster/p50_ms@shards={n_shards}", 0.0,
                     f"{np.percentile(lats, 50) * 1e3:.2f}")
                _row(f"cluster/p99_ms@shards={n_shards}", 0.0,
                     f"{np.percentile(lats, 99) * 1e3:.2f}")

                # aggregate skip-rate on narrow in-band probes
                skipped = total = 0
                prng = np.random.default_rng(13)
                for _ in range(8):
                    d = docs[int(prng.integers(args.docs))][1]
                    qi = np.full((1, cfg.max_query_nnz), -1, np.int32)
                    qv = np.zeros((1, cfg.max_query_nnz), np.float32)
                    for j, (w, c) in enumerate(d):
                        qi[0, j] = w
                        qv[0, j] = c
                    sess.search(qi, qv)
                    skipped += sess.last_stats.segments_skipped
                    total += sess.last_stats.segments_total
                skip_at[n_shards] = skipped / total if total else 0.0
                _row(f"cluster/skip_rate@shards={n_shards}", 0.0,
                     f"{skip_at[n_shards]:.2f}")
                worst_traces = max(worst_traces,
                                   max(sess.compile_stats["per_shard"]))
            shutil.rmtree(root, ignore_errors=True)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    base = args.shards[0]
    for n_shards in args.shards[1:]:
        _row(f"cluster/speedup@shards={n_shards}", 0.0,
             f"{qps_at[n_shards] / qps_at[base]:.2f}")
    bound = int(math.log2(args.max_batch)) + 1
    _row("cluster/compile_per_shard", 0.0,
         f"{worst_traces} (bound {bound})")

    top = max(args.shards)
    speedup = qps_at[top] / qps_at[base]
    cores = os.cpu_count() or 1
    compile_ok = worst_traces <= bound
    if cores >= args.min_cores:
        ok = compile_ok and speedup >= args.min_speedup
        verdict = "PASS" if ok else "FAIL"
        detail = (f"speedup {speedup:.2f}x >= {args.min_speedup}x, "
                  f"{worst_traces} traces <= {bound}")
    else:
        ok = compile_ok
        verdict = "PASS" if ok else "FAIL"
        detail = (f"speedup gate SKIP: host has {cores} cores "
                  f"< {args.min_cores} (measured {speedup:.2f}x); "
                  f"{worst_traces} traces <= {bound}")
    print(f"cluster/acceptance,0.0,{verdict} ({detail})")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
