"""Noise-aware perf-regression detector over BENCH_*.json snapshots
(DESIGN.md §13).

``ci_smoke.py`` leaves one ``repro-bench-v1`` report per CI run; this
tool diffs two of them — a committed ``BENCH_baseline.json`` and the
fresh run — row by row and fails the build only on regressions that
clear a per-row tolerance band. Three layers of noise defense, because
shared CI runners jitter double digits:

1. **Per-row tolerance bands** (``GATES``): warm-path rows — the
   steady-state serving numbers the repo actually optimizes — gate at
   15%; cold rows (dominated by mmap page-in and first-touch compile)
   and everything un-listed get the loose ``DEFAULT_TOL``.
2. **An absolute noise floor** (``--min-us``): a row that moved from
   120 µs to 180 µs is a 50% "regression" made of scheduler hiccups;
   rows whose *both* sides sit under the floor are reported but never
   gate.
3. **Informational rows**: names present in only one report (a bench
   was added or renamed) are listed, never failed — the baseline is
   refreshed by committing the new file, not by blocking the PR that
   added a row.

Exit status: 0 when no gated row regresses beyond its band, 1
otherwise. ``--update-baseline`` copies current -> baseline instead of
comparing (the maintained way to re-anchor after an accepted perf
change).

Usage:
    PYTHONPATH=src python benchmarks/ci_smoke.py --out BENCH_ci.json
    python benchmarks/bench_compare.py BENCH_baseline.json BENCH_ci.json
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from typing import Dict, Optional, Tuple

# per-row relative tolerance: current may exceed baseline by this
# fraction before the row fails. Warm rows are the tight gates (the
# ISSUE's >15% warm-path bar); cold rows carry page-cache + compile
# noise and get wide bands so they inform without flapping.
GATES: Dict[str, float] = {
    "storage/warm_query_ms": 0.15,
    "storage/fused_warm_query_ms": 0.15,
    "storage/cold_query_ms": 0.50,
    "storage/fused_cold_query_ms": 0.50,
    "serve/coalesced_p50_ms": 0.25,
    "serve/coalesced_p99_ms": 0.40,
    "ingest/append_us": 0.40,
    # approximate tier (DESIGN.md §15): the candidate-path latency is
    # the row the tier exists to shrink, so it gates like a warm row;
    # the exact baseline rides along looser (it is the storage bench's
    # stream path measured again). recall_at_10/speedup rows are
    # derived-only (us=0) and never gate here — the recall floor is
    # recall_bench's own PASS/FAIL verdict, checked by ci_smoke --check.
    "recall/approx_query_ms@c=16": 0.25,
    "recall/approx_query_ms@c=64": 0.25,
    "recall/approx_query_ms@c=256": 0.25,
    "recall/exact_query_ms": 0.50,
}
DEFAULT_TOL = 0.50          # un-listed rows: report, gate only loosely
MIN_US = 500.0              # noise floor: sub-0.5 ms rows never gate


def load_rows(path: str) -> Dict[str, float]:
    """Flatten one repro-bench-v1 report to {row name: us_per_call}."""
    with open(path) as f:
        report = json.load(f)
    if report.get("schema") != "repro-bench-v1":
        sys.exit(f"{path}: unknown schema {report.get('schema')!r}")
    rows: Dict[str, float] = {}
    for bench in report.get("benches", {}).values():
        for r in bench.get("rows", []):
            rows[r["name"]] = float(r["us_per_call"])
    return rows


def compare_row(name: str, base: float, cur: float, *,
                min_us: float = MIN_US
                ) -> Tuple[str, float, Optional[float]]:
    """One row's verdict: (status, delta_fraction, tolerance).
    status is 'ok' | 'FAIL' | 'noise' (both sides under the floor) |
    'improved'."""
    tol = GATES.get(name, DEFAULT_TOL)
    if base <= 0.0:
        # a zero/negative baseline carries no signal (derived-only row)
        return "noise", 0.0, tol
    delta = (cur - base) / base
    if base < min_us and cur < min_us:
        return "noise", delta, tol
    if delta > tol:
        return "FAIL", delta, tol
    if delta < -0.05:
        return "improved", delta, tol
    return "ok", delta, tol


def compare(baseline: Dict[str, float], current: Dict[str, float], *,
            min_us: float = MIN_US):
    """Full diff: returns (lines to print, list of failed row names)."""
    lines, failed = [], []
    for name in sorted(set(baseline) | set(current)):
        if name not in current:
            lines.append(f"  -       {name}: only in baseline "
                         f"({baseline[name]:.1f}us) — informational")
            continue
        if name not in baseline:
            lines.append(f"  +       {name}: new row "
                         f"({current[name]:.1f}us) — informational")
            continue
        base, cur = baseline[name], current[name]
        status, delta, tol = compare_row(name, base, cur, min_us=min_us)
        if status == "FAIL":
            failed.append(name)
        lines.append(
            f"  {status:<7} {name}: {base:.1f}us -> {cur:.1f}us "
            f"({delta:+.1%}, band ±{tol:.0%})")
    return lines, failed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("current", help="fresh BENCH_*.json from ci_smoke")
    ap.add_argument("--min-us", type=float, default=MIN_US,
                    help="absolute noise floor: rows under this on both "
                         "sides never gate (default %(default)s)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="copy current over baseline instead of "
                         "comparing (re-anchor after an accepted change)")
    args = ap.parse_args()

    if args.update_baseline:
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated: {args.current} -> {args.baseline}")
        return

    baseline = load_rows(args.baseline)
    current = load_rows(args.current)
    lines, failed = compare(baseline, current, min_us=args.min_us)
    print(f"bench compare: {args.baseline} vs {args.current} "
          f"({len(baseline)} baseline rows, {len(current)} current)")
    for line in lines:
        print(line)
    if failed:
        sys.exit(f"{len(failed)} row(s) regressed beyond tolerance: "
                 f"{', '.join(failed)}")
    print("no gated regressions")


if __name__ == "__main__":
    main()
