"""Ingestion-tier benchmark: write-path throughput and the cost of
searching under live writes (DESIGN.md §13).

Prints the same ``name,us_per_call,derived`` CSV rows as run.py:

    ingest/appends_per_sec       WAL + memtable append rate (no fsync)
    ingest/seal_ms               memtable -> delta segment commit
    ingest/compact_ms            tail fold of the accumulated deltas
    ingest/search_static_ms      query latency, quiesced store
    ingest/search_live_ms        query latency with a writer thread
                                 appending flat out (snapshot capture +
                                 memtable scoring overhead included)
    ingest/search_live_overhead  live / static latency ratio

Usage: PYTHONPATH=src python benchmarks/ingest_bench.py [--docs 20000]
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.storage import FlashSearchSession, FlashStore


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _docs(n, vocab, nnz, rng, start_id=0):
    return [(start_id + i,
             sorted((int(w), int(rng.integers(1, 30))) for w in
                    rng.choice(vocab, nnz, replace=False)))
            for i in range(n)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20_000,
                    help="base corpus size (appends add --append-docs)")
    ap.add_argument("--append-docs", type=int, default=4_000)
    ap.add_argument("--docs-per-segment", type=int, default=1_000)
    ap.add_argument("--seal-docs", type=int, default=500)
    ap.add_argument("--vocab", type=int, default=50_000)
    ap.add_argument("--nnz", type=int, default=60)
    ap.add_argument("--repeats", type=int, default=20)
    args = ap.parse_args()

    cfg = SearchConfig(name="ingest-bench", vocab_size=args.vocab,
                       avg_nnz_per_doc=args.nnz, nnz_pad=64, top_k=16,
                       block_docs=128, block_query=512)
    rng = np.random.default_rng(0)
    base = _docs(args.docs, args.vocab, args.nnz, rng)
    extra = _docs(args.append_docs, args.vocab, args.nnz, rng,
                  start_id=args.docs)

    root = os.path.join(tempfile.mkdtemp(), "store")
    store = FlashStore.create(root, vocab_size=args.vocab,
                              docs_per_segment=args.docs_per_segment)
    store.append_docs(base)
    sess = FlashSearchSession(store, cfg)
    pipe = sess.enable_ingest(seal_docs=args.seal_docs,
                              fold_min_segments=4, auto_compact=False)

    # -- append throughput (seals included, amortized) ------------------
    t0 = time.perf_counter()
    for d, p in extra:
        sess.append(d, p)
    dt = time.perf_counter() - t0
    _row("ingest/appends_per_sec", dt * 1e6 / len(extra),
         f"{len(extra) / dt:.0f}")

    # -- seal + compact latency ----------------------------------------
    sess.append(*_docs(1, args.vocab, args.nnz, rng,
                       start_id=args.docs + len(extra))[0])
    t0 = time.perf_counter()
    pipe.seal()
    _row("ingest/seal_ms", 0.0, f"{(time.perf_counter() - t0) * 1e3:.2f}")
    t0 = time.perf_counter()
    folded = pipe.compact_once()
    _row("ingest/compact_ms", 0.0,
         f"{(time.perf_counter() - t0) * 1e3:.2f} ({folded} folded)")

    # -- search latency: quiesced vs under live appends ----------------
    probe = base[len(base) // 2]
    qi = np.full((1, cfg.max_query_nnz), -1, np.int32)
    qv = np.zeros((1, cfg.max_query_nnz), np.float32)
    for j, (w, c) in enumerate(probe[1][:cfg.max_query_nnz]):
        qi[0, j] = w
        qv[0, j] = c
    sess.search(qi, qv)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(args.repeats):
        sess.search(qi, qv)
    static = (time.perf_counter() - t0) / args.repeats
    _row("ingest/search_static_ms", static * 1e6, f"{static * 1e3:.2f}")

    stop = threading.Event()

    def writer():
        i = 0
        churn = _docs(2_000, args.vocab, args.nnz, rng, start_id=10**7)
        while not stop.is_set():
            sess.append(*churn[i % len(churn)])
            i += 1

    t = threading.Thread(target=writer, daemon=True)
    t.start()
    t0 = time.perf_counter()
    for _ in range(args.repeats):
        sess.search(qi, qv)
    live = (time.perf_counter() - t0) / args.repeats
    stop.set()
    t.join(timeout=10)
    _row("ingest/search_live_ms", live * 1e6, f"{live * 1e3:.2f}")
    _row("ingest/search_live_overhead", 0.0, f"{live / static:.2f}x")

    sess.close()
    shutil.rmtree(os.path.dirname(root), ignore_errors=True)


if __name__ == "__main__":
    main()
