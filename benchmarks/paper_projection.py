"""First-principles TPU-v5e projections for the paper's headline numbers.

The paper's accelerator is bandwidth-bound at 2 GB/s flash (10.35M docs/s,
~240 B/doc in the Fig. 8 stream format). Our "storage" is pod HBM: the same
roofline algebra at 819 GB/s/chip x 256 chips, with the match-matrix
kernel's arithmetic intensity deciding when the L-query batching (paper
Table 2) flips the bound from memory to compute.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12
HBM_BW = 819e9
CHIPS_PER_POD = 256
ASSUMED_CHIP_WATTS = 200.0     # assumption, recorded in EXPERIMENTS.md
PAPER_DOCS_PER_SEC = 10.35e6   # Table 2 row 1
PAPER_OPT_DOCS_PER_SEC = 27e6  # Table 2 row 2 (estimated in paper)
PAPER_WATTS = 120.0            # Table 1, BlueDBM column
PAPER_PP_PER_SEC = 13e6        # Sec V.C


@dataclasses.dataclass
class Projection:
    name: str
    docs_per_sec_chip: float
    docs_per_sec_pod: float
    bound: str
    flops_per_doc: float
    bytes_per_doc: float
    docs_per_joule: float

    def speedup_vs_paper(self) -> float:
        return self.docs_per_sec_pod / PAPER_DOCS_PER_SEC


def project(nnz_pad: int = 128, query_tile: int = 512, l_queries: int = 1,
            val_bytes: int = 4, chips: int = CHIPS_PER_POD) -> Projection:
    """ELL corpus scan: bytes/doc = 2 arrays x nnz_pad x 4B; match-matrix
    FLOPs/doc = eq-dot (2 x nnz_pad x Qm x L) + compare ops."""
    bytes_per_doc = 2 * nnz_pad * val_bytes
    flops_per_doc = 2.0 * nnz_pad * query_tile * l_queries + \
        nnz_pad * query_tile          # compares on the VPU
    mem_rate = HBM_BW / bytes_per_doc
    comp_rate = PEAK_FLOPS / flops_per_doc
    rate = min(mem_rate, comp_rate)
    bound = "memory" if mem_rate < comp_rate else "compute"
    return Projection(
        name=f"L={l_queries},Q={query_tile},K={nnz_pad}",
        docs_per_sec_chip=rate,
        docs_per_sec_pod=rate * chips,
        bound=bound,
        flops_per_doc=flops_per_doc,
        bytes_per_doc=bytes_per_doc,
        docs_per_joule=rate / ASSUMED_CHIP_WATTS,
    )


def partial_products_per_sec(docs_per_sec: float, avg_nnz: int = 60,
                             vocab: int = 141_000,
                             query_nnz: int = 60) -> float:
    """Expected nonzero partial products/s at the paper's sparsity: each
    (doc word, query word) pair matches with p = query_nnz / vocab."""
    pp_per_doc = avg_nnz * query_nnz / vocab
    return docs_per_sec * pp_per_doc
