"""Recall@k vs QPS benchmark for the approximate candidate tier
(DESIGN.md §15).

The approximate tier answers a query in two phases: the per-segment
posting index nominates a top-C candidate pool, and only those rows are
gathered and re-ranked through the exact scoring stack. The bargain is
recall-for-throughput, and this bench prices it: one exact
(full-stream) baseline, then a sweep over candidate-pool sizes C, each
reporting latency, recall@k against the exact top-k, and the speedup.

Both sessions run with the slab cache disabled — a warm slab makes
exact scoring free, so the cache-on steady state never takes the
posting path by design (execute_plan consults the cache first); the
interesting regime is the disk-bound one, which is exactly where the
candidate tier pays.

The corpus is *mixed* (every doc samples the whole vocabulary), the
complement of storage_bench's clustered corpus: vocabulary filters
prune by term overlap, so a mixed corpus degrades their skip-rate to 0
and exact search must stream every segment. That is precisely the
workload the posting tier exists for — it prunes by *score*, not by
term presence, and keeps winning where the filter can't.

Gate (the ISSUE's acceptance bar): some swept C must reach recall@10
>= --recall-gate (default 0.95) AND speedup >= --speedup-gate (default
2x) over the exact baseline. The recall half is deterministic and
always enforced; the speedup half is a performance statement, so —
like storage_bench's gates — it only votes on hosts with at least
--min-cores cores and SKIPs elsewhere. --no-gate downgrades everything
to informational (CI's tiny run).

Prints the same ``name,us_per_call,derived`` CSV rows as run.py.

Usage: PYTHONPATH=src python benchmarks/recall_bench.py [--docs 20000]
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.serve.api import Query, QueryOptions
from repro.storage import FlashSearchSession, FlashStore

TOP_K = 10                       # the recall@k axis is recall@10


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _mixed_docs(n_docs, vocab_size, nnz, rng):
    """Fully-mixed corpus: every doc samples the whole vocabulary, so
    the per-segment vocab filter skips nothing and exact search streams
    every segment (see module docstring)."""
    docs = []
    for i in range(n_docs):
        words = rng.choice(vocab_size, min(nnz, vocab_size), replace=False)
        docs.append((i, sorted((int(w), int(rng.integers(1, 30)))
                               for w in words)))
    return docs


def _queries(docs, n_queries, q_nnz, max_query_nnz, rng):
    """Doc-derived queries (the realistic case: queries share the
    corpus vocabulary, so posting lists actually match)."""
    out = []
    for idx in rng.choice(len(docs), n_queries, replace=False):
        qi = np.full((1, max_query_nnz), -1, np.int32)
        qv = np.zeros((1, max_query_nnz), np.float32)
        pairs = docs[int(idx)][1][:q_nnz]
        for j, (w, c) in enumerate(pairs):
            qi[0, j] = w
            qv[0, j] = c
        out.append((qi, qv))
    return out


def _recall_at_k(exact_ids, approx_ids, k):
    """|exact top-k ∩ approx top-k| / k for one query row."""
    e = set(int(d) for d in np.asarray(exact_ids).ravel()[:k] if d >= 0)
    a = set(int(d) for d in np.asarray(approx_ids).ravel()[:k] if d >= 0)
    return len(e & a) / max(len(e), 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20_000)
    ap.add_argument("--docs-per-segment", type=int, default=2_000)
    ap.add_argument("--vocab", type=int, default=141_000)
    ap.add_argument("--nnz", type=int, default=60)
    ap.add_argument("--q-nnz", type=int, default=8)
    ap.add_argument("--queries", type=int, default=10)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--candidates", type=int, nargs="+",
                    default=[16, 64, 256],
                    help="candidate-pool sizes C to sweep (row names "
                         "embed these, so keep them stable for "
                         "bench_compare)")
    ap.add_argument("--recall-gate", type=float, default=0.95)
    ap.add_argument("--speedup-gate", type=float, default=2.0)
    ap.add_argument("--min-cores", type=int, default=8,
                    help="enforce the speedup half of the gate only on "
                         "hosts with at least this many cores")
    ap.add_argument("--no-gate", action="store_true",
                    help="report rows, never fail (CI tiny runs)")
    args = ap.parse_args()

    cfg = SearchConfig(name="recall-bench", vocab_size=args.vocab,
                       avg_nnz_per_doc=args.nnz, nnz_pad=64, top_k=TOP_K,
                       block_docs=128, block_query=512)
    rng = np.random.default_rng(7)
    docs = _mixed_docs(args.docs, args.vocab, args.nnz, rng)
    queries = _queries(docs, args.queries, args.q_nnz,
                       cfg.max_query_nnz, rng)

    root = os.path.join(tempfile.mkdtemp(), "store")
    store = FlashStore.create(root, vocab_size=args.vocab,
                              docs_per_segment=args.docs_per_segment)
    store.append_docs(docs)

    # cache disabled: see module docstring — this is the disk-bound
    # regime where the candidate tier actually changes the cost model
    sess = FlashSearchSession(store, cfg, cache_bytes=0)

    # -- exact baseline (full-stream scoring, every query) -------------
    for qi, qv in queries:                   # compile warmup
        sess.search(Query(qi, qv))
    t0 = time.perf_counter()
    for _ in range(args.repeats):
        for qi, qv in queries:
            sess.search(Query(qi, qv))
    exact_s = (time.perf_counter() - t0) / (args.repeats * len(queries))
    exact_top = [np.asarray(sess.search(Query(qi, qv)).doc_ids)
                 for qi, qv in queries]
    _row("recall/exact_query_ms", exact_s * 1e6,
         f"{exact_s * 1e3:.2f} ({1.0 / exact_s:.1f} QPS)")

    # -- candidate-pool sweep ------------------------------------------
    best = None                              # (recall, speedup, C)
    for c in args.candidates:
        opts = QueryOptions(mode="approx", candidates=c)
        approx_segments = 0
        for qi, qv in queries:               # compile warmup (pool shapes)
            sess.search(Query(qi, qv), options=opts)
            approx_segments += sess.last_stats.approx_segments
        assert approx_segments > 0, \
            "approx sweep never took the posting path (bench bug)"
        t0 = time.perf_counter()
        for _ in range(args.repeats):
            for qi, qv in queries:
                sess.search(Query(qi, qv), options=opts)
        approx_s = ((time.perf_counter() - t0)
                    / (args.repeats * len(queries)))
        recalls = []
        for (qi, qv), ref in zip(queries, exact_top):
            res = sess.search(Query(qi, qv), options=opts)
            recalls.append(_recall_at_k(ref, res.doc_ids, TOP_K))
        recall = float(np.mean(recalls))
        speedup = exact_s / approx_s
        _row(f"recall/approx_query_ms@c={c}", approx_s * 1e6,
             f"{approx_s * 1e3:.2f} ({1.0 / approx_s:.1f} QPS, "
             f"{sess.last_stats.candidates} candidate docs/query)")
        _row(f"recall/recall_at_10@c={c}", 0.0, f"{recall:.3f}")
        _row(f"recall/speedup@c={c}", 0.0, f"{speedup:.2f}x")
        if best is None or (recall, speedup) > best[:2]:
            best = (recall, speedup, c)

    sess.close()
    shutil.rmtree(os.path.dirname(root), ignore_errors=True)

    # -- gate -----------------------------------------------------------
    recall_best, speed_best, c_best = best
    cores = os.cpu_count() or 1
    ok = True
    if args.no_gate:
        detail = (f"SKIP gate (--no-gate): best C={c_best} "
                  f"recall={recall_best:.3f} speedup={speed_best:.2f}x")
    else:
        recall_ok = recall_best >= args.recall_gate
        if cores >= args.min_cores:
            speed_ok = speed_best >= args.speedup_gate
            ok = recall_ok and speed_ok
            detail = (f"{'PASS' if ok else 'FAIL'} (gate recall>="
                      f"{args.recall_gate:g} and speedup>="
                      f"{args.speedup_gate:g}x: best C={c_best} "
                      f"recall={recall_best:.3f} "
                      f"speedup={speed_best:.2f}x)")
        else:
            # recall is deterministic — enforce it even on small hosts;
            # only the perf half SKIPs
            ok = recall_ok
            verdict = "PASS" if ok else "FAIL"
            detail = (f"{verdict} recall-only (host has {cores} cores < "
                      f"{args.min_cores}; speedup={speed_best:.2f}x "
                      f"informational, recall={recall_best:.3f})")
    _row("recall/gate", 0.0, detail)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
