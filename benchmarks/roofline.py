"""Roofline table builder (assignment §ROOFLINE ANALYSIS).

Reads dryrun JSON + gzipped HLO, runs the while-aware static analyzer, and
emits per-(arch x shape x mesh):

    compute term    = HLO_FLOPs_per_chip / peak_FLOPs
    memory term     = HBM_traffic_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / (links x link_bw)

(The post-SPMD HLO is the per-chip program, so per-chip quantities come out
directly; dividing global totals by chip count is equivalent.)

HBM traffic model: dot operand+result bytes from the analyzer (each matmul
operand read once — fusion of elementwise ops means dots dominate traffic),
plus the decode-cache sweep for serve steps. cost_analysis() numbers are
recorded too but undercount while-loop bodies (documented).

MODEL_FLOPS: train = 6·N·D (N params or active params for MoE, D tokens);
prefill = 2·N·D; decode = 2·N·B (+ attention cache term, reported
separately). The ratio MODEL_FLOPS / HLO_FLOPs flags remat/redundancy
waste.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI (per-direction per-link budget the assignment specifies).
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys
from typing import Dict, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.hlo_analysis import analyze  # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16 * 1024 ** 3

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token per sequence
    "long_500k": 1,
}
SHAPE_KIND = {"train_4k": "train", "prefill_32k": "prefill",
              "decode_32k": "decode", "long_500k": "decode"}


def model_flops(rec: Dict) -> float:
    n = rec["active_params"] if rec["active_params"] else rec["params"]
    d = SHAPE_TOKENS[rec["shape"]]
    if SHAPE_KIND[rec["shape"]] == "train":
        return 6.0 * n * d
    return 2.0 * n * d


def analyze_cell(json_path: str) -> Optional[Dict]:
    with open(json_path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return rec
    hlo_path = json_path.replace(".json", ".hlo.gz")
    if os.path.exists(hlo_path):
        with gzip.open(hlo_path, "rt") as f:
            text = f.read()
        rec["hlo_analysis"] = analyze(text)
    h = rec.get("hlo_analysis", {})
    chips = rec["n_chips"]
    flops_chip = h.get("flops", 0.0)
    coll_chip = h.get("collective_bytes", 0.0)
    # HBM traffic model (documented in the module docstring):
    #   read-once dot bytes (while bodies once: flash tiles stay in VMEM)
    # + analytic parameter stream (layer-scanned stacked weights read fully
    #   per pass: fwd + bwd + grad write for train, one read for serve)
    kind = SHAPE_KIND[rec["shape"]]
    param_traffic = rec["params"] * 2 / chips * (3 if kind == "train" else 1)
    mem_chip = h.get("dot_bytes_once", h.get("dot_bytes", 0.0)) + param_traffic
    # decode steps additionally sweep the whole KV cache (elementwise +
    # reduce, not dots): charge the argument bytes once per step
    if kind == "decode":
        mem_chip += rec["memory_analysis"].get("argument_size_in_bytes", 0)

    terms = {
        "compute_s": flops_chip / PEAK_FLOPS,
        "memory_s": mem_chip / HBM_BW,
        "collective_s": coll_chip / (LINK_BW),
    }
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops(rec)
    rec["roofline"] = {
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_per_chip": flops_chip,
        "hlo_flops_global": flops_chip * chips,
        "useful_ratio": mf / max(flops_chip * chips, 1.0),
        "mfu_at_bound": mf / max(step_s, 1e-12) / (chips * PEAK_FLOPS),
        "step_time_s": step_s,
        "fits_v5e": (rec["memory_analysis"].get("argument_size_in_bytes", 0)
                     + rec["memory_analysis"].get("temp_size_in_bytes", 0))
        < HBM_PER_CHIP,
    }
    return rec


def build_table(dryrun_dir: str, mesh: str = "single",
                variant: str = "base"):
    rows = []
    suffix = "" if variant == "base" else f"__{variant}"
    for path in sorted(glob.glob(os.path.join(
            dryrun_dir, f"*__{mesh}{suffix}.json"))):
        base = os.path.basename(path)
        if variant == "base" and base.count("__") != 2:
            continue
        rec = analyze_cell(path)
        if rec is None:
            continue
        rows.append(rec)
    return rows


def fmt_table(rows) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'dom':11s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'MFU@bound':>9s} "
           f"{'useful':>7s} {'fits':>5s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} "
                         f"SKIP ({r['reason'][:60]})")
            continue
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} ERROR")
            continue
        rf = r["roofline"]
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} "
            f"{rf['dominant'].replace('_s',''):11s} "
            f"{rf['compute_s']:10.4f} {rf['memory_s']:10.4f} "
            f"{rf['collective_s']:10.4f} {rf['mfu_at_bound']*100:8.1f}% "
            f"{rf['useful_ratio']*100:6.1f}% "
            f"{'y' if rf['fits_v5e'] else 'N':>5s}")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = build_table(args.dir, args.mesh, args.variant)
    print(fmt_table(rows))
    if args.json_out:
        slim = []
        for r in rows:
            r = dict(r)
            r.pop("traceback", None)
            slim.append(r)
        with open(args.json_out, "w") as f:
            json.dump(slim, f, indent=1)


if __name__ == "__main__":
    main()
