import atexit
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
sys.path.insert(0, os.path.dirname(__file__))


def pytest_sessionfinish(session, exitstatus):
    """Arrange to hard-exit with pytest's real status instead of running
    interpreter finalization.

    jax 0.4's CPU runtime intermittently aborts ("terminate called
    without an active exception", SIGABRT) during interpreter shutdown
    after a large suite — every test has passed and the summary printed
    when it fires, but the exit code becomes 134 and CI reads that as a
    failure. The atexit handler registers last, so it runs first: it
    flushes stdio and ``os._exit``s before the racy native teardown.
    The terminal summary still prints normally (sessionfinish returns)."""

    if "coverage" in sys.modules or os.environ.get("REPRO_NO_HARD_EXIT"):
        # os._exit would skip earlier-registered atexit hooks (coverage's
        # data-file save, profilers); let those runs take the SIGABRT
        # lottery instead of losing their data silently
        return

    def _exit_now(status=int(exitstatus)):
        sys.stdout.flush()
        sys.stderr.flush()
        os._exit(status)

    atexit.register(_exit_now)
