"""Concurrent query serving: 16 blocking clients, one engine, coalesced
micro-batches (DESIGN.md §7) — driven through the typed Query /
QueryOptions request API (DESIGN.md §7.3).

Each "user" thread submits single queries and blocks on its Future —
the closed-loop shape of real traffic. The SearchService coalesces
whatever is pending into one L-column batch per corpus pass, so
throughput scales with concurrency while every client still gets
exactly the result a serial engine search would have returned. Passing
QueryOptions opts a request into the scheduling plane: it gets a
latency budget (the EDF batcher flushes early to honor it), a tenant
for admission accounting, and a SearchResponse back whose QueryStats
report the queue wait the scheduler actually charged it.

    PYTHONPATH=src python examples/serve_search.py
"""
import threading
import time

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.serve import Query, QueryOptions, SearchService


def main():
    cfg = SearchConfig(name="serve-demo", vocab_size=30_000,
                       avg_nnz_per_doc=50, nnz_pad=64, top_k=5)
    n_docs, n_clients, per_client = 8_000, 16, 16
    print(f"synthesizing {n_docs} docs, serving {n_clients} concurrent "
          f"clients x {per_client} queries each...")
    corpus = corpus_lib.synthesize(n_docs, cfg.vocab_size,
                                   cfg.avg_nnz_per_doc, cfg.nnz_pad, seed=0)
    engine = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                                 backend="jnp")

    # warm each power-of-two L bucket so the demo numbers are steady-state
    rng = np.random.default_rng(0)
    L = 1
    while L <= 8:
        qs = [corpus_lib.make_query(corpus, int(rng.integers(n_docs)), 48)
              for _ in range(L)]
        engine.search(Query(np.stack([q[0] for q in qs]),
                            np.stack([q[1] for q in qs])))
        L *= 2

    hits = []
    waits = []
    lock = threading.Lock()
    # every request runs under a generous 250ms budget; the EDF batcher
    # flushes early rather than let one miss it
    opts = QueryOptions(deadline_ms=250.0, tenant="demo")
    with SearchService(engine, max_batch=8, max_delay_ms=2.0) as svc:
        def client(tid):
            crng = np.random.default_rng(100 + tid)
            for _ in range(per_client):
                want = int(crng.integers(n_docs))
                qi, qv = corpus_lib.make_query(corpus, want, 48)
                resp = svc.submit(Query(qi, qv),
                                  options=opts).result()  # blocking Future
                with lock:
                    hits.append(resp.doc_ids[0] == want)
                    waits.append(resp.stats.queue_wait_ms)

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(n_clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        st = svc.stats

    n = n_clients * per_client
    print(f"\n{n} queries in {wall:.2f}s -> {n / wall:.0f} QPS")
    print(f"batches: {st.n_batches}, mean occupancy "
          f"{st.mean_occupancy:.2f}, flushes {st.flushes}")
    print(f"queue wait (scheduler-attributed): mean "
          f"{np.mean(waits):.2f} ms, max {np.max(waits):.2f} ms")
    print(f"engine programs compiled: "
          f"{engine.compile_stats['n_traces']} (L-bucket cache)")
    assert all(hits), "every self-query must rank its own document first"
    print("OK: all self-queries returned themselves at rank 1")


if __name__ == "__main__":
    main()
