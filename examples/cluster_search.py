"""Cluster-tier search: one corpus partitioned over 4 shard FlashStores
with 2 replicas each, served scatter/gather behind one session
(DESIGN.md §5).

Builds a topic-banded corpus, splits it with the range policy (bands
stay contiguous, so each shard's segment vocab filters stay clustered),
then runs (1) a narrow query that only one shard scores — every other
shard prunes all of its segments in storage — and (2) the same query
after killing the owning shard's primary replica, which fails over to
the second replica with the identical result.

    PYTHONPATH=src python examples/cluster_search.py
"""
import os
import shutil
import tempfile

import numpy as np

from repro.cluster import FlashClusterSession, build_sharded_store
from repro.configs.paper_search import SearchConfig


def main():
    cfg = SearchConfig(name="cluster-demo", vocab_size=40_000,
                       avg_nnz_per_doc=32, nnz_pad=64, top_k=5)
    n_docs, n_topics = 8_000, 16
    band = cfg.vocab_size // n_topics

    rng = np.random.default_rng(0)
    docs = []
    for i in range(n_docs):
        topic = (i * n_topics) // n_docs
        words = rng.choice(np.arange(topic * band, (topic + 1) * band),
                           cfg.avg_nnz_per_doc, replace=False)
        docs.append((i, sorted((int(w), int(rng.integers(1, 30)))
                               for w in words)))

    root = os.path.join(tempfile.mkdtemp(), "cluster")
    print(f"partitioning {n_docs} docs into 4 shards x 2 replicas "
          f"(range policy, topic-banded)...")
    cluster = build_sharded_store(root, docs, n_shards=4, replicas=2,
                                  policy="range",
                                  vocab_size=cfg.vocab_size,
                                  docs_per_segment=500)
    for s, st in enumerate(cluster.stats()):
        print(f"  shard {s}: {st.n_docs} docs / {st.n_segments} segments / "
              f"{st.n_bytes / 1e6:.1f} MB ({st.filter_kind} filters)")

    sess = FlashClusterSession(cluster, cfg)
    target = docs[4321]
    qi = np.full((1, cfg.max_query_nnz), -1, np.int32)
    qv = np.zeros((1, cfg.max_query_nnz), np.float32)
    for j, (w, c) in enumerate(target[1]):
        qi[0, j] = w
        qv[0, j] = c

    res = sess.search(qi, qv)
    st = sess.last_stats
    print(f"\nnarrow query (doc {target[0]}'s topic): scored "
          f"{st.segments_scored}/{st.segments_total} segments across "
          f"{sess.store.n_shards} shards, aggregate skip rate "
          f"{st.skip_rate:.2f}")
    for rank, (d, s) in enumerate(zip(res.doc_ids[0], res.scores[0])):
        print(f"  #{rank + 1}: doc {d}  cosine {s:.4f}")
    assert res.doc_ids[0, 0] == target[0]

    # -- kill the owning shard's primary replica mid-run ----------------
    owner = int(cluster.partitioner.shard_of([target[0]])[0])
    victim = sess.router._session(owner, 0)
    shutil.rmtree(victim.store.root)             # the slice "dies"
    victim.store.manifest["segments"] = [        # poison the cached handle
        {**e, "name": "gone-" + e["name"]}
        for e in victim.store.manifest["segments"]]
    print(f"\nkilled shard {owner} replica 0; re-running the query...")
    res2 = sess.search(qi, qv)
    st = sess.last_stats
    print(f"  failovers {st.failovers}, replica health "
          f"{sess.router.health()[owner]}")
    np.testing.assert_array_equal(res2.doc_ids, res.doc_ids)
    np.testing.assert_array_equal(res2.scores, res.scores)
    print("OK: identical top-k with one replica dead")

    sess.close()
    shutil.rmtree(os.path.dirname(root), ignore_errors=True)


if __name__ == "__main__":
    main()
