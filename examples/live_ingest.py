"""Live ingestion: documents appended while a reader loops, then online
compaction shrinking the segment count under that same reader
(DESIGN.md §6).

A writer thread appends 3,000 documents one at a time through the
WAL -> memtable -> delta-segment pipeline while the main thread keeps
searching. Every search sees an atomic snapshot — watch the visible doc
count only ever grow while the delta segments pile up — then one
compaction folds the pile into full segments, shrinking the segment
count without perturbing the reader. The finale proves the differential
contract: the live store's top-k is bit-identical to a from-scratch
store built over the same documents.

    PYTHONPATH=src python examples/live_ingest.py
"""
import os
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.storage import FlashSearchSession, FlashStore


def main():
    cfg = SearchConfig(name="live-demo", vocab_size=20_000,
                       avg_nnz_per_doc=40, nnz_pad=64, top_k=5)
    n_base, n_live = 2_000, 3_000
    rng = np.random.default_rng(0)

    def make_doc(i):
        words = rng.choice(cfg.vocab_size, cfg.avg_nnz_per_doc,
                           replace=False)
        return (i, sorted((int(w), int(rng.integers(1, 30)))
                          for w in words))

    docs = [make_doc(i) for i in range(n_base + n_live)]

    tmp = tempfile.mkdtemp()
    store = FlashStore.create(os.path.join(tmp, "live"),
                              vocab_size=cfg.vocab_size,
                              docs_per_segment=500)
    store.append_docs(docs[:n_base])
    sess = FlashSearchSession(store, cfg)
    # auto_compact=False so the delta segments pile up visibly and the
    # fold below has something to show; production leaves the background
    # compactor on and never sees the pile
    sess.enable_ingest(seal_docs=200, fold_min_segments=4,
                       auto_compact=False)
    print(f"base store: {store.n_segments} segments, {store.n_docs} docs; "
          f"writer will append {n_live} more while we search")

    target = docs[n_base + n_live - 1]       # the very last live doc
    qi = np.full((1, cfg.max_query_nnz), -1, np.int32)
    qv = np.zeros((1, cfg.max_query_nnz), np.float32)
    for j, (w, c) in enumerate(target[1]):
        qi[0, j] = w
        qv[0, j] = c
    sess.search(qi, qv)                      # compile before the race

    done = threading.Event()

    def writer():
        for d, p in docs[n_base:]:
            sess.append(d, p)
            time.sleep(0)                    # yield to the reader
        done.set()

    threading.Thread(target=writer, daemon=True).start()

    # -- reader loop: snapshots only ever grow -------------------------
    seen = 0
    while not done.is_set():
        sess.search(qi, qv)
        st = sess.last_stats
        assert st.docs_scored >= seen, "snapshot went backwards!"
        seen = st.docs_scored
        print(f"  search saw {st.docs_scored:5d} docs "
              f"({st.segments_total} segments, "
              f"{st.memtable_docs} still in memtable)")
        time.sleep(0.15)

    res = sess.search(qi, qv)
    print(f"\nwriter done: top hit doc {res.doc_ids[0, 0]} "
          f"(expected {target[0]}) from "
          f"{sess.last_stats.docs_scored} docs")
    assert res.doc_ids[0, 0] == target[0]

    # -- compaction shrinks the segment count under the reader ---------
    before = store.n_segments
    sess.flush_ingest()                      # seal the tail...
    while sess.ingest.compact_once():        # ...and fold to full segments
        pass
    print(f"compaction: {before} segments -> {store.n_segments} "
          f"(docs unchanged: {store.n_docs})")
    assert store.n_segments < before

    # -- differential finale: bit-identical to a from-scratch store ----
    ref_store = FlashStore.create(os.path.join(tmp, "ref"),
                                  vocab_size=cfg.vocab_size,
                                  docs_per_segment=500)
    ref_store.append_docs(docs)
    with FlashSearchSession(ref_store, cfg) as ref:
        want = ref.search(qi, qv)
    got = sess.search(qi, qv)
    np.testing.assert_array_equal(got.doc_ids, want.doc_ids)
    np.testing.assert_array_equal(got.scores, want.scores)
    print("OK: live store top-k == from-scratch store top-k, bit for bit")

    sess.close()
    shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
