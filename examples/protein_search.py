"""Protein pre-filter search (paper §II.B, Fig. 6): encode sequences as
3-mer bags-of-words and find candidate homologs for a mutated query —
the BLAST-prefilter use case the paper demonstrates on UniProt TrEMBL.

    PYTHONPATH=src python examples/protein_search.py
"""
import dataclasses

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx


def mutate(seq: str, rng, n_mut: int) -> str:
    s = list(seq)
    for _ in range(n_mut):
        i = rng.integers(len(s))
        s[i] = corpus_lib.AMINO[rng.integers(20)]
    return "".join(s)


def main():
    rng = np.random.default_rng(7)
    print("generating 2000 synthetic protein sequences...")
    seqs = ["".join(rng.choice(list(corpus_lib.AMINO), rng.integers(80, 300)))
            for _ in range(2000)]
    corpus = corpus_lib.proteins_corpus(seqs, nnz_pad=256)
    cfg = dataclasses.replace(
        SearchConfig(name="protein", top_k=5), vocab_size=8000)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                              backend="jnp")

    target = 321
    query_seq = mutate(seqs[target], rng, n_mut=6)   # a diverged homolog
    bow = corpus_lib.protein_to_bow(query_seq)
    qi = np.full(cfg.max_query_nnz, -1, np.int32)
    qv = np.zeros(cfg.max_query_nnz, np.float32)
    qi[:len(bow)] = [w for w, _ in bow]
    qv[:len(bow)] = [c for _, c in bow]

    res = eng.search(qi[None], qv[None])
    print(f"query: protein {target} with 6 point mutations")
    for rank, (d, s) in enumerate(zip(res.doc_ids[0], res.scores[0])):
        mark = "  <-- true homolog" if d == target else ""
        print(f"  #{rank + 1}: protein {d}  cosine {s:.4f}{mark}")
    assert res.doc_ids[0, 0] == target, "prefilter missed the homolog"
    print("OK: 3-mer prefilter recovered the mutated homolog "
          "(search space reduced 2000 -> 5 for the exact aligner)")


if __name__ == "__main__":
    main()
