"""Quickstart: build a corpus, search it, check the answer. ~10 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx


def main():
    cfg = SearchConfig(name="quickstart", vocab_size=50_000,
                       avg_nnz_per_doc=60, nnz_pad=64, top_k=5)
    print("synthesizing 20k documents (paper §IV.A synthesizer)...")
    corpus = corpus_lib.synthesize(20_000, cfg.vocab_size,
                                   cfg.avg_nnz_per_doc, cfg.nnz_pad, seed=0)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                              backend="jnp")

    # query = document 1234 itself -> it must be the top hit with cos=1
    qi, qv = corpus_lib.make_query(corpus, 1234, cfg.max_query_nnz)
    res = eng.search(qi[None], qv[None])
    print("query: document 1234")
    for rank, (d, s) in enumerate(zip(res.doc_ids[0], res.scores[0])):
        print(f"  #{rank + 1}: doc {d}  cosine {s:.4f}")
    assert res.doc_ids[0, 0] == 1234
    print("OK: self-search returned itself (cosine = 1)")


if __name__ == "__main__":
    main()
