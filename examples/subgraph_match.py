"""Subgraph matching (paper §II.B, Fig. 5): edges become 'words' of their
vertex labels; similar subgraphs share edge vocabulary.

    PYTHONPATH=src python examples/subgraph_match.py
"""
import dataclasses

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx

N_LABELS = 128


def main():
    rng = np.random.default_rng(3)
    print("generating 500 random labeled subgraphs...")
    graphs = []
    for _ in range(500):
        n_edges = rng.integers(10, 40)
        graphs.append([(int(rng.integers(N_LABELS)),
                        int(rng.integers(N_LABELS)))
                       for _ in range(n_edges)])
    corpus = corpus_lib.subgraphs_corpus(graphs, n_labels=N_LABELS,
                                         nnz_pad=64)
    cfg = dataclasses.replace(SearchConfig(name="subgraph", top_k=5),
                              vocab_size=N_LABELS * N_LABELS)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                              backend="jnp")

    # query: graph 42 with 3 edges rewired (a noisy motif)
    target = 42
    g = list(graphs[target])
    for _ in range(3):
        g[rng.integers(len(g))] = (int(rng.integers(N_LABELS)),
                                   int(rng.integers(N_LABELS)))
    bow = corpus_lib.subgraph_to_bow(g, N_LABELS)
    qi = np.full(cfg.max_query_nnz, -1, np.int32)
    qv = np.zeros(cfg.max_query_nnz, np.float32)
    qi[:len(bow)] = [w for w, _ in bow]
    qv[:len(bow)] = [c for _, c in bow]

    res = eng.search(qi[None], qv[None])
    print(f"query: subgraph {target} with 3 rewired edges")
    for rank, (d, s) in enumerate(zip(res.doc_ids[0], res.scores[0])):
        mark = "  <-- source graph" if d == target else ""
        print(f"  #{rank + 1}: graph {d}  cosine {s:.4f}{mark}")
    assert res.doc_ids[0, 0] == target
    print("OK: noisy motif matched to its source subgraph")


if __name__ == "__main__":
    main()
