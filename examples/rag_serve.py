"""Retrieval-augmented serving: the paper's document-search engine feeding
an LM decoder — the integration point of the sparse pattern processor with
the assigned architectures (DESIGN.md §8).

A query is scored against the sharded corpus (in-storage search), the top
document's tokens are prepended as context, and the LM generates a
continuation.

    PYTHONPATH=src python examples/rag_serve.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.paper_search import SearchConfig
from repro.configs.registry import get_smoke_config
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.models import model as M
from repro.serve.step import generate


def main():
    ctx = single_device_ctx()

    # 1. the retrieval layer: sparse pattern search over a corpus
    scfg = SearchConfig(name="rag", vocab_size=256, avg_nnz_per_doc=12,
                        nnz_pad=16, top_k=3, block_docs=16, block_query=32)
    corpus = corpus_lib.synthesize(512, scfg.vocab_size,
                                   scfg.avg_nnz_per_doc, scfg.nnz_pad,
                                   seed=0)
    engine = PatternSearchEngine(corpus, scfg, ctx, backend="jnp")

    # 2. the generator: a (smoke-scale) qwen3 decoder
    cfg = get_smoke_config("qwen3-4b")
    params = M.init(jax.random.PRNGKey(0), cfg)

    # 3. retrieve-then-generate
    qi, qv = corpus_lib.make_query(corpus, 77, scfg.max_query_nnz)
    res = engine.search(qi[None], qv[None])
    top_doc = int(res.doc_ids[0, 0])
    print(f"retrieved doc {top_doc} (cosine {res.scores[0, 0]:.3f})")

    # context = the retrieved doc's word ids as tokens (toy tokenization)
    doc_ids = corpus.ids[top_doc]
    context = doc_ids[doc_ids >= 0][:12] % cfg.vocab_size
    prompt = np.concatenate([context, [1, 2, 3]])[None].astype(np.int32)
    out = generate(params, cfg, ctx, jnp.asarray(prompt), max_new=8,
                   max_len=prompt.shape[1] + 8)
    print("prompt tokens:  ", prompt[0].tolist())
    print("generated tokens:", np.asarray(out)[0].tolist())
    assert out.shape == (1, 8)
    print("OK: retrieval-augmented generation ran end to end")


if __name__ == "__main__":
    main()
