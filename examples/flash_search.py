"""Flash-tier search: a store bigger than the resident slab budget,
searched end-to-end through filter pruning + background prefetch.

Builds a FlashStore of 40k documents across 20 segments (clustered by
topic vocabulary band), then runs (1) a broad query that streams every
surviving segment through the double-buffered prefetcher, (2) a
narrow single-topic query that the per-segment vocabulary filter prunes
to one segment — the paper's in-storage filtering win, at store scope —
and (3) the broad query again, now warm: every surviving segment is
served from the device slab cache (DESIGN.md §4.2), skipping disk,
decode, and upload, bit-identical to the cold pass.

    PYTHONPATH=src python examples/flash_search.py
"""
import os
import shutil
import tempfile

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.storage import FlashSearchSession, FlashStore


def main():
    cfg = SearchConfig(name="flash-demo", vocab_size=50_000,
                       avg_nnz_per_doc=40, nnz_pad=64, top_k=5)
    n_docs, n_topics, per_segment = 40_000, 20, 2_000
    band = cfg.vocab_size // n_topics

    rng = np.random.default_rng(0)
    print(f"encoding {n_docs} documents into a segment store "
          f"({n_docs // per_segment} segments, Fig. 8 stream format)...")
    docs = []
    for i in range(n_docs):
        topic = (i * n_topics) // n_docs
        words = rng.choice(np.arange(topic * band, (topic + 1) * band),
                           cfg.avg_nnz_per_doc, replace=False)
        docs.append((i, sorted((int(w), int(rng.integers(1, 30)))
                               for w in words)))

    root = os.path.join(tempfile.mkdtemp(), "store")
    store = FlashStore.create(root, vocab_size=cfg.vocab_size,
                              docs_per_segment=per_segment)
    store.append_docs(docs)
    mb = sum(seg.nbytes for seg in store.segments()) / 1e6
    print(f"store: {store.n_segments} segments, {store.n_docs} docs, "
          f"{mb:.1f} MB on disk")

    # resident budget = one segment's slab; the session streams the rest
    sess = FlashSearchSession(store, cfg)

    # -- broad query: words from several topics -> most segments score --
    target = docs[17]
    qi = np.full((1, cfg.max_query_nnz), -1, np.int32)
    qv = np.zeros((1, cfg.max_query_nnz), np.float32)
    for j, (w, c) in enumerate(target[1]):
        qi[0, j] = w
        qv[0, j] = c
    extra = rng.choice(cfg.vocab_size, 64, replace=False)
    qi[0, len(target[1]):len(target[1]) + 64] = np.sort(extra).astype(np.int32)
    qv[0, len(target[1]):len(target[1]) + 64] = 0.01
    res = sess.search(qi, qv)
    st = sess.last_stats
    print(f"\nbroad query: scored {st.segments_scored}/{st.segments_total} "
          f"segments ({st.docs_scored} docs), skip rate {st.skip_rate:.2f}")
    for rank, (d, s) in enumerate(zip(res.doc_ids[0], res.scores[0])):
        print(f"  #{rank + 1}: doc {d}  cosine {s:.4f}")
    assert res.doc_ids[0, 0] == target[0]

    # -- narrow query: one topic's words -> the filter prunes the rest --
    qi2 = np.full((1, cfg.max_query_nnz), -1, np.int32)
    qv2 = np.zeros((1, cfg.max_query_nnz), np.float32)
    for j, (w, c) in enumerate(target[1]):
        qi2[0, j] = w
        qv2[0, j] = c
    res2 = sess.search(qi2, qv2)
    st = sess.last_stats
    print(f"\nnarrow query: scored {st.segments_scored}/{st.segments_total} "
          f"segments ({st.docs_scored} docs), skip rate {st.skip_rate:.2f}")
    print(f"  top hit: doc {res2.doc_ids[0, 0]} "
          f"cosine {res2.scores[0, 0]:.4f}")
    assert res2.doc_ids[0, 0] == target[0]
    assert st.segments_skipped >= 1
    print("\nOK: identical top hit, "
          f"{st.segments_skipped} segments never left storage")

    # -- broad query again, warm: slabs come from the device cache -----
    import time
    t0 = time.perf_counter()
    res3 = sess.search(qi, qv)
    warm_ms = (time.perf_counter() - t0) * 1e3
    st = sess.last_stats
    print(f"\nwarm broad query: {st.cache_hits}/{st.segments_scored} "
          f"slabs from cache (hit rate {st.cache_hit_rate:.2f}) "
          f"in {warm_ms:.1f} ms")
    np.testing.assert_array_equal(res3.doc_ids, res.doc_ids)
    np.testing.assert_array_equal(res3.scores, res.scores)
    print("OK: warm result bit-identical to cold")

    sess.close()
    shutil.rmtree(os.path.dirname(root), ignore_errors=True)


if __name__ == "__main__":
    main()
