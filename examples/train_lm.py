"""End-to-end training driver (assignment: "train a ~100M model for a few
hundred steps"): trains the qwen2-0.5b *smoke-scaled-up* config on the
synthetic pipeline with checkpointing; restartable by re-running.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig, OptimizerConfig, TrainConfig
from repro.distributed.meshctx import single_device_ctx
from repro.train.loop import Trainer


def small_lm() -> ModelConfig:
    """~10M-param dense LM (CPU-trainable in minutes; scale d_model/layers
    up for the real thing — same code path as the 256-chip config)."""
    return ModelConfig(
        name="example-lm-10m", family="dense",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=1024, vocab_size=8192, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_lm")
    ap.add_argument("--int8-opt", action="store_true")
    args = ap.parse_args()

    tc = TrainConfig(
        model=small_lm(),
        opt=OptimizerConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps,
                            int8_states=args.int8_opt),
        seq_len=128, global_batch=8, checkpoint_every=50,
        checkpoint_dir=args.ckpt_dir, seed=0)
    trainer = Trainer(tc, single_device_ctx())
    trainer.install_preemption_hook()
    metrics = trainer.run(args.steps)
    print(f"final: {metrics}")


if __name__ == "__main__":
    main()
