"""Recurrent-query memo cache (DESIGN.md §15.3).

"Leveraging Recurrent Patterns in Graph Accelerators" (PAPERS.md) makes
the case this module implements: real query streams repeat, and the
cheapest query is the one whose *answer* is already in hand. The memo
cache sits one level above the SlabCache — where the slab cache
memoizes decoded segment data keyed by (store, segment, shape), the
memo cache memoizes whole search results keyed by a normalized query
fingerprint plus everything that could change the answer:

    (cache_token, generation, memtable key, slab fmt,
     top_k, mode, candidates, query fingerprint)

Invalidation mirrors the slab cache's generation discipline, but
structurally: the store generation and the memtable fingerprint are
*part of the key*, so a seal/compaction/append bump makes every stale
entry unreachable the instant it happens — there is no window in which
a result from the old view can be served against the new one. Dead
generations age out of the bounded LRU; ``drop_store`` purges a closing
store's entries eagerly.

The fingerprint is order- and padding-insensitive: a query row hashes
its valid (id, value) pairs in sorted order, so the same logical query
arriving with different pad widths or pair orderings hits the same
entry (results are identical — scoring is a sum over pairs).
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Hashable, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class MemoStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    drops: int = 0          # entries purged by drop_store
    entries: int = 0


def query_fingerprint(q_ids: np.ndarray, q_vals: np.ndarray) -> str:
    """Canonical digest of a query batch [L, Qn] (pad < 0): per row,
    the valid (id, value) pairs sorted by (id, value) — two encodings
    of the same logical query always collide, two different queries
    practically never do (blake2b-128)."""
    q_ids = np.atleast_2d(np.asarray(q_ids))
    q_vals = np.atleast_2d(np.asarray(q_vals))
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(q_ids.shape[0]).tobytes())
    for r in range(q_ids.shape[0]):
        ids = q_ids[r].astype(np.int64)
        vals = q_vals[r].astype(np.float32)
        keep = ids >= 0
        ids, vals = ids[keep], vals[keep]
        order = np.lexsort((vals, ids))
        h.update(b"\x00row")
        h.update(ids[order].tobytes())
        h.update(vals[order].tobytes())
    return h.hexdigest()


def memo_key(cache_token: Hashable, memo_state: Tuple, fmt: str,
             top_k: int, mode: str, candidates: int,
             q_ids: np.ndarray, q_vals: np.ndarray) -> Tuple:
    """Full result key. ``memo_state`` is the view's
    ``(generation, memtable key)`` — see FlashStore.memo_state /
    Snapshot.memo_state — which is what makes cross-generation serving
    structurally impossible rather than merely checked."""
    return (cache_token, memo_state, fmt, int(top_k), mode,
            int(candidates), query_fingerprint(q_ids, q_vals))


class MemoCache:
    """Thread-safe bounded LRU: fingerprint key -> (result, stats)."""

    def __init__(self, max_entries: int = 256):
        if max_entries <= 0:
            raise ValueError("max_entries must be positive")
        self.max_entries = max_entries
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Tuple, object]" = OrderedDict()
        self._stats = MemoStats()

    def get(self, key: Tuple):
        with self._lock:
            hit = self._entries.get(key)
            if hit is None:
                self._stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self._stats.hits += 1
            return hit

    def put(self, key: Tuple, value) -> int:
        """Insert (idempotent on re-insert). Returns evictions."""
        ev = 0
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self._entries[key] = value
                return 0
            self._entries[key] = value
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                ev += 1
            self._stats.evictions += ev
            self._stats.entries = len(self._entries)
        return ev

    def drop_store(self, cache_token: Hashable) -> int:
        """Purge every entry of one store (session close)."""
        with self._lock:
            dead = [k for k in self._entries if k[0] == cache_token]
            for k in dead:
                del self._entries[k]
            self._stats.drops += len(dead)
            self._stats.entries = len(self._entries)
            return len(dead)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats_snapshot(self) -> MemoStats:
        with self._lock:
            return dataclasses.replace(self._stats,
                                       entries=len(self._entries))
