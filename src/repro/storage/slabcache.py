"""SlabCache — byte-budgeted LRU of decoded, device-resident slabs
(DESIGN.md §4.2).

The paper's accelerator keeps hot data next to the compute; the host
analogue is keeping a hot segment's *decoded* form — the `DeviceSlab`
the engine scores — resident across queries, so a cache hit skips the
disk read, the ELL decode, and the `device_put` entirely. Keys are
``(store token, segment name, nnz_pad, slab_docs)``:

- the **store token** is unique per live `FlashStore` instance, so a
  reopened (possibly crash-recovered) store can never alias a previous
  instance's entries even if segment names were reused on disk;
- segment files are immutable and segment ids monotonic within one
  store instance (§3.1), so a keyed entry can never go stale;
- ``nnz_pad`` / ``slab_docs`` pin the decode and the padded program
  shape — a store whose largest segment grows simply misses and
  re-decodes at the new shape.

Entries carry the slab's truncation count and decoded doc count so a
warm query reports the exact `SearchStats` a cold one would.
Invalidation is precise: manifest mutations call ``invalidate`` with
the replaced segment names (see ``FlashStore.bump_generation``).
Eviction is LRU under a byte budget; an item larger than the whole
budget is scored but never admitted. All methods are thread-safe —
prefetcher workers and shard-router threads share one instance.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Hashable, Iterable, NamedTuple, Optional, Tuple

DEFAULT_CACHE_BYTES = 256 * 1024 * 1024


class CachedSlab(NamedTuple):
    """One decoded segment: the device-resident slab plus the decode
    metadata a warm query must still report (bit-identical stats)."""
    slab: object          # engine.DeviceSlab
    n_docs: int           # decoded (pre-padding) document rows
    n_trunc: int          # pairs truncated by nnz_pad at decode time
    nbytes: int           # device footprint charged to the budget


@dataclasses.dataclass
class CacheStats:
    """Lifetime counters (process scope, across every sharer)."""
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


def slab_nbytes(slab) -> int:
    """Device footprint of a DeviceSlab (sum of its array buffers)."""
    return sum(int(np_like.size) * int(np_like.dtype.itemsize)
               for np_like in slab)


# (store token, name, nnz_pad, slab_docs, slab fmt)
Key = Tuple[Hashable, str, int, int, str]


def slab_key(token: Hashable, name: str, nnz_pad: int,
             slab_docs: int, fmt: str = "ell") -> Key:
    """The one cache-key constructor — planner peeks and executor
    get/puts must key identically or every planned hit silently
    degrades to a miss. ``fmt`` is the engine's slab layout
    (``engine.slab_fmt``): an ELL DeviceSlab and a fused PackedSlab of
    the same segment are different device objects and must never alias
    (the fused fmt also carries its doc-tile side, since re-tiling
    changes the layout)."""
    return (token, name, nnz_pad, slab_docs, fmt)


class SlabCache:
    def __init__(self, max_bytes: int = DEFAULT_CACHE_BYTES):
        if max_bytes < 0:
            raise ValueError("max_bytes must be >= 0")
        self.max_bytes = max_bytes
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Key, CachedSlab]" = OrderedDict()
        self._bytes = 0
        self.stats = CacheStats()

    @classmethod
    def resolve(cls, slab_cache: "Optional[SlabCache]",
                cache_bytes: Optional[int]) -> "Optional[SlabCache]":
        """The one knob ladder every session tier uses: an explicit
        ``slab_cache`` is shared as-is; otherwise ``cache_bytes`` sizes
        a private cache (None = default budget, 0 = disabled)."""
        if slab_cache is not None:
            return slab_cache
        if cache_bytes is None:
            return cls()
        return cls(cache_bytes) if cache_bytes > 0 else None

    # -- introspection -------------------------------------------------
    @property
    def nbytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Key) -> bool:
        return self.peek(key)

    def keys(self):
        with self._lock:
            return list(self._entries)

    def stats_snapshot(self) -> CacheStats:
        """A point-in-time copy of the lifetime counters, taken under
        the cache lock. ``cache_stats`` surfaces must return this, not
        the live ``stats`` object: a lock-free read of the mutating
        dataclass can pair a ``hits`` from one moment with a ``misses``
        from another, so ``hit_rate`` mid-flight was not any state the
        cache ever held."""
        with self._lock:
            return dataclasses.replace(self.stats)

    # -- read path -----------------------------------------------------
    def peek(self, key: Key) -> bool:
        """Membership without touching LRU order or hit/miss counters —
        the Planner's verdict probe (the executor's ``get`` is what
        counts, so planned-but-evicted entries surface as misses)."""
        with self._lock:
            return key in self._entries

    def get(self, key: Key) -> Optional[CachedSlab]:
        with self._lock:
            hit = self._entries.get(key)
            if hit is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return hit

    # -- write path ----------------------------------------------------
    def put(self, key: Key, slab, *, n_docs: int, n_trunc: int,
            admit=None) -> int:
        """Admit one decoded slab, evicting LRU entries to fit the byte
        budget. Returns how many entries were evicted. A slab larger
        than the whole budget is not admitted (returns 0).

        ``admit`` (a zero-arg callable) is evaluated *under the cache
        lock*: because ``invalidate`` also runs under it, a guard like
        the executor's generation check cannot race a concurrent
        invalidation — either the guard already sees the bumped
        generation (skip), or the entry lands before the invalidate
        acquires the lock and is dropped by it."""
        nbytes = slab_nbytes(slab)
        evicted = 0
        with self._lock:
            if admit is not None and not admit():
                return 0
            if nbytes > self.max_bytes:
                return 0
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old.nbytes
            while self._entries and self._bytes + nbytes > self.max_bytes:
                _, dead = self._entries.popitem(last=False)
                self._bytes -= dead.nbytes
                evicted += 1
            self._entries[key] = CachedSlab(slab, int(n_docs),
                                            int(n_trunc), nbytes)
            self._bytes += nbytes
            self.stats.evictions += evicted
        return evicted

    # -- invalidation --------------------------------------------------
    def invalidate(self, token: Hashable, names: Iterable[str]) -> int:
        """Drop the entries of ``names`` for one store instance — the
        precise set a manifest mutation (fold/compact) replaced. A live
        snapshot that still scores a replaced file reloads it from the
        graveyard (a miss, never a wrong answer)."""
        names = set(names)
        dropped = 0
        with self._lock:
            for key in [k for k in self._entries
                        if k[0] == token and k[1] in names]:
                self._bytes -= self._entries.pop(key).nbytes
                dropped += 1
            self.stats.invalidations += dropped
        return dropped

    def drop_store(self, token: Hashable) -> int:
        """Drop every entry of one store instance (session close —
        nothing will ever key on this token again)."""
        dropped = 0
        with self._lock:
            for key in [k for k in self._entries if k[0] == token]:
                self._bytes -= self._entries.pop(key).nbytes
                dropped += 1
        return dropped

    def clear(self):
        """Empty the cache (benchmarks' cold-start lever). Lifetime
        counters are preserved."""
        with self._lock:
            self._entries.clear()
            self._bytes = 0
