"""Query planning and the shared plan executor (DESIGN.md §4.1).

Every scoring surface — single store, live memtable snapshot, sharded
cluster, micro-batched service — used to hand-roll the same implicit
scan: walk the manifest, filter, read + decode each survivor from
disk. This module makes that plan *explicit* and single-sourced:

    Planner.plan(view, q_ids[, snap])  ->  QueryPlan
    execute_plan(engine, view, plan, q_ids, q_vals, ...) -> SearchResult

A ``view`` duck-types the segment surface (``entries`` / ``segment`` /
``release`` / ``cache_token`` — a FlashStore or an ingest Snapshot).
The plan records one verdict per manifest segment (skip via the §3.2
vocabulary filter, or scan), the slab source for each survivor
(``cache``: already decoded + device-resident in the §4.2 SlabCache;
``disk``: mmap read -> ELL decode -> device_put), the memtable tail
when the view is a live snapshot, and the padded program shape. Steps
are ordered cache-first so the prefetcher thread overlaps every disk
decode behind the free cache hits.

The executor is the only scan loop in the tree: it streams the plan's
steps through the §3.3 Prefetcher, scores each slab as it lands, and
folds the per-slab candidates in *manifest rank order* (memtable last)
so the scan-order optimization can never change score-tie breaking
relative to a cold scan. The cache is consulted at *execution* time (a
planned hit that was evicted in between simply degrades to a disk load
— plans are advisory about sources, never about correctness), and one
``SearchStats`` is filled, including the cache hit/miss/eviction
counters.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.core import stream_format
from repro.core.corpus import Corpus
from repro.core.engine import _merge_results, _next_pow2
from repro.obs import NULL_REGISTRY, NULL_SPAN
from repro.storage import filter as filter_lib
from repro.storage import postings as postings_lib
from repro.storage.prefetch import Prefetcher
from repro.storage.slabcache import SlabCache, slab_key

SOURCE_CACHE = "cache"
SOURCE_DISK = "disk"

MODE_EXACT = "exact"
MODE_APPROX = "approx"
MODE_AUTO = "auto"
MODES = (MODE_EXACT, MODE_APPROX, MODE_AUTO)
# "auto" takes the approximate tier only past this many snapshot docs:
# below it the exhaustive scan is already a handful of slabs and the
# posting traversal would cost more than it saves
DEFAULT_APPROX_MIN_DOCS = 4096


@dataclasses.dataclass(frozen=True)
class PlanStep:
    """One surviving segment in scan order. ``rank`` is its position in
    *manifest* order among the scored segments — the executor folds
    results by rank, so the cache-first scan order can never change the
    merge's tie-breaking relative to a cold manifest-order scan."""
    name: str
    n_docs: int
    source: str            # SOURCE_CACHE | SOURCE_DISK (advisory)
    rank: int              # manifest-order fold position


@dataclasses.dataclass
class QueryPlan:
    """Explicit per-query scan plan over one snapshot view."""
    steps: List[PlanStep]              # cache-first scan order
    skipped: List[str]                 # filter-pruned segment names
    segments_total: int
    slab_docs: int                     # padded program shape (§3.3)
    nnz_pad: int
    cache_token: object                # store identity for cache keys
    generation: int = 0                # generation the view's segment
                                       # list belongs to (capture-time
                                       # for snapshots): admission is
                                       # skipped once the live one
                                       # moves (see execute_plan)
    memtable: Optional[Corpus] = None  # live tail (unpadded), or None
    memtable_trunc: int = 0
    memtable_pad: int = 0              # doubling pad target for the tail
    fmt: str = "ell"                   # engine slab layout (§12.2):
                                       # "ell" or "fused:<block_docs>"
    mode: str = MODE_EXACT             # resolved per query: exact scans
                                       # every surviving slab; approx
                                       # takes the posting-candidate +
                                       # re-rank path per disk segment
    candidates: int = 0                # top-C pool size per segment row
                                       # (approx mode only)
    filtered: bool = False             # vocab-filter pruning ran — the
                                       # executor may attribute zero-
                                       # score survivors to filter FPs

    def key_for(self, name: str):
        return slab_key(self.cache_token, name, self.nnz_pad,
                        self.slab_docs, self.fmt)

    @property
    def n_cached(self) -> int:
        return sum(s.source == SOURCE_CACHE for s in self.steps)

    @property
    def n_disk(self) -> int:
        return sum(s.source == SOURCE_DISK for s in self.steps)

    @property
    def is_empty(self) -> bool:
        return not self.steps and self.memtable is None


class Planner:
    """Turns (snapshot view, query batch) into a QueryPlan. Stateless
    beyond its knobs, so one instance serves every query of a session."""

    def __init__(self, *, nnz_pad: int, rows: int, use_filter: bool = True,
                 cache: Optional[SlabCache] = None, fmt: str = "ell",
                 mode: str = MODE_EXACT, candidates: int = 0,
                 approx_min_docs: int = DEFAULT_APPROX_MIN_DOCS):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.nnz_pad = nnz_pad
        self.rows = rows                # mesh rows the slab pad aligns to
        self.use_filter = use_filter
        self.cache = cache
        self.fmt = fmt                  # the engine's slab_fmt: cache
                                        # verdicts must probe the same
                                        # keys the executor will load
        self.mode = mode                # session default; plan() takes a
                                        # per-query override
        self.candidates = candidates    # default top-C pool per segment
        self.approx_min_docs = approx_min_docs

    def plan(self, view, q_ids: np.ndarray, snap=None, *,
             mode: Optional[str] = None,
             candidates: Optional[int] = None) -> QueryPlan:
        """``snap`` carries the memtable when ``view`` is a live
        Snapshot (the session passes the same object twice). ``mode`` /
        ``candidates`` override the session defaults for this query
        (the QueryOptions knobs); ``auto`` resolves against the view's
        total doc count here, where the manifest is already in hand."""
        entries = view.entries
        rows = self.rows
        slab_docs = -(-max(view.max_segment_docs, 1) // rows) * rows
        token = view.cache_token
        eff_mode = self.mode if mode is None else mode
        if eff_mode not in MODES:
            raise ValueError(
                f"mode must be one of {MODES}, got {eff_mode!r}")
        eff_cand = self.candidates if candidates is None else int(candidates)
        if eff_mode == MODE_AUTO:
            total_docs = sum(e.n_docs for e in entries)
            eff_mode = (MODE_APPROX if total_docs >= self.approx_min_docs
                        else MODE_EXACT)
        if eff_mode == MODE_APPROX and eff_cand <= 0:
            raise ValueError("approx mode needs a positive candidate "
                             "pool size (candidates)")
        # the query's probe state (dedup + splitmix64 mixes) is computed
        # ONCE here and reused for every segment verdict below — the
        # per-segment cost is a bitmap gather or a Bloom modulo only
        probe = filter_lib.QueryProbe(q_ids) if self.use_filter else None
        do_filter = probe is not None and probe.ids.size > 0
        cached: List[PlanStep] = []
        disk: List[PlanStep] = []
        skipped: List[str] = []
        # one segment handle held at a time: a skipped segment costs its
        # footer + filter pages, a survivor is reopened lazily by the
        # executor's loader (snapshot entries stay openable — the
        # pipeline defers GC while the snapshot lives)
        rank = 0
        for entry in entries:
            if do_filter:
                seg = view.segment(entry.name)
                hit_any = seg.vocab_filter.contains_any_probe(probe)
                view.release(entry.name)
                if not hit_any:
                    skipped.append(entry.name)
                    continue
            key = slab_key(token, entry.name, self.nnz_pad, slab_docs,
                           self.fmt)
            step = PlanStep(
                entry.name, entry.n_docs,
                SOURCE_CACHE if self.cache is not None
                and self.cache.peek(key) else SOURCE_DISK, rank)
            rank += 1
            (cached if step.source == SOURCE_CACHE else disk).append(step)
        mem_corpus, mem_trunc = (snap.memtable_corpus(self.nnz_pad)
                                 if snap is not None else (None, 0))
        mem_pad = 0
        if mem_corpus is not None:
            # reuse the segment program shape whenever the memtable fits;
            # a memtable that outgrows it pads to the next *doubling* so
            # interleaved append/search compiles O(log) shapes (§3.4)
            mem_pad = slab_docs
            while mem_pad < mem_corpus.n_docs:
                mem_pad *= 2
        return QueryPlan(steps=cached + disk, skipped=skipped,
                         segments_total=len(entries), slab_docs=slab_docs,
                         nnz_pad=self.nnz_pad, cache_token=token,
                         generation=view.generation,
                         memtable=mem_corpus, memtable_trunc=mem_trunc,
                         memtable_pad=mem_pad, fmt=self.fmt,
                         mode=eff_mode, candidates=eff_cand,
                         filtered=do_filter)


def execute_plan(engine, view, plan: QueryPlan, q_ids: np.ndarray,
                 q_vals: np.ndarray, *, stats,
                 cache: Optional[SlabCache] = None,
                 prefetch_depth: int = 2, span=NULL_SPAN,
                 registry=None):
    """Run one QueryPlan: prefetch + score its slab stream, mutating
    ``stats`` (a SearchStats) as slabs resolve. The shared scan loop
    behind every scoring surface (DESIGN.md §4.1).

    Slabs are *scored* in the plan's cache-first scan order (so the
    prefetcher overlaps disk decodes behind the free hits) but their
    per-slab candidates are *folded* in manifest rank order, memtable
    last — exactly the cold scan's fold. ``_merge_results`` breaks
    score ties by fold position, so without the rank fold a partially
    warm query could flip tied candidates relative to a cold one.

    ``span``/``registry`` are the §8 observability hooks: per-segment
    child spans (slab source, decode/upload ms) hang off ``span`` when
    a trace sampled this query (``NULL_SPAN`` otherwise — allocation-
    free), and stage latencies land in the registry's ``stage_ms``
    histograms. Neither touches the numeric path: scan order, fold
    order, and every array op are identical with observability on,
    off, or disabled."""
    reg = NULL_REGISTRY if registry is None else registry
    h_decode = reg.histogram("stage_ms", stage="decode")
    h_upload = reg.histogram("stage_ms", stage="upload")
    h_score = reg.histogram("stage_ms", stage="score")
    # the Obs.disabled() floor (§8.1): with a null registry AND no trace
    # span, every perf_counter() read below is dead weight — skip them
    # all, so the disabled path costs zero clock syscalls per slab
    timed = not (reg is NULL_REGISTRY and span is NULL_SPAN)

    def load(step: PlanStep):
        """Prefetch-thread body: cache lookup, else mmap read -> ELL
        decode -> device upload (+ admission). At most ``prefetch_depth``
        segments are open during the scoring stream."""
        lspan = span.child("load", segment=step.name, rank=step.rank)
        if cache is not None:
            hit = cache.get(plan.key_for(step.name))
            if hit is not None:
                stats.cache_hits += 1
                stats.docs_scored += hit.n_docs
                stats.pairs_truncated += hit.n_trunc
                lspan.end(source=SOURCE_CACHE)
                return step, hit.slab
            stats.cache_misses += 1
        t0 = time.perf_counter() if timed else 0.0
        seg = view.segment(step.name)
        if plan.mode == MODE_APPROX and seg.postings is not None:
            # approximate tier (§15): posting traversal picks the top-C
            # candidate pool, then ONLY those rows are decoded (page-
            # level partial decode) and re-ranked exactly through the
            # session backend. The mini-slab is keyed by the query, so
            # it is never admitted to the slab cache; a pre-postings
            # segment file (postings is None) falls through to the
            # exhaustive branch below.
            pool = seg.postings.candidates(q_ids, q_vals, plan.candidates)
            doc_ids, ids, vals, norms, n_trunc = postings_lib.gather_rows(
                seg, pool, plan.nnz_pad)
            view.release(step.name)
            t1 = time.perf_counter() if timed else 0.0
            n_docs = int(doc_ids.size)
            stats.docs_scored += n_docs
            stats.pairs_truncated += n_trunc
            stats.approx_segments += 1
            stats.candidates += n_docs
            if n_docs == 0:
                lspan.end(source=SOURCE_DISK, approx=True, candidates=0)
                return step, None
            # pow2 pad capped at the plan shape: candidate pools of any
            # size compile O(log slab_docs) distinct programs
            corpus = Corpus(doc_ids, ids, vals, norms).pad_docs_to(
                min(plan.slab_docs, _next_pow2(n_docs)))
            slab = engine.put_slab(corpus)
            t2 = time.perf_counter() if timed else 0.0
            if timed:
                h_decode.observe((t1 - t0) * 1e3)
                h_upload.observe((t2 - t1) * 1e3)
                lspan.end(source=SOURCE_DISK, approx=True,
                          candidates=n_docs,
                          decode_ms=round((t1 - t0) * 1e3, 3),
                          upload_ms=round((t2 - t1) * 1e3, 3))
            return step, slab
        if plan.fmt.startswith("fused"):
            # the fused kernel decodes the Fig. 8 words on-device: the
            # segment stream is only *tiled* here (a boundary-index
            # pass), never staged through host ELL arrays (§12.2). The
            # mmap view stays open until the tiles are built — tiling
            # copies, so the segment can be released right after.
            slab, n_docs, n_trunc = engine.put_stream_slab(
                seg.stream(), pad_docs_to=plan.slab_docs)
            view.release(step.name)
            t1 = t2 = time.perf_counter() if timed else 0.0
            stats.docs_scored += n_docs
            stats.pairs_truncated += n_trunc
        else:
            doc_ids, ids, vals, norms, n_trunc = stream_format.decode_to_ell(
                seg.stream(), plan.nnz_pad)
            view.release(step.name)
            t1 = time.perf_counter() if timed else 0.0
            n_docs = int(doc_ids.size)
            stats.docs_scored += n_docs
            stats.pairs_truncated += n_trunc
            corpus = Corpus(doc_ids, ids, vals, norms)
            slab = engine.put_slab(corpus.pad_docs_to(plan.slab_docs))
            t2 = time.perf_counter() if timed else 0.0
        if timed:
            h_decode.observe((t1 - t0) * 1e3)
            h_upload.observe((t2 - t1) * 1e3)
        # admission is gated on the LIVE store generation still matching
        # the generation the plan's segment list was captured at: once a
        # fold/compact has moved it, this segment may be a graveyard
        # file a snapshot is straggling over — admitting it would undo
        # the precise invalidation and squat in the budget. The guard
        # runs under the cache lock (see SlabCache.put) so it cannot
        # race the fold's invalidate.
        if cache is not None:
            stats.cache_evictions += cache.put(
                plan.key_for(step.name), slab,
                n_docs=n_docs, n_trunc=n_trunc,
                admit=lambda: view.live_generation == plan.generation)
        if timed:
            lspan.end(source=SOURCE_DISK,
                      decode_ms=round((t1 - t0) * 1e3, 3),
                      upload_ms=round((t2 - t1) * 1e3, 3))
        return step, slab

    if plan.is_empty:
        span.set(empty=True)
        return engine.empty_result(q_ids.shape[0])
    # one fold slot per scored segment in manifest order, + the memtable
    folds: List[Optional[object]] = [None] * (len(plan.steps) + 1)
    mem_slab = None
    if plan.memtable is not None:
        # stats land BEFORE the prefetcher (and its loader thread)
        # exists: += on shared counters from two threads would race
        stats.memtable_docs = plan.memtable.n_docs
        stats.docs_scored += plan.memtable.n_docs
        stats.pairs_truncated += plan.memtable_trunc
        mem_slab = plan.memtable.pad_docs_to(plan.memtable_pad)
    pf = Prefetcher(plan.steps, load, depth=prefetch_depth,
                    timed=timed) \
        if plan.steps else None
    try:
        if mem_slab is not None:
            # scored while the prefetcher's worker loads the first slabs
            sspan = span.child("score", segment="memtable")
            t0 = time.perf_counter() if timed else 0.0
            folds[-1] = engine.search_streaming(q_ids, q_vals, [mem_slab])
            if timed:
                h_score.observe((time.perf_counter() - t0) * 1e3)
            sspan.end(source="memtable", docs=stats.memtable_docs)
        if pf is not None:
            for step, slab in pf:
                if slab is None:        # empty approx candidate pool
                    continue
                sspan = span.child("score", segment=step.name,
                                   rank=step.rank)
                t0 = time.perf_counter() if timed else 0.0
                r = engine.search_streaming(q_ids, q_vals, [slab])
                folds[step.rank] = r
                # a segment the vocab filter let through whose every
                # real score is exactly 0 had no query-term overlap:
                # a filter false positive (exact for bitmaps, the
                # Bloom FPR made flesh) — surfaced per query so the
                # fleet can see when a filter has gone saturated
                if plan.filtered:
                    sc = np.asarray(r.scores)
                    fin = sc[np.isfinite(sc)]
                    if fin.size == 0 or not np.any(fin != 0):
                        stats.filter_fp_segments += 1
                if timed:
                    h_score.observe((time.perf_counter() - t0) * 1e3)
                sspan.end()
    finally:
        if pf is not None:
            pf.close()
    if pf is not None and timed:
        wait_ms = pf.consumer_wait_s * 1e3
        reg.histogram("stage_ms", stage="prefetch_wait").observe(wait_ms)
        span.set(prefetch_wait_ms=round(wait_ms, 3))
    mspan = span.child("merge")
    t0 = time.perf_counter() if timed else 0.0
    best = None
    for r in folds:
        if r is None:
            continue
        best = r if best is None else _merge_results(best, r,
                                                     engine.cfg.top_k)
    if timed:
        reg.histogram("stage_ms", stage="merge").observe(
            (time.perf_counter() - t0) * 1e3)
    mspan.end(folds=sum(r is not None for r in folds))
    return best
