"""FlashSearchSession — end-to-end search over a FlashStore (DESIGN.md §3.4).

Wires the storage tier into the engine the way the paper wires flash
slices into accelerator kernels:

    FlashStore segments
        -> vocabulary-filter pruning   (in-storage pattern filter, §3.2)
        -> Prefetcher background thread (read + decode + device_put, §3.3)
        -> PatternSearchEngine.search_streaming (score + merge top-k)

Every surviving segment becomes one fixed-shape DeviceSlab (padded to the
store's largest segment rounded up to the mesh rows) so the whole stream
reuses a single compiled program. ``last_stats`` reports how much the
filter pruned — the skip-rate is the storage tier's headline metric.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.core import stream_format
from repro.core.corpus import Corpus
from repro.core.engine import DeviceSlab, PatternSearchEngine, SearchResult
from repro.distributed.meshctx import MeshCtx, single_device_ctx
from repro.serve.session_surface import ServingSessionMixin
from repro.storage.prefetch import Prefetcher
from repro.storage.store import FlashStore


@dataclasses.dataclass
class SearchStats:
    segments_total: int = 0
    segments_skipped: int = 0
    segments_scored: int = 0
    docs_scored: int = 0
    pairs_truncated: int = 0

    @property
    def skip_rate(self) -> float:
        return (self.segments_skipped / self.segments_total
                if self.segments_total else 0.0)


class FlashSearchSession(ServingSessionMixin):
    def __init__(self, store: FlashStore, cfg: SearchConfig,
                 ctx: Optional[MeshCtx] = None, backend: str = "jnp",
                 use_filter: bool = True, prefetch_depth: int = 2):
        self.store = store
        self.cfg = cfg
        self.ctx = ctx or single_device_ctx()
        self.use_filter = use_filter
        self.prefetch_depth = prefetch_depth
        if store.vocab_size > cfg.vocab_size:
            # same invariant the resident engine constructor enforces:
            # out-of-range word ids would silently scatter out of bounds
            raise ValueError(
                f"store vocab_size {store.vocab_size} exceeds "
                f"cfg.vocab_size {cfg.vocab_size}")
        self.engine = PatternSearchEngine(None, cfg, self.ctx, backend)
        self.last_stats = SearchStats()
        # one program shape for every slab: largest segment, mesh-aligned
        rows = self.ctx.dp_size
        self._slab_docs = -(-max(store.max_segment_docs, 1) // rows) * rows
        self._init_serving()

    # ------------------------------------------------------------------
    def search(self, q_ids: np.ndarray, q_vals: np.ndarray) -> SearchResult:
        """q_ids/q_vals: [L, Qn] (pad < 0) -> global top-k over the store."""
        stats = SearchStats(segments_total=self.store.n_segments)
        # segments appended since construction may have grown the slab shape
        rows = self.ctx.dp_size
        self._slab_docs = -(-max(self.store.max_segment_docs, 1)
                            // rows) * rows
        q_words = np.unique(q_ids[q_ids >= 0])
        survivors = []
        # one segment open at a time: a skipped segment costs its footer +
        # filter pages and the handle is dropped immediately
        for entry in self.store.entries:
            seg = self.store.segment(entry.name)
            if (self.use_filter and q_words.size
                    and not seg.vocab_filter.contains_any(q_words)):
                stats.segments_skipped += 1
                self.store.release(entry.name)
                continue
            survivors.append(entry.name)
            self.store.release(entry.name)
        stats.segments_scored = len(survivors)
        self.last_stats = stats
        if not survivors:
            return self.engine.empty_result(q_ids.shape[0])
        with Prefetcher(survivors, self._load_slab,
                        depth=self.prefetch_depth) as slabs:
            result = self.engine.search_streaming(q_ids, q_vals, slabs)
        return result

    # ------------------------------------------------------------------
    def _load_slab(self, name: str) -> DeviceSlab:
        """Prefetch-thread body: mmap read -> ELL decode -> device upload.
        The segment handle is released once decoded, so at most
        ``prefetch_depth`` segments are open during the scoring stream."""
        seg = self.store.segment(name)
        doc_ids, ids, vals, norms, n_trunc = stream_format.decode_to_ell(
            seg.stream(), self.cfg.nnz_pad)
        self.store.release(name)
        self.last_stats.docs_scored += int(doc_ids.size)
        self.last_stats.pairs_truncated += n_trunc
        corpus = Corpus(doc_ids, ids, vals, norms).pad_docs_to(self._slab_docs)
        return self.engine.put_slab(corpus)

    def _close_resources(self):
        # service/submit/close lifecycle comes from ServingSessionMixin
        self.store.close()
