"""FlashSearchSession — end-to-end search over a FlashStore (DESIGN.md §3.4).

Wires the storage tier into the engine the way the paper wires flash
slices into accelerator kernels:

    FlashStore segments
        -> vocabulary-filter pruning   (in-storage pattern filter, §3.2)
        -> Prefetcher background thread (read + decode + device_put, §3.3)
        -> PatternSearchEngine.search_streaming (score + merge top-k)

Every surviving segment becomes one fixed-shape DeviceSlab (padded to the
store's largest segment rounded up to the mesh rows) so the whole stream
reuses a single compiled program. ``last_stats`` reports how much the
filter pruned — the skip-rate is the storage tier's headline metric.

With ``enable_ingest()`` the session also becomes a *live* writer
surface (DESIGN.md §5): ``append`` routes documents through a
write-ahead log + memtable, and every search scores an atomic snapshot
— the manifest segments, sealed deltas, and memtable captured at the
moment the query (or its coalesced batch) starts scoring — so results
are bit-identical to a from-scratch store holding the same documents,
and background seals/compactions never perturb an in-flight query.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.core import stream_format
from repro.core.corpus import Corpus
from repro.core.engine import DeviceSlab, PatternSearchEngine, SearchResult
from repro.distributed.meshctx import MeshCtx, single_device_ctx
from repro.serve.session_surface import ServingSessionMixin
from repro.storage.prefetch import Prefetcher
from repro.storage.store import FlashStore


@dataclasses.dataclass
class SearchStats:
    segments_total: int = 0
    segments_skipped: int = 0
    segments_scored: int = 0
    docs_scored: int = 0
    pairs_truncated: int = 0
    memtable_docs: int = 0     # of docs_scored, how many came from the
                               # live memtable (0 without ingest)

    @property
    def skip_rate(self) -> float:
        return (self.segments_skipped / self.segments_total
                if self.segments_total else 0.0)


class FlashSearchSession(ServingSessionMixin):
    def __init__(self, store: FlashStore, cfg: SearchConfig,
                 ctx: Optional[MeshCtx] = None, backend: str = "jnp",
                 use_filter: bool = True, prefetch_depth: int = 2):
        self.store = store
        self.cfg = cfg
        self.ctx = ctx or single_device_ctx()
        self.use_filter = use_filter
        self.prefetch_depth = prefetch_depth
        if store.vocab_size > cfg.vocab_size:
            # same invariant the resident engine constructor enforces:
            # out-of-range word ids would silently scatter out of bounds
            raise ValueError(
                f"store vocab_size {store.vocab_size} exceeds "
                f"cfg.vocab_size {cfg.vocab_size}")
        self.engine = PatternSearchEngine(None, cfg, self.ctx, backend)
        self.last_stats = SearchStats()
        self._ingest = None
        # one program shape for every slab: largest segment, mesh-aligned
        rows = self.ctx.dp_size
        self._slab_docs = -(-max(store.max_segment_docs, 1) // rows) * rows
        self._init_serving()

    # -- live ingestion (DESIGN.md §5) ---------------------------------
    def enable_ingest(self, **knobs) -> "IngestPipeline":
        """Attach a write path (WAL + memtable + background compactor)
        to this session's store and replay any WAL tail a crash left
        behind. ``knobs`` are ``repro.ingest.IngestConfig`` fields.
        Idempotent; returns the pipeline."""
        from repro.ingest import IngestConfig, IngestPipeline
        if self._ingest is None:
            self._ingest = IngestPipeline(self.store, IngestConfig(**knobs))
        return self._ingest

    @property
    def ingest(self) -> Optional["IngestPipeline"]:
        return self._ingest

    def append(self, doc_id: int, pairs: Sequence[Tuple[int, int]]) -> int:
        """Durably append one document ([(word, count), ...]) to the live
        store; it is searchable by the next query. Requires
        ``enable_ingest()``. Returns the WAL sequence number."""
        if self._ingest is None:
            raise RuntimeError(
                "append() needs enable_ingest() first — the session is "
                "read-only until a write path is attached")
        return self._ingest.append(doc_id, pairs)

    def flush_ingest(self) -> int:
        """Seal the memtable into delta segments now (0 without ingest)."""
        return self._ingest.seal() if self._ingest is not None else 0

    # ------------------------------------------------------------------
    def search(self, q_ids: np.ndarray, q_vals: np.ndarray) -> SearchResult:
        """q_ids/q_vals: [L, Qn] (pad < 0) -> global top-k over the store
        (plus, with ingest enabled, the sealed deltas and memtable of an
        atomic snapshot taken now)."""
        if self._ingest is None:
            return self._search_view(self.store, None, q_ids, q_vals)
        snap = self._ingest.capture()
        try:
            return self._search_view(snap, snap, q_ids, q_vals)
        finally:
            snap.close()

    def _search_view(self, view, snap, q_ids: np.ndarray,
                     q_vals: np.ndarray) -> SearchResult:
        """Score one segment view. ``view`` duck-types the segment
        surface (``entries`` / ``segment`` / ``release`` — a FlashStore
        or an ingest Snapshot); ``snap`` carries the memtable when the
        view is a snapshot."""
        entries = view.entries
        stats = SearchStats(segments_total=len(entries))
        # segments appended since construction may have grown the slab shape
        rows = self.ctx.dp_size
        self._slab_docs = -(-max(view.max_segment_docs, 1) // rows) * rows
        q_words = np.unique(q_ids[q_ids >= 0])
        survivors = []
        # one segment handle held at a time on both paths: a skipped
        # segment costs its footer + filter pages, a survivor is
        # reopened lazily by the prefetch loader (snapshot entries stay
        # openable — the pipeline defers GC while the snapshot lives)
        for entry in entries:
            seg = view.segment(entry.name)
            if (self.use_filter and q_words.size
                    and not seg.vocab_filter.contains_any(q_words)):
                stats.segments_skipped += 1
                view.release(entry.name)
                continue
            survivors.append(entry.name)
            view.release(entry.name)
        stats.segments_scored = len(survivors)
        mem_corpus, mem_trunc = (snap.memtable_corpus(self.cfg.nnz_pad)
                                 if snap is not None else (None, 0))
        self.last_stats = stats
        if not survivors and mem_corpus is None:
            return self.engine.empty_result(q_ids.shape[0])
        mem_slab = None
        if mem_corpus is not None:
            stats.memtable_docs = mem_corpus.n_docs
            stats.docs_scored += mem_corpus.n_docs
            stats.pairs_truncated += mem_trunc
            # reuse the segment program shape whenever the memtable fits;
            # a memtable that outgrows it (seal_docs > largest segment)
            # pads to the next *doubling* so interleaved append/search
            # compiles O(log) shapes, not one per append
            pad = self._slab_docs
            while pad < mem_corpus.n_docs:
                pad *= 2
            mem_slab = mem_corpus.pad_docs_to(pad)
        pf = Prefetcher(survivors, lambda name: self._load_slab(view, name),
                        depth=self.prefetch_depth) if survivors else None
        try:
            slabs = self._chain_slabs(pf, mem_slab)
            result = self.engine.search_streaming(q_ids, q_vals, slabs)
        finally:
            if pf is not None:
                pf.close()
        return result

    @staticmethod
    def _chain_slabs(pf, mem_slab):
        if pf is not None:
            yield from pf
        if mem_slab is not None:
            yield mem_slab

    # ------------------------------------------------------------------
    def _load_slab(self, view, name: str) -> DeviceSlab:
        """Prefetch-thread body: mmap read -> ELL decode -> device upload.
        The segment handle is released once decoded, so at most
        ``prefetch_depth`` segments are open during the scoring stream."""
        seg = view.segment(name)
        doc_ids, ids, vals, norms, n_trunc = stream_format.decode_to_ell(
            seg.stream(), self.cfg.nnz_pad)
        view.release(name)
        self.last_stats.docs_scored += int(doc_ids.size)
        self.last_stats.pairs_truncated += n_trunc
        corpus = Corpus(doc_ids, ids, vals, norms).pad_docs_to(self._slab_docs)
        return self.engine.put_slab(corpus)

    def _close_resources(self):
        # service/submit/close lifecycle comes from ServingSessionMixin
        if self._ingest is not None:
            self._ingest.close()
            self._ingest = None
        self.store.close()
