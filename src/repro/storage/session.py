"""FlashSearchSession — end-to-end search over a FlashStore (DESIGN.md §3.4).

Wires the storage tier into the engine the way the paper wires flash
slices into accelerator kernels:

    FlashStore segments
        -> Planner: filter verdicts + slab sources  (§4.1)
        -> execute_plan: SlabCache hits (§4.2) + Prefetcher disk
           decodes (§3.3), cache-first scan order
        -> PatternSearchEngine.search_streaming (score + merge top-k)

Every surviving segment becomes one fixed-shape DeviceSlab (padded to the
store's largest segment rounded up to the mesh rows) so the whole stream
reuses a single compiled program. Hot segments stay decoded and
device-resident in the byte-budgeted slab cache, so steady-state
queries skip the disk read, the ELL decode, and the upload entirely —
warm results are bit-identical to cold ones. ``last_stats`` reports how
much the filter pruned (the skip-rate is the storage tier's headline
metric) plus the cache hit/miss/eviction counters.

With ``enable_ingest()`` the session also becomes a *live* writer
surface (DESIGN.md §6): ``append`` routes documents through a
write-ahead log + memtable, and every search scores an atomic snapshot
— the manifest segments, sealed deltas, and memtable captured at the
moment the query (or its coalesced batch) starts scoring — so results
are bit-identical to a from-scratch store holding the same documents,
and background seals/compactions never perturb an in-flight query.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.core.engine import PatternSearchEngine, SearchResult
from repro.distributed.meshctx import MeshCtx, single_device_ctx
from repro.obs import NULL_REGISTRY, NULL_SPAN, Obs, default_obs
from repro.serve.api import (Query, QueryOptions, QueryStats, SearchResponse,
                             coerce_request, truncate_k)
from repro.serve.session_surface import ServingSessionMixin
from repro.storage.memo import MemoCache, MemoStats, memo_key
from repro.storage.plan import (DEFAULT_APPROX_MIN_DOCS, MODE_EXACT,
                                Planner, execute_plan)
from repro.storage.slabcache import CacheStats, SlabCache
from repro.storage.store import FlashStore


@dataclasses.dataclass
class SearchStats:
    segments_total: int = 0
    segments_skipped: int = 0
    segments_scored: int = 0
    docs_scored: int = 0
    pairs_truncated: int = 0
    memtable_docs: int = 0     # of docs_scored, how many came from the
                               # live memtable (0 without ingest)
    cache_hits: int = 0        # slab-cache counters for this query
    cache_misses: int = 0      # (DESIGN.md §4.2); all zero when the
    cache_evictions: int = 0   # cache is disabled
    filter_fp_segments: int = 0  # scored segments with zero overlap —
                               # the vocab filter passed them anyway
                               # (Bloom false positives made visible)
    approx_segments: int = 0   # segments scored via the posting-
                               # candidate + exact-re-rank tier (§15)
    candidates: int = 0        # candidate docs gathered across them
    memo_hits: int = 0         # 1 when this result came from the
                               # recurrent-query memo cache

    @property
    def skip_rate(self) -> float:
        return ((self.segments_skipped or 0) / self.segments_total
                if self.segments_total else 0.0)

    @property
    def cache_hit_rate(self) -> float:
        # hardened against both the zero-slab query (every segment
        # filter-skipped: zero probes -> 0.0, never a ZeroDivisionError)
        # and None-valued fields from a shard that reported partial
        # stats (e.g. its cache disabled) — see also ClusterStats._sum
        hits = self.cache_hits or 0
        probes = hits + (self.cache_misses or 0)
        return hits / probes if probes else 0.0


class FlashSearchSession(ServingSessionMixin):
    def __init__(self, store: FlashStore, cfg: SearchConfig,
                 ctx: Optional[MeshCtx] = None, backend: str = "jnp",
                 use_filter: bool = True, prefetch_depth: int = 2,
                 slab_cache: Optional[SlabCache] = None,
                 cache_bytes: Optional[int] = None,
                 obs: Optional[Obs] = None,
                 mode: str = MODE_EXACT, candidates: int = 0,
                 approx_min_docs: int = DEFAULT_APPROX_MIN_DOCS,
                 memo: Optional[MemoCache] = None, memo_entries: int = 0):
        """``slab_cache`` shares an existing cache (the cluster router
        passes one per-cluster instance); otherwise ``cache_bytes``
        sizes a private one (None = default budget, 0 = disabled).
        ``obs`` shares an observability bundle (DESIGN.md §8); None
        falls back to the process-wide ``default_obs()``.

        ``mode`` picks the session-default scoring tier (§15):
        ``exact`` (the default — every path bit-identical to the
        pre-approx repo), ``approx`` (posting-candidate + exact
        re-rank), or ``auto`` (approx once the view holds at least
        ``approx_min_docs`` docs). ``candidates`` is the default
        per-segment top-C pool (0 = 4 * cfg.top_k). A per-query
        ``QueryOptions.mode/candidates/recall_target`` overrides both.
        ``memo``/``memo_entries`` attach the recurrent-query memo cache
        (shared instance wins; entries > 0 sizes a private one; the
        default is off)."""
        self.store = store
        self.cfg = cfg
        self.ctx = ctx or single_device_ctx()
        self.use_filter = use_filter
        self.prefetch_depth = prefetch_depth
        self.obs = obs if obs is not None else default_obs()
        if store.vocab_size > cfg.vocab_size:
            # same invariant the resident engine constructor enforces:
            # out-of-range word ids would silently scatter out of bounds
            raise ValueError(
                f"store vocab_size {store.vocab_size} exceeds "
                f"cfg.vocab_size {cfg.vocab_size}")
        self.engine = PatternSearchEngine(None, cfg, self.ctx, backend,
                                          obs=self.obs)
        self.slab_cache = SlabCache.resolve(slab_cache, cache_bytes)
        if self.slab_cache is not None:
            store.register_cache(self.slab_cache)
        self._planner = Planner(nnz_pad=cfg.nnz_pad, rows=self.ctx.dp_size,
                                use_filter=use_filter, cache=self.slab_cache,
                                fmt=self.engine.slab_fmt, mode=mode,
                                candidates=(candidates if candidates > 0
                                            else 4 * cfg.top_k),
                                approx_min_docs=approx_min_docs)
        self._memo = memo if memo is not None else (
            MemoCache(memo_entries) if memo_entries > 0 else None)
        self.last_stats = SearchStats()
        self._ingest = None
        # one program shape for every slab: largest segment, mesh-aligned
        rows = self.ctx.dp_size
        self._slab_docs = -(-max(store.max_segment_docs, 1) // rows) * rows
        self._init_serving()

    # -- live ingestion (DESIGN.md §6) ---------------------------------
    def enable_ingest(self, **knobs) -> "IngestPipeline":
        """Attach a write path (WAL + memtable + background compactor)
        to this session's store and replay any WAL tail a crash left
        behind. ``knobs`` are ``repro.ingest.IngestConfig`` fields.
        Idempotent; returns the pipeline."""
        from repro.ingest import IngestConfig, IngestPipeline
        if self._ingest is None:
            self._ingest = IngestPipeline(self.store, IngestConfig(**knobs),
                                          obs=self.obs)
        return self._ingest

    @property
    def ingest(self) -> Optional["IngestPipeline"]:
        return self._ingest

    def append(self, doc_id: int, pairs: Sequence[Tuple[int, int]]) -> int:
        """Durably append one document ([(word, count), ...]) to the live
        store; it is searchable by the next query. Requires
        ``enable_ingest()``. Returns the WAL sequence number."""
        if self._ingest is None:
            raise RuntimeError(
                "append() needs enable_ingest() first — the session is "
                "read-only until a write path is attached")
        return self._ingest.append(doc_id, pairs)

    def flush_ingest(self) -> int:
        """Seal the memtable into delta segments now (0 without ingest)."""
        return self._ingest.seal() if self._ingest is not None else 0

    # ------------------------------------------------------------------
    def search(self, query, q_vals=None, *,
               options: Optional[QueryOptions] = None, _span=None):
        """Public search surface. Typed form — ``search(Query(ids,
        vals), options=QueryOptions(...))`` — returns a
        ``SearchResponse``; positional ``search(q_ids, q_vals)`` arrays
        remain as a deprecation shim returning the bare
        ``SearchResult`` (repro/serve/api.py). A single store has no
        shards to gather, so of the scheduling options only ``k``
        applies here; deadlines act in the coalescing service's queue
        (serve/batcher.py)."""
        q, options = coerce_request(query, q_vals, options,
                                    surface="FlashSearchSession.search")
        res = self.search_typed(q, options=options, _span=_span)
        if options is None:
            return res
        return SearchResponse(truncate_k(res, options.k), QueryStats(
            deadline_ms=options.deadline_ms, tenant=options.tenant))

    def search_typed(self, query: Query,
                     options: Optional[QueryOptions] = None, *,
                     _span=None) -> SearchResult:
        """Query rows [L, Qn] (pad < 0) -> global top-k over the store
        (plus, with ingest enabled, the sealed deltas and memtable of an
        atomic snapshot taken now). Always returns the raw
        ``SearchResult`` — wrapping/truncation belong to the public
        ``search`` shim.

        ``_span`` is the observability hook for nesting callers (the
        cluster router hands each shard session a child span of the
        cluster trace): when set, this query joins the parent's trace
        and the parent owns the query-level accounting."""
        q_ids, q_vals = query.rows()
        # the wall clock only matters when this call owns the query-level
        # accounting AND the bundle is live (Obs.disabled() floor: zero
        # clock reads on the whole path, asserted by test_obs_disabled)
        timed = self.obs.enabled and _span is None
        t0 = time.perf_counter() if timed else 0.0
        trace = None
        if _span is None:
            trace = self.obs.tracer.start("query", surface="store",
                                          L=int(q_ids.shape[0]))
            span = trace.root if trace is not None else NULL_SPAN
        else:
            span = _span
        mode, cand = self._query_knobs(options)
        try:
            if self._ingest is None:
                res = self._memo_or_search(self.store, None, q_ids, q_vals,
                                           span, mode, cand)
            else:
                snap = self._ingest.capture()
                try:
                    res = self._memo_or_search(snap, snap, q_ids, q_vals,
                                               span, mode, cand)
                finally:
                    snap.close()
        except BaseException:
            if _span is None:
                # the availability-SLO bad-event stream (§8.4); nested
                # calls leave the error to the router's cluster counter
                self.obs.registry.counter(
                    "query_errors_total", surface="store").inc()
            raise
        finally:
            if trace is not None:
                trace.finish()
        if timed:
            # nested (per-shard) calls skip this: the router publishes
            # the cluster aggregate, so counting here would double it
            st = self.last_stats
            self.obs.note_query(
                "store", (time.perf_counter() - t0) * 1e3,
                segments_scored=st.segments_scored,
                segments_skipped=st.segments_skipped,
                cache_hits=st.cache_hits, docs_scored=st.docs_scored)
            self.obs.publish_search_stats(st, surface="store")
        return res

    def _query_knobs(self, options: Optional[QueryOptions]):
        """Resolve the per-query (mode, candidates) overrides; None
        means the session (Planner) default applies. A bare
        ``recall_target`` maps to a pool multiplier — the closer to
        1.0, the wider the candidate pool the posting tier keeps."""
        mode = options.mode if options is not None else None
        cand = options.candidates if options is not None else None
        if (cand is None and options is not None
                and options.recall_target is not None):
            mult = max(4.0, 2.0 / max(1.0 - options.recall_target, 0.01))
            cand = int(np.ceil(self.cfg.top_k * mult))
        return mode, cand

    def _memo_or_search(self, view, snap, q_ids, q_vals, span,
                        mode, cand) -> SearchResult:
        """Memo-cache wrapper around ``_search_view`` (§15.3). The key
        is derived from the *captured* view's memo_state — generation
        and memtable fingerprint frozen under the snapshot lock — so a
        concurrent append/seal can never alias a stale entry onto the
        new view; the bumped state is simply a different key."""
        memo = self._memo
        if memo is None:
            return self._search_view(view, snap, q_ids, q_vals, span,
                                     mode=mode, candidates=cand)
        eff_mode = mode if mode is not None else self._planner.mode
        eff_cand = cand if cand is not None else self._planner.candidates
        key = memo_key(view.cache_token, view.memo_state,
                       self.engine.slab_fmt, self.cfg.top_k,
                       eff_mode, eff_cand, q_ids, q_vals)
        hit = memo.get(key)
        if hit is not None:
            res, st = hit
            self.last_stats = dataclasses.replace(st, memo_hits=1)
            span.set(memo_hit=True)
            return res
        res = self._search_view(view, snap, q_ids, q_vals, span,
                                mode=mode, candidates=cand)
        memo.put(key, (res, dataclasses.replace(self.last_stats)))
        return res

    def _search_view(self, view, snap, q_ids: np.ndarray,
                     q_vals: np.ndarray, span=NULL_SPAN, *,
                     mode=None, candidates=None) -> SearchResult:
        """Score one segment view (a FlashStore or an ingest Snapshot;
        ``snap`` carries the memtable when the view is a snapshot):
        plan, then run the shared executor (DESIGN.md §4.1)."""
        reg = self.obs.registry
        timed = not (reg is NULL_REGISTRY and span is NULL_SPAN)
        pspan = span.child("plan")
        t0 = time.perf_counter() if timed else 0.0
        plan = self._planner.plan(view, q_ids, snap, mode=mode,
                                  candidates=candidates)
        if timed:
            reg.histogram("stage_ms", stage="plan").observe(
                (time.perf_counter() - t0) * 1e3)
        pspan.end(segments_total=plan.segments_total,
                  skipped=len(plan.skipped), cached=plan.n_cached,
                  disk=plan.n_disk,
                  skipped_names=plan.skipped[:16])
        self._slab_docs = plan.slab_docs
        stats = SearchStats(segments_total=plan.segments_total,
                            segments_skipped=len(plan.skipped),
                            segments_scored=len(plan.steps))
        self.last_stats = stats
        return execute_plan(self.engine, view, plan, q_ids, q_vals,
                            stats=stats, cache=self.slab_cache,
                            prefetch_depth=self.prefetch_depth,
                            span=span, registry=reg)

    @property
    def cache_stats(self) -> Optional[CacheStats]:
        """A locked point-in-time snapshot of the lifetime slab-cache
        counters (shared across every sharer of the cache), or None when
        the cache is disabled. A snapshot, not the live object: the
        counters mutate under the cache lock mid-query, so a lock-free
        read could pair hits and misses from different moments."""
        return (self.slab_cache.stats_snapshot()
                if self.slab_cache is not None else None)

    @property
    def compile_stats(self) -> dict:
        """The engine's compile-cache telemetry, surfaced here so every
        search_serve target prints one consistent block (DESIGN.md §8.3)."""
        return self.engine.compile_stats

    @property
    def last_trace(self):
        """Most recent sampled QueryTrace (None unless the session's
        ``obs`` was built with ``trace_sample`` > 0)."""
        return self.obs.tracer.last_trace

    @property
    def memo_stats(self) -> Optional[MemoStats]:
        """Lifetime memo-cache counters (None when the memo is off)."""
        return (self._memo.stats_snapshot()
                if self._memo is not None else None)

    def _close_resources(self):
        # service/submit/close lifecycle comes from ServingSessionMixin,
        # whose close() guarantees this runs at most once
        if self._memo is not None:
            self._memo.drop_store(self.store.cache_token)
        if self.slab_cache is not None:
            # drop the store's entries only when the *last* session
            # sharing this (store, cache) pair detaches — another live
            # session's warm set must not be wiped from under it
            if self.store.unregister_cache(self.slab_cache):
                self.slab_cache.drop_store(self.store.cache_token)
        if self._ingest is not None:
            self._ingest.close()
            self._ingest = None
        self.store.close()
