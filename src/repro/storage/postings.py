"""Per-segment inverted posting index — the approximate candidate tier
(DESIGN.md §15).

Every scoring path below this module is exhaustive-exact: a query pays
decode + correlate for every document of every segment the vocabulary
filter can't skip. SpANNS-style sparse search wins at scale by splitting
that into (1) cheap *candidate generation* near the data and (2) exact
re-ranking of a small pool. This module is phase 1: at segment-build
time the Fig. 8 stream is inverted into term -> (doc offset, quantized
weight) postings, stored in the segment file next to the vocabulary
filter; at query time an in-memory accumulator walks only the query
terms' posting lists and returns the per-segment top-C candidate pool.

On-disk layout (all little-endian uint32 words, Fig. 8 footer style —
the segment footer records ``{"off", "nbytes", "meta"}`` exactly like
the filter section):

    [n_terms | n_docs | n_postings | reserved]      4-word header
    [term_ids   u32 * n_terms]                      sorted, unique
    [offsets    u32 * (n_terms + 1)]                prefix sums
    [postings   u32 * n_postings]                   [doc_off:20 | w:12]
    [norms      f32 * n_docs]                       full-doc L2 norms
    [doc_starts u32 * (n_docs + 1)]                 item offset of each
                                                    doc's header in the
                                                    segment stream

``doc_starts`` is the gather side's row directory: a candidate doc
offset maps straight to its ``[start, end)`` item range in the Fig. 8
stream, so the re-rank reads and decodes *only the candidate
documents' bytes* — the in-storage "move only what matches" economy,
applied to the exact phase.

A posting packs the document's *offset within the segment* (20 bits —
bounded by ``MAX_SEGMENT_DOCS``, far above any docs_per_segment in use)
with the Fig. 8 12-bit saturating count, so one posting is one u32 and
the whole index is typically ~the stream's own size. Norms are stored
densely so the accumulator ranks by cosine-like score (dot / norm), the
same monotone ordering the exact path uses per query.

The candidate score is *approximate* in exactly two ways: counts
saturate at 4095 (as the stream itself does) and postings cover the
full document while the exact path scores rows truncated to
``nnz_pad`` — so the pool can miss a true winner, which is what the
recall@k axis (benchmarks/recall_bench.py) measures and the exact
re-rank stage (storage/plan.py) repairs for every candidate it does
contain.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core import stream_format

KIND = "postings1"
OFF_BITS = 32 - stream_format.VAL_BITS      # 20-bit doc offsets
MAX_SEGMENT_DOCS = 1 << OFF_BITS
_VAL_BITS = stream_format.VAL_BITS
_VAL_MASK = stream_format.VAL_MASK


class PostingIndex:
    """Inverted index over one segment: sorted unique ``term_ids`` with
    CSR-style ``offsets`` into the packed ``postings`` array, plus the
    per-document norms the accumulator divides by."""

    def __init__(self, term_ids: np.ndarray, offsets: np.ndarray,
                 postings: np.ndarray, norms: np.ndarray,
                 doc_starts: np.ndarray):
        self.term_ids = term_ids        # uint32 [n_terms], sorted
        self.offsets = offsets          # uint32 [n_terms + 1]
        self.postings = postings        # uint32 [n_postings]
        self.norms = norms              # float32 [n_docs]
        self.doc_starts = doc_starts    # uint32 [n_docs + 1], item offsets

    @property
    def n_terms(self) -> int:
        return int(self.term_ids.size)

    @property
    def n_docs(self) -> int:
        return int(self.norms.size)

    @property
    def n_postings(self) -> int:
        return int(self.postings.size)

    @property
    def nbytes(self) -> int:
        return 4 * (4 + self.n_terms + (self.n_terms + 1)
                    + self.n_postings + self.n_docs + (self.n_docs + 1))

    # -- build ---------------------------------------------------------
    @classmethod
    def build(cls, stream: np.ndarray) -> "PostingIndex":
        """Invert a Fig. 8 uint32 stream. One pass, fully vectorized:
        every pair item becomes one posting keyed by its word id and
        attributed to its document's offset within the stream."""
        stream = np.asarray(stream, np.uint32)
        is_hdr = (stream & stream_format.HEADER_BIT) != 0
        n_docs = int(is_hdr.sum())
        if n_docs > MAX_SEGMENT_DOCS:
            raise ValueError(
                f"segment has {n_docs} docs; postings pack doc offsets "
                f"into {OFF_BITS} bits (max {MAX_SEGMENT_DOCS})")
        if n_docs == 0:
            return cls(np.empty(0, np.uint32), np.zeros(1, np.uint32),
                       np.empty(0, np.uint32), np.empty(0, np.float32),
                       np.zeros(1, np.uint32))
        doc_starts = np.append(np.flatnonzero(is_hdr),
                               stream.size).astype(np.uint32)
        doc_of_item = np.cumsum(is_hdr) - 1     # doc offset per item
        pair_mask = ~is_hdr
        pairs = stream[pair_mask]
        doc_off = doc_of_item[pair_mask].astype(np.uint32)
        words = ((pairs >> _VAL_BITS) & np.uint32(stream_format.KEY_MASK))
        counts = pairs & np.uint32(_VAL_MASK)
        # group by term, documents ascending inside each group (stable)
        order = np.argsort(words, kind="stable")
        words = words[order]
        packed = (doc_off[order] << np.uint32(_VAL_BITS)) | counts[order]
        term_ids, starts = np.unique(words, return_index=True)
        offsets = np.append(starts, words.size).astype(np.uint32)
        cf = counts.astype(np.float64)
        norms = np.sqrt(np.bincount(doc_off.astype(np.int64), cf * cf,
                                    minlength=n_docs)).astype(np.float32)
        return cls(term_ids.astype(np.uint32), offsets, packed, norms,
                   doc_starts)

    # -- (de)serialization — the segment footer embeds meta + raw ------
    def to_bytes(self) -> bytes:
        hdr = np.asarray([self.n_terms, self.n_docs, self.n_postings, 0],
                         np.uint32)
        return b"".join(a.astype("<u4").tobytes() if a.dtype != np.float32
                        else a.astype("<f4").tobytes()
                        for a in (hdr, self.term_ids, self.offsets,
                                  self.postings, self.norms,
                                  self.doc_starts))

    def meta(self) -> Dict:
        return {"kind": KIND, "n_terms": self.n_terms,
                "n_docs": self.n_docs, "n_postings": self.n_postings}

    @classmethod
    def from_bytes(cls, meta: Dict, raw: bytes) -> "PostingIndex":
        if meta["kind"] != KIND:
            raise ValueError(f"unknown postings kind {meta['kind']!r}")
        words = np.frombuffer(raw, "<u4")
        n_terms, n_docs, n_postings = (int(words[0]), int(words[1]),
                                       int(words[2]))
        o = 4
        term_ids = words[o:o + n_terms].astype(np.uint32)
        o += n_terms
        offsets = words[o:o + n_terms + 1].astype(np.uint32)
        o += n_terms + 1
        postings = words[o:o + n_postings].astype(np.uint32)
        o += n_postings
        norms = np.frombuffer(raw, "<f4", count=n_docs,
                              offset=4 * o).astype(np.float32)
        o += n_docs
        doc_starts = words[o:o + n_docs + 1].astype(np.uint32)
        return cls(term_ids, offsets, postings, norms, doc_starts)

    # -- the accumulator -----------------------------------------------
    def candidates(self, q_ids: np.ndarray, q_vals: np.ndarray,
                   n_cand: int) -> np.ndarray:
        """Top-C candidate pool for one query batch ``[L, Qn]``
        (pad < 0): walk only the query terms' posting lists, accumulate
        ``sum(q_val * count) / doc_norm`` per (row, doc), take the
        top-``n_cand`` docs per row and return the union as *sorted*
        doc offsets — ascending segment order, so the re-rank mini-slab
        preserves the exact scan's within-segment tie-breaking for
        every doc in the pool.

        Zero-score docs are eligible (argpartition over the full score
        vector): the exact path ranks no-overlap docs at score 0 above
        the -inf filler, so a pool that simply dropped them could never
        reproduce the exhaustive result even at C = n_docs.
        """
        n_docs = self.n_docs
        if n_docs == 0:
            return np.empty(0, np.int64)
        n_cand = max(1, min(int(n_cand), n_docs))
        q_ids = np.atleast_2d(q_ids)
        q_vals = np.atleast_2d(q_vals)
        L = q_ids.shape[0]
        rows, cols = np.nonzero(q_ids >= 0)
        acc = np.zeros((L, n_docs), np.float32)
        if rows.size and self.n_terms:
            terms = q_ids[rows, cols].astype(np.uint32)
            tvals = q_vals[rows, cols].astype(np.float32)
            ti = np.searchsorted(self.term_ids, terms)
            ti_safe = np.minimum(ti, self.n_terms - 1)
            hit = self.term_ids[ti_safe] == terms
            if hit.any():
                ti = ti_safe[hit]
                starts = self.offsets[ti].astype(np.int64)
                lens = self.offsets[ti + 1].astype(np.int64) - starts
                # grouped arange: flat indices of every posting touched
                out_starts = np.cumsum(lens) - lens
                total = int(lens.sum())
                flat = (np.arange(total, dtype=np.int64)
                        - np.repeat(out_starts, lens)
                        + np.repeat(starts, lens))
                p = self.postings[flat]
                d = (p >> np.uint32(_VAL_BITS)).astype(np.int64)
                w = (p & np.uint32(_VAL_MASK)).astype(np.float32)
                np.add.at(acc, (np.repeat(rows[hit], lens), d),
                          np.repeat(tvals[hit], lens) * w)
        acc /= np.maximum(self.norms, np.float32(1e-12))[None, :]
        if n_cand >= n_docs:
            return np.arange(n_docs, dtype=np.int64)
        top = np.argpartition(-acc, n_cand - 1, axis=1)[:, :n_cand]
        return np.unique(top.reshape(-1)).astype(np.int64)


def gather_rows(seg, doc_offs: np.ndarray, nnz_pad: int
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                           np.ndarray, int]:
    """Phase 2's gather: read and decode *only the candidate documents'
    item ranges*. The posting index's ``doc_starts`` directory maps
    every candidate doc offset straight to its ``[start, end)`` slice
    of the mmap-backed Fig. 8 stream, so the OS faults in only the file
    pages those slices touch and the decoder never sees a non-candidate
    item. Documents decode independently (each carries its own header),
    so the concatenated sub-stream's rows are bit-identical to the same
    rows of a full-stream decode — the exact re-rank inherits exactness
    from that.

    Returns ``(doc_ids, ids, vals, norms, n_truncated)`` with
    ``n_truncated`` counted over the *selected* rows only (the stats a
    full scan would have attributed to these documents)."""
    doc_offs = np.asarray(doc_offs, np.int64)
    if doc_offs.size == 0:
        return (np.empty(0, np.int64),
                np.full((0, nnz_pad), -1, np.int32),
                np.zeros((0, nnz_pad), np.float32),
                np.zeros(0, np.float32), 0)
    bounds = seg.postings.doc_starts.astype(np.int64)
    starts = bounds[doc_offs]
    lens = bounds[doc_offs + 1] - starts          # items incl. header
    # grouped arange: flat item indices of every selected doc's range
    out_starts = np.cumsum(lens) - lens
    total = int(lens.sum())
    flat = (np.arange(total, dtype=np.int64)
            - np.repeat(out_starts, lens) + np.repeat(starts, lens))
    sub = seg.stream()[flat]
    doc_ids, ids, vals, norms, _ = stream_format.decode_to_ell(
        sub, nnz_pad)
    n_trunc = int(np.maximum((lens - 1) - nnz_pad, 0).sum())
    return doc_ids, ids, vals, norms, n_trunc
