"""Background slab prefetcher (DESIGN.md §3.3).

The paper hides flash latency behind compute with a prefetch predictor in
the flash interface logic; the host-scope analogue is a worker thread that
stays ``depth`` slabs ahead of the scoring loop: while the engine scores
segment i, the worker reads segment i+1 from disk (mmap page-in), decodes
it to ELL, and issues the async ``device_put``. A bounded queue provides
the double buffering — depth 2 means one slab being scored, one in flight
— and backpressure so host RAM holds at most ``depth`` decoded slabs no
matter how large the store is.

``Prefetcher`` is generic: ``items`` is any iterable, ``load`` maps an
item to the prefetched value (here: ``Segment`` -> ``DeviceSlab``).
Exceptions in the worker surface in the consumer at the failing item's
position; ``close()`` stops early without draining.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Generic, Iterable, Iterator, TypeVar

T = TypeVar("T")
U = TypeVar("U")

_DONE = object()


class _WorkerError:
    def __init__(self, exc: BaseException):
        self.exc = exc


class Prefetcher(Generic[T, U]):
    def __init__(self, items: Iterable[T], load: Callable[[T], U],
                 depth: int = 2, timed: bool = True):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._finished = False
        self._closed = False
        # timed=False is the Obs.disabled() floor: the blocking path
        # skips its perf_counter pair too, so a fully-disabled scan does
        # zero clock reads in this module (consumer_wait_s stays 0.0)
        self._timed = timed
        # seconds the consumer spent blocked waiting on the worker: the
        # overlap telemetry (DESIGN.md §8.2) — 0 means the prefetcher
        # fully hid the disk+decode latency behind scoring
        self.consumer_wait_s = 0.0
        self._worker = threading.Thread(
            target=self._run, args=(iter(items), load), daemon=True,
            name="slab-prefetch")
        self._worker.start()

    def _put(self, obj) -> bool:
        """Blocking put that aborts on close(); True if delivered."""
        while not self._stop.is_set():
            try:
                self._q.put(obj, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _run(self, it: Iterator[T], load: Callable[[T], U]):
        try:
            for item in it:
                if self._stop.is_set():
                    return
                if not self._put(load(item)):
                    return
            self._put(_DONE)
        except BaseException as e:  # surfaced at the consumer
            self._put(_WorkerError(e))

    def __iter__(self) -> Iterator[U]:
        return self

    def __next__(self) -> U:
        if self._finished:          # after _DONE or a worker error the
            raise StopIteration     # stream is over; never block again
        try:                        # fast path: slab already queued —
            v = self._q.get_nowait()   # no clock reads on full overlap
        except queue.Empty:
            if self._timed:
                t0 = time.perf_counter()
                v = self._q.get()
                self.consumer_wait_s += time.perf_counter() - t0
            else:
                v = self._q.get()
        if v is _DONE:
            self._finished = True
            raise StopIteration
        if isinstance(v, _WorkerError):
            self._finished = True
            raise v.exc
        return v

    def close(self):
        """Stop the worker and discard queued (possibly unconsumed)
        slabs. Idempotent: a plan that finishes with items still queued
        — e.g. every segment was a cache hit and the engine drained the
        stream early — can be closed again by an outer finally without
        re-joining or re-draining."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._drain()
        self._worker.join(timeout=5)
        self._drain()     # anything the worker enqueued while we joined

    def _drain(self):
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
