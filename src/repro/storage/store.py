"""FlashStore — a directory of segments plus a manifest (DESIGN.md §3.1).

The persistent analogue of the paper's flash slices: a corpus too large
for aggregate device memory lives as Fig. 8 segment files; queries stream
only the segments whose vocabulary filter matches. Layout:

    <root>/MANIFEST.json        store config + ordered segment entries
    <root>/seg-000000.rsps      paged stream + filter + footer (segment.py)
    <root>/seg-000001.rsps      ...

The manifest is the commit point: segments are written (atomically) first,
then the manifest is swapped via ``os.replace``; a crash mid-append leaves
the previous manifest intact and at worst an orphan segment file, which
``compact()`` garbage-collects.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import logging
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import stream_format
from repro.core.corpus import Corpus, from_stream
from repro.storage import segment as segment_lib

MANIFEST = "MANIFEST.json"
SEGMENT_SUFFIX = ".rsps"
STORE_MAGIC = "rsps-store"
SUPPORTED_VERSIONS = (1,)
_REQUIRED_KEYS = ("version", "vocab_size", "docs_per_segment", "page_items",
                  "filter_kind", "next_segment_id", "segments")

log = logging.getLogger(__name__)


def fsync_dir(path: str):
    """fsync a directory so a just-renamed or just-unlinked dirent is
    durable. A crash after ``os.replace(manifest)`` but before the
    directory metadata reaches disk could resurrect the *old* manifest —
    whose segment list references files a post-swap GC already deleted,
    or re-references segments the swap replaced."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class StoreFormatError(ValueError):
    """The directory is not a readable FlashStore of a supported version:
    missing or garbled manifest, foreign magic, or an unknown config
    version. The message always names the offending path, so a router
    opening N stores can report which shard directory is bad."""


def load_validated_manifest(path: str, *, magic: str,
                            versions: Tuple[int, ...],
                            required: Tuple[str, ...], kind: str) -> Dict:
    """Read + validate a JSON manifest, raising StoreFormatError (always
    naming ``path``) on anything that is not a ``kind`` manifest of a
    supported version. Shared by FlashStore and ShardedStore so the two
    validation paths cannot drift. Manifests written before the magic
    key existed (version-1, all required keys present) are accepted."""
    try:
        with open(path) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise StoreFormatError(
            f"{path}: no manifest — {os.path.dirname(path) or '.'!r} "
            f"is not a {kind}") from None
    except json.JSONDecodeError as e:
        raise StoreFormatError(
            f"{path}: manifest is not valid JSON ({e})") from None
    if not isinstance(manifest, dict):
        raise StoreFormatError(
            f"{path}: manifest is {type(manifest).__name__}, not an "
            f"object (stale or foreign directory)")
    got = manifest.get("magic")
    if got is not None and got != magic:
        raise StoreFormatError(
            f"{path}: manifest magic {got!r} != {magic!r} "
            f"(stale or foreign directory)")
    if manifest.get("version") not in versions:
        raise StoreFormatError(
            f"{path}: unsupported {kind} version "
            f"{manifest.get('version')!r} (supported: {list(versions)}; "
            f"stale or foreign directory?)")
    missing = [k for k in required if k not in manifest]
    if missing:
        raise StoreFormatError(
            f"{path}: manifest missing keys {missing} "
            f"(stale or foreign directory?)")
    return manifest


@dataclasses.dataclass(frozen=True)
class SegmentEntry:
    name: str
    n_docs: int
    n_items: int
    doc_id_min: int
    doc_id_max: int


@dataclasses.dataclass(frozen=True)
class StoreStats:
    """Cheap store summary (manifest + segment footers via plain seeks —
    no page mmap). The cluster tier's rebalance planner reads these."""
    n_segments: int
    n_docs: int
    n_items: int
    n_bytes: int
    filter_kind: str


def _corpus_docs(corpus: Corpus) -> List[Tuple[int, List[Tuple[int, int]]]]:
    """ELL rows -> [(doc_id, [(word, count), ...])], skipping pad rows."""
    docs = []
    for r in range(corpus.n_docs):
        did = int(corpus.doc_ids[r])
        if did < 0:
            continue
        keep = corpus.ids[r] >= 0
        docs.append((did, list(zip(corpus.ids[r][keep].tolist(),
                                   corpus.vals[r][keep].astype(int).tolist()))))
    return docs


# unique per FlashStore *instance*: a reopened (possibly
# crash-recovered) store must never alias a previous instance's slab
# cache entries even if segment names were reused on disk
_CACHE_TOKENS = itertools.count(1)


class FlashStore:
    def __init__(self, root: str, manifest: Dict):
        self.root = root
        self.manifest = manifest
        self._open_segments: Dict[str, segment_lib.Segment] = {}
        # DESIGN.md §4.2: manifest-mutation bookkeeping for the device
        # slab cache — ``generation`` counts commits, registered caches
        # get precise invalidations for replaced segment names
        self.cache_token = next(_CACHE_TOKENS)
        self.generation = 0
        # id(cache) -> [cache, refcount]: refcounted so N sessions
        # sharing one cache over one store register/unregister cleanly,
        # and a long-lived store never accumulates dead sessions' caches
        self._caches: Dict[int, List] = {}

    def register_cache(self, cache):
        """Attach a SlabCache for invalidation callbacks. Paired with
        ``unregister_cache`` at session close (refcounted)."""
        slot = self._caches.setdefault(id(cache), [cache, 0])
        slot[1] += 1

    def unregister_cache(self, cache) -> bool:
        """Detach one registration (session close). Returns True when it
        was the last one — only then may the caller drop this store's
        entries from the cache; earlier a sibling session still serving
        from them would lose its warm set."""
        slot = self._caches.get(id(cache))
        if slot is None:
            return False
        slot[1] -= 1
        if slot[1] <= 0:
            del self._caches[id(cache)]
            return True
        return False

    @property
    def live_generation(self) -> int:
        """Alias so FlashStore and ingest Snapshot expose the same
        plan-view surface (a snapshot's ``generation`` is capture-time,
        its ``live_generation`` is the store's current one)."""
        return self.generation

    @property
    def memo_state(self):
        """Everything beyond the segment files that could change a
        query's answer on this view — keyed into the memo cache
        (storage/memo.py). No memtable here, so generation alone."""
        return (self.generation, None)

    def bump_generation(self, removed: Sequence[str] = ()):
        """Record one manifest mutation (append/seal/fold/compact) and
        drop exactly the replaced segment names from every registered
        cache. Dropping is a perf event, never a correctness one — a
        live snapshot that still scores a replaced file reloads it from
        the graveyard (§6.2)."""
        self.generation += 1
        if removed:
            for cache, _ in list(self._caches.values()):
                cache.invalidate(self.cache_token, removed)

    # -- lifecycle -----------------------------------------------------
    @classmethod
    def create(cls, root: str, *, vocab_size: int,
               docs_per_segment: int = 4096,
               page_items: int = segment_lib.DEFAULT_PAGE_ITEMS,
               filter_kind: str = "auto") -> "FlashStore":
        os.makedirs(root, exist_ok=True)
        if os.path.exists(os.path.join(root, MANIFEST)):
            raise FileExistsError(f"store already exists at {root}")
        manifest = {
            "magic": STORE_MAGIC,
            "version": 1,
            "vocab_size": vocab_size,
            "docs_per_segment": docs_per_segment,
            "page_items": page_items,
            "filter_kind": filter_kind,
            "next_segment_id": 0,
            "segments": [],
        }
        store = cls(root, manifest)
        store._write_manifest()
        return store

    @classmethod
    def open(cls, root: str) -> "FlashStore":
        return cls(root, load_validated_manifest(
            os.path.join(root, MANIFEST), magic=STORE_MAGIC,
            versions=SUPPORTED_VERSIONS, required=_REQUIRED_KEYS,
            kind="FlashStore"))

    def close(self):
        for seg in self._open_segments.values():
            seg.close()
        self._open_segments.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _write_manifest(self, durable: bool = False,
                        manifest: Optional[Dict] = None):
        """Swap MANIFEST.json atomically. ``durable=True`` additionally
        fsyncs the tmp file before the rename and the directory after it
        — required wherever the swap is a commit point whose loss would
        resurrect deleted state (compaction GC, ingest seals). Passing
        ``manifest`` writes that dict *without* touching ``self.manifest``
        — the ingest tier commits to disk first and swaps the in-memory
        state after, so a crash at the commit point leaves the live
        object behind disk (safe) rather than ahead of it."""
        tmp = os.path.join(self.root, MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(self.manifest if manifest is None else manifest,
                      f, indent=1)
            if durable:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.root, MANIFEST))
        if durable:
            fsync_dir(self.root)

    # -- properties ----------------------------------------------------
    @property
    def entries(self) -> List[SegmentEntry]:
        return [SegmentEntry(**e) for e in self.manifest["segments"]]

    @property
    def n_segments(self) -> int:
        return len(self.manifest["segments"])

    @property
    def n_docs(self) -> int:
        return sum(e["n_docs"] for e in self.manifest["segments"])

    @property
    def max_segment_docs(self) -> int:
        """Largest segment (slab padding target so every slab compiles to
        one program shape — DESIGN.md §3.3)."""
        return max((e["n_docs"] for e in self.manifest["segments"]),
                   default=0)

    @property
    def vocab_size(self) -> int:
        return self.manifest["vocab_size"]

    def stats(self) -> StoreStats:
        """Store summary from the manifest plus per-segment footers read
        with plain seeks — nothing is mmapped, so this is cheap even on a
        cold store. ``filter_kind`` is the kind actually written to the
        segments (the manifest may say ``auto``)."""
        entries = self.manifest["segments"]
        n_bytes = 0
        kinds = set()
        for e in entries:
            path = os.path.join(self.root, e["name"])
            n_bytes += os.path.getsize(path)
            kinds.add(
                segment_lib.read_footer(path)["filter"]["meta"]["kind"])
        if len(kinds) == 1:
            kind = kinds.pop()
        elif kinds:
            kind = "mixed"
        else:
            kind = self.manifest["filter_kind"]
        return StoreStats(n_segments=len(entries),
                          n_docs=sum(e["n_docs"] for e in entries),
                          n_items=sum(e["n_items"] for e in entries),
                          n_bytes=n_bytes, filter_kind=kind)

    # -- write path ----------------------------------------------------
    def _reserve_segment_name(self) -> str:
        """Claim the next segment id (mutates the in-memory manifest;
        persisted with the next manifest write). Split from the file
        write so the ingest tier can take ids under its state lock while
        writing segment data with no lock held."""
        sid = self.manifest["next_segment_id"]
        self.manifest["next_segment_id"] = sid + 1
        return f"seg-{sid:06d}{SEGMENT_SUFFIX}"

    def _write_segment_file(self, name: str, chunk,
                            durable: bool = False) -> Dict:
        """Write one segment file (atomic tmp+rename) and return its
        manifest entry. Neither the segment list nor the manifest file
        is touched — callers commit. ``durable=True`` fsyncs the data
        first: mandatory when the committing manifest write will itself
        be durable, else power loss yields a durable manifest naming a
        torn segment."""
        footer = segment_lib.write_segment(
            os.path.join(self.root, name), chunk,
            page_items=self.manifest["page_items"],
            vocab_size=self.manifest["vocab_size"],
            filter_kind=self.manifest["filter_kind"], fsync=durable)
        return {"name": name, "n_docs": footer["n_docs"],
                "n_items": footer["n_items"],
                "doc_id_min": footer["doc_id_min"],
                "doc_id_max": footer["doc_id_max"]}

    def _write_one_segment(self, chunk, durable: bool = False) -> Dict:
        return self._write_segment_file(self._reserve_segment_name(), chunk,
                                        durable)

    def append_docs(self, docs: Sequence[Tuple[int, Sequence[Tuple[int, int]]]],
                    docs_per_segment: Optional[int] = None) -> List[str]:
        """Append documents, splitting into <= docs_per_segment segments.
        Returns the new segment names."""
        per = docs_per_segment or self.manifest["docs_per_segment"]
        entries = [self._write_one_segment(docs[lo:lo + per])
                   for lo in range(0, len(docs), per)]
        self.manifest["segments"].extend(entries)
        self._write_manifest()
        self.bump_generation()
        return [e["name"] for e in entries]

    def append_corpus(self, corpus: Corpus,
                      docs_per_segment: Optional[int] = None) -> List[str]:
        return self.append_docs(_corpus_docs(corpus), docs_per_segment)

    def compact(self, docs_per_segment: Optional[int] = None) -> int:
        """Rewrite all segments at full occupancy (merging small appends)
        and drop orphan segment files. Streams one old segment at a time,
        so host memory stays bounded at ~one segment regardless of store
        size. Returns the new segment count."""
        per = docs_per_segment or self.manifest["docs_per_segment"]
        old_entries = list(self.manifest["segments"])
        new_entries: List[Dict] = []
        buf: List = []
        for e in old_entries:
            seg = self.segment(e["name"])
            buf.extend(seg.docs())
            self.release(e["name"])
            while len(buf) >= per:
                # durable: compaction deletes the originals below, so the
                # rewrites must be on disk before the fsynced manifest
                # (and the GC) makes them the only copy
                new_entries.append(self._write_one_segment(buf[:per],
                                                           durable=True))
                del buf[:per]
        if buf:
            new_entries.append(self._write_one_segment(buf, durable=True))
        self.close()
        self.manifest["segments"] = new_entries
        self.manifest["docs_per_segment"] = per
        # commit point: durable swap (fsync file + directory) — without
        # the directory fsync a crash here could resurrect the old
        # manifest after the loop below has GC'd the segments it names
        self._write_manifest(durable=True)
        live = {e["name"] for e in new_entries}
        replaced = {e["name"] for e in old_entries}
        for fn in os.listdir(self.root):
            if fn.endswith(SEGMENT_SUFFIX) and fn not in live:
                if fn not in replaced:
                    # never referenced by any manifest: a crashed append
                    log.warning("compact(%s): removing orphan segment %s",
                                self.root, fn)
                else:
                    log.info("compact(%s): removing replaced segment %s",
                             self.root, fn)
                os.unlink(os.path.join(self.root, fn))
        self.bump_generation(removed=[e["name"] for e in old_entries])
        return self.n_segments

    # -- read path -----------------------------------------------------
    def segment(self, name: str) -> segment_lib.Segment:
        if name not in self._open_segments:
            self._open_segments[name] = segment_lib.Segment(
                os.path.join(self.root, name))
        return self._open_segments[name]

    def release(self, name: str):
        """Close one segment's fd/mmap (readers drop handles as soon as a
        segment is filtered out or decoded, so a search never holds more
        than a few descriptors regardless of store size)."""
        seg = self._open_segments.pop(name, None)
        if seg is not None:
            seg.close()

    def segments(self) -> Iterable[segment_lib.Segment]:
        return [self.segment(e["name"]) for e in self.manifest["segments"]]

    def scan_corpus(self, nnz_pad: int, *, strict: bool = True) -> Corpus:
        """Decode the whole store into one in-memory Corpus (tests and
        small stores; the query path never needs this)."""
        streams = [seg.stream() for seg in self.segments()]
        if not streams:
            return Corpus.empty(nnz_pad)
        return from_stream(np.concatenate(streams), nnz_pad, strict=strict)
