"""Flash storage tier: persistent segment store with in-storage filtering
and async prefetch (DESIGN.md §3)."""
from repro.storage.filter import (BitmapFilter, BloomFilter, build_filter,
                                  from_meta)
from repro.storage.prefetch import Prefetcher
from repro.storage.segment import Segment, read_footer, write_segment
from repro.storage.session import FlashSearchSession, SearchStats
from repro.storage.store import (FlashStore, StoreFormatError, StoreStats)

__all__ = [
    "BitmapFilter", "BloomFilter", "build_filter", "from_meta",
    "Prefetcher", "Segment", "read_footer", "write_segment",
    "FlashSearchSession", "SearchStats", "FlashStore",
    "StoreFormatError", "StoreStats",
]
