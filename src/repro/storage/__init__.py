"""Flash storage tier: persistent segment store with in-storage filtering
and async prefetch (DESIGN.md §3)."""
from repro.storage.filter import (BitmapFilter, BloomFilter, build_filter,
                                  from_meta)
from repro.storage.prefetch import Prefetcher
from repro.storage.segment import Segment, write_segment
from repro.storage.session import FlashSearchSession, SearchStats
from repro.storage.store import FlashStore

__all__ = [
    "BitmapFilter", "BloomFilter", "build_filter", "from_meta",
    "Prefetcher", "Segment", "write_segment",
    "FlashSearchSession", "SearchStats", "FlashStore",
]
