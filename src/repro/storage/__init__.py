"""Flash storage tier: persistent segment store with in-storage filtering,
async prefetch, and the query planner + device slab cache
(DESIGN.md §3–§4)."""
from repro.storage.filter import (BitmapFilter, BloomFilter, QueryProbe,
                                  build_filter, from_meta)
from repro.storage.memo import MemoCache, MemoStats, query_fingerprint
from repro.storage.plan import (MODE_APPROX, MODE_AUTO, MODE_EXACT, MODES,
                                Planner, PlanStep, QueryPlan, execute_plan)
from repro.storage.postings import PostingIndex, gather_rows
from repro.storage.prefetch import Prefetcher
from repro.storage.segment import Segment, read_footer, write_segment
from repro.storage.session import FlashSearchSession, SearchStats
from repro.storage.slabcache import (CacheStats, SlabCache,
                                     DEFAULT_CACHE_BYTES)
from repro.storage.store import (FlashStore, StoreFormatError, StoreStats)

__all__ = [
    "BitmapFilter", "BloomFilter", "QueryProbe", "build_filter", "from_meta",
    "MemoCache", "MemoStats", "query_fingerprint",
    "MODE_APPROX", "MODE_AUTO", "MODE_EXACT", "MODES",
    "Planner", "PlanStep", "QueryPlan", "execute_plan",
    "PostingIndex", "gather_rows",
    "Prefetcher", "Segment", "read_footer", "write_segment",
    "FlashSearchSession", "SearchStats",
    "CacheStats", "SlabCache", "DEFAULT_CACHE_BYTES",
    "FlashStore", "StoreFormatError", "StoreStats",
]
