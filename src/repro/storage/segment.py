"""On-disk segment files for the flash tier (DESIGN.md §3.1).

A segment is the unit a flash slice serves: the Fig. 8 uint32 stream
(``core/stream_format``) laid out in fixed-size pages, each page starting
at a document header so it decodes independently, followed by the
segment's vocabulary filter and a footer index:

    [magic "RSPSEG1\\n"]
    [page 0 | page 1 | ...]          raw uint32 stream, doc-aligned splits
    [filter bytes]                   BitmapFilter / BloomFilter payload
    [postings bytes]                 PostingIndex payload (approx tier)
    [footer JSON]                    page index + doc-id range + filter meta
    [footer offset u64 LE][magic "RSPSEGF\\n"]

The footer carries, per page: byte offset, item count, doc count and the
min/max doc id — enough for point lookups and range pruning without
touching page data. Readers memory-map the file; ``stream()`` is a
zero-copy uint32 view over all pages, so decode cost is paid only for
segments that survive the vocabulary filter.
"""
from __future__ import annotations

import json
import mmap
import os
import struct
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import stream_format
from repro.storage import filter as filter_lib
from repro.storage import postings as postings_lib

MAGIC = b"RSPSEG1\n"
FOOTER_MAGIC = b"RSPSEGF\n"
VERSION = 1
DEFAULT_PAGE_ITEMS = 1 << 15   # 128 KB pages of 4-byte items


def _page_splits(stream: np.ndarray, hdr_pos: np.ndarray,
                 page_items: int) -> List[Tuple[int, int]]:
    """Split the stream at document headers into [start, end) item ranges
    of at most ``page_items`` items (a single over-long document gets its
    own over-sized page rather than being torn). ``hdr_pos`` is the item
    index of every document header."""
    if hdr_pos.size == 0:
        return []
    if int(hdr_pos[0]) != 0:
        raise ValueError("stream must begin with a document header")
    # doc i occupies items [bounds[i], bounds[i+1])
    bounds = np.append(hdr_pos, stream.size)
    splits = []
    i, n = 0, hdr_pos.size
    while i < n:
        j = i + 1   # page always takes doc i, even if it alone overflows
        while j < n and int(bounds[j + 1] - bounds[i]) <= page_items:
            j += 1
        splits.append((int(bounds[i]), int(bounds[j])))
        i = j
    return splits


def write_segment(path: str, docs: Sequence[Tuple[int, Sequence[Tuple[int, int]]]],
                  *, page_items: int = DEFAULT_PAGE_ITEMS,
                  vocab_size: Optional[int] = None,
                  filter_kind: str = "auto", fsync: bool = False) -> Dict:
    """Encode ``docs`` ([(doc_id, [(word, count), ...])]) into a segment
    file at ``path``. Returns the footer dict (the manifest keeps a
    subset). Writes to ``path + '.tmp'`` and atomically renames.
    ``fsync=True`` flushes the data to disk before the rename — required
    when a durable manifest will reference this file (a manifest that
    survives power loss must never point at torn pages)."""
    stream = stream_format.encode(docs)
    hdr_pos = np.flatnonzero((stream & stream_format.HEADER_BIT) != 0)
    splits = _page_splits(stream, hdr_pos, page_items)
    # word ids come straight off the encoded stream (encode() already
    # validated every id): all non-header items, keyed per Fig. 8
    pair_items = stream[(stream & stream_format.HEADER_BIT) == 0]
    word_ids = ((pair_items >> stream_format.VAL_BITS)
                & stream_format.KEY_MASK).astype(np.int64)
    filt = filter_lib.build_filter(word_ids, vocab_size=vocab_size,
                                   kind=filter_kind)
    filter_raw = filt.to_bytes()
    postings = postings_lib.PostingIndex.build(stream)
    postings_raw = postings.to_bytes()

    doc_ids = np.asarray([d for d, _ in docs], np.int64)
    pages = []
    data_off = len(MAGIC)
    for start, end in splits:
        lo = int(np.searchsorted(hdr_pos, start, side="left"))
        hi = int(np.searchsorted(hdr_pos, end, side="left"))
        page_docs = doc_ids[lo:hi]
        pages.append({
            "off": data_off + 4 * start,
            "n_items": end - start,
            "n_docs": int(hi - lo),
            "doc_min": int(page_docs.min()) if page_docs.size else -1,
            "doc_max": int(page_docs.max()) if page_docs.size else -1,
        })

    filter_off = data_off + 4 * stream.size
    postings_off = filter_off + len(filter_raw)
    footer = {
        "version": VERSION,
        "n_docs": int(doc_ids.size),
        "n_items": int(stream.size),
        "doc_id_min": int(doc_ids.min()) if doc_ids.size else -1,
        "doc_id_max": int(doc_ids.max()) if doc_ids.size else -1,
        "data_off": data_off,
        "pages": pages,
        "filter": {"off": filter_off, "nbytes": len(filter_raw),
                   "meta": filt.meta()},
        "postings": {"off": postings_off, "nbytes": len(postings_raw),
                     "meta": postings.meta()},
    }
    footer_raw = json.dumps(footer).encode()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(stream.astype("<u4").tobytes())
        f.write(filter_raw)
        f.write(postings_raw)
        footer_off = f.tell()
        f.write(footer_raw)
        f.write(struct.pack("<Q", footer_off))
        f.write(FOOTER_MAGIC)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    os.replace(tmp, path)
    return footer


def read_footer(path: str) -> Dict:
    """Read a segment's footer JSON with plain seeks — no mmap, no page
    data touched. This is the cheap path store-wide stats and rebalance
    planning use to inspect cold segments."""
    with open(path, "rb") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        tail = 8 + len(FOOTER_MAGIC)
        if size < len(MAGIC) + tail:
            raise ValueError(f"{path}: too small to be a segment file")
        f.seek(size - tail)
        trailer = f.read(tail)
        if trailer[8:] != FOOTER_MAGIC:
            raise ValueError(f"{path}: bad footer magic (truncated write?)")
        (footer_off,) = struct.unpack("<Q", trailer[:8])
        if not len(MAGIC) <= footer_off <= size - tail:
            raise ValueError(f"{path}: footer offset {footer_off} out of range")
        f.seek(footer_off)
        return json.loads(f.read(size - tail - footer_off))


class Segment:
    """Memory-mapped reader over one segment file."""

    def __init__(self, path: str):
        self.path = path
        self._file = open(path, "rb")
        self._mm = mmap.mmap(self._file.fileno(), 0, access=mmap.ACCESS_READ)
        if self._mm[:len(MAGIC)] != MAGIC:
            raise ValueError(f"{path}: bad segment magic")
        if self._mm[-len(FOOTER_MAGIC):] != FOOTER_MAGIC:
            raise ValueError(f"{path}: bad footer magic (truncated write?)")
        (footer_off,) = struct.unpack(
            "<Q", self._mm[-len(FOOTER_MAGIC) - 8:-len(FOOTER_MAGIC)])
        self.footer = json.loads(
            self._mm[footer_off:len(self._mm) - len(FOOTER_MAGIC) - 8])
        if self.footer["version"] != VERSION:
            raise ValueError(f"{path}: unsupported version")
        self._filter = None
        self._postings = None

    # -- metadata ------------------------------------------------------
    @property
    def n_docs(self) -> int:
        return self.footer["n_docs"]

    @property
    def n_items(self) -> int:
        return self.footer["n_items"]

    @property
    def doc_id_range(self) -> Tuple[int, int]:
        return self.footer["doc_id_min"], self.footer["doc_id_max"]

    @property
    def nbytes(self) -> int:
        return len(self._mm)

    # -- data plane ----------------------------------------------------
    def stream(self) -> np.ndarray:
        """Zero-copy uint32 view over the full Fig. 8 stream."""
        off = self.footer["data_off"]
        return np.frombuffer(self._mm, dtype="<u4", count=self.n_items,
                             offset=off)

    def page_stream(self, i: int) -> np.ndarray:
        p = self.footer["pages"][i]
        return np.frombuffer(self._mm, dtype="<u4", count=p["n_items"],
                             offset=p["off"])

    @property
    def n_pages(self) -> int:
        return len(self.footer["pages"])

    # -- filter --------------------------------------------------------
    @property
    def vocab_filter(self):
        if self._filter is None:
            meta = self.footer["filter"]
            raw = self._mm[meta["off"]:meta["off"] + meta["nbytes"]]
            self._filter = filter_lib.from_meta(meta["meta"], raw)
        return self._filter

    @property
    def postings(self):
        """Lazy posting index, or None for pre-postings segment files
        (the planner then keeps those segments on the exact path)."""
        if self._postings is None:
            meta = self.footer.get("postings")
            if meta is None:
                return None
            raw = self._mm[meta["off"]:meta["off"] + meta["nbytes"]]
            self._postings = postings_lib.PostingIndex.from_bytes(
                meta["meta"], raw)
        return self._postings

    def docs(self):
        """Decode back to [(doc_id, [(word, count), ...])] (compaction /
        debugging path; the query path uses decode_to_ell on stream())."""
        return stream_format.decode(self.stream())

    def close(self):
        if self._mm is not None:
            self._mm.close()
            self._file.close()
            self._mm = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
