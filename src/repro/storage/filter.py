"""Per-segment vocabulary filters — the in-storage pattern filter
(DESIGN.md §3.2).

The paper's accelerator prunes data at the storage boundary: a query never
pays flash bandwidth for patterns that cannot match. Here each on-disk
segment carries a compact summary of the word ids it contains; a query
whose word-id set misses the summary skips the segment without reading a
single page.

Two summaries, one interface:

- ``BitmapFilter`` — one bit per vocab word. Exact (no false positives);
  at the paper's 141k-word vocabulary it is ~17 KB/segment, negligible
  next to megabytes of pages. Default whenever the vocab is bounded.
- ``BloomFilter`` — classic double-hashed Bloom over the word ids, for
  open/huge key spaces (the 19-bit key limit makes this rare, but protein
  k-mer or edge-label spaces can be configured larger).

Both serialize to ``(meta dict, raw bytes)`` so the segment footer can
embed them; ``from_meta`` reconstructs either kind.

A planner probing hundreds of segments asks the *same* query against
every one, so the query-side work — dedup, validation, and above all the
two splitmix64 mixes behind the Kirsch–Mitzenmacher scheme — is hoisted
into a per-query ``QueryProbe``: build it once, then each segment verdict
costs only a table lookup (bitmap) or a modulo + gather (Bloom).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


def _as_word_ids(word_ids) -> np.ndarray:
    ids = np.asarray(word_ids).reshape(-1).astype(np.int64)
    return np.unique(ids[ids >= 0])


def splitmix64(x: np.ndarray) -> np.ndarray:
    """The splitmix64 finalizer (avalanche so sequential ids spread).

    STABLE CONTRACT: these exact constants are baked into persisted
    formats — Bloom filter bit positions inside segment files and the
    cluster tier's hash partition assignments under CLUSTER.json.
    Changing them requires a format-version bump on both."""
    x = np.asarray(x, np.uint64)
    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15))
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


class QueryProbe:
    """Filter-independent probe state for one query: the unique valid
    word ids plus their Kirsch–Mitzenmacher base hashes. h1/h2 depend
    only on the ids and the stable splitmix64 constants, never on a
    particular filter's geometry, so every segment verdict reuses them
    — only the ``% n_bits`` fold is per-filter."""

    __slots__ = ("ids", "h1", "h2")

    def __init__(self, word_ids):
        self.ids = _as_word_ids(word_ids)
        u = self.ids.astype(np.uint64)
        self.h1 = splitmix64(u)
        self.h2 = splitmix64(u ^ np.uint64(0xA5A5A5A5A5A5A5A5)) | np.uint64(1)


class BitmapFilter:
    """Exact one-bit-per-word membership bitmap."""

    kind = "bitmap"

    def __init__(self, bits: np.ndarray, vocab_size: int):
        self.bits = bits                     # uint8 [ceil(vocab/8)]
        self.vocab_size = vocab_size

    @classmethod
    def build(cls, word_ids, vocab_size: int) -> "BitmapFilter":
        ids = _as_word_ids(word_ids)
        if ids.size and int(ids.max()) >= vocab_size:
            raise ValueError(
                f"word id {int(ids.max())} >= vocab_size {vocab_size}")
        bits = np.zeros(-(-vocab_size // 8), np.uint8)
        np.bitwise_or.at(bits, ids >> 3, np.uint8(1) << (ids & 7).astype(np.uint8))
        return cls(bits, vocab_size)

    def contains(self, word_ids) -> np.ndarray:
        ids = np.asarray(word_ids, np.int64).reshape(-1)
        ok = (ids >= 0) & (ids < self.vocab_size)
        safe = np.where(ok, ids, 0)
        hit = (self.bits[safe >> 3] >> (safe & 7).astype(np.uint8)) & 1
        return (hit.astype(bool)) & ok

    def contains_any(self, word_ids) -> bool:
        return bool(self.contains(word_ids).any())

    def contains_any_probe(self, probe: QueryProbe) -> bool:
        """Same verdict as ``contains_any(probe source ids)`` with the
        query-side dedup/validation already paid."""
        ids = probe.ids
        if ids.size == 0:
            return False
        ok = ids < self.vocab_size
        safe = np.where(ok, ids, 0)
        hit = (self.bits[safe >> 3] >> (safe & 7).astype(np.uint8)) & 1
        return bool((hit.astype(bool) & ok).any())

    def estimated_fpr(self) -> float:
        """Exact membership — never a false positive."""
        return 0.0

    def to_bytes(self) -> bytes:
        return self.bits.tobytes()

    def meta(self) -> Dict:
        return {"kind": self.kind, "vocab_size": self.vocab_size}


class BloomFilter:
    """Double-hashed Bloom filter over word ids (splitmix64 mixing)."""

    kind = "bloom"

    def __init__(self, words: np.ndarray, n_bits: int, n_hashes: int):
        self.words = words                   # uint64 [n_bits/64]
        self.n_bits = n_bits
        self.n_hashes = n_hashes

    _mix = staticmethod(splitmix64)

    def _bit_positions(self, ids: np.ndarray) -> np.ndarray:
        """[n] ids -> [n, n_hashes] bit indices (Kirsch–Mitzenmacher)."""
        h1 = self._mix(ids)
        h2 = self._mix(ids ^ np.uint64(0xA5A5A5A5A5A5A5A5)) | np.uint64(1)
        ks = np.arange(self.n_hashes, dtype=np.uint64)
        with np.errstate(over="ignore"):
            hh = h1[:, None] + ks[None, :] * h2[:, None]
        return (hh % np.uint64(self.n_bits)).astype(np.int64)

    @classmethod
    def build(cls, word_ids, n_bits: Optional[int] = None,
              n_hashes: int = 4, bits_per_key: int = 10) -> "BloomFilter":
        ids = _as_word_ids(word_ids).astype(np.uint64)
        if n_bits is None:
            n_bits = max(64, 1 << int(np.ceil(np.log2(
                max(1, ids.size) * bits_per_key))))
        f = cls(np.zeros(-(-n_bits // 64), np.uint64), n_bits, n_hashes)
        if ids.size:
            pos = f._bit_positions(ids).reshape(-1)
            np.bitwise_or.at(f.words, pos >> 6,
                             np.uint64(1) << (pos & 63).astype(np.uint64))
        return f

    def contains(self, word_ids) -> np.ndarray:
        ids = np.asarray(word_ids, np.int64).reshape(-1)
        ok = ids >= 0
        pos = self._bit_positions(np.where(ok, ids, 0).astype(np.uint64))
        hit = (self.words[pos >> 6] >> (pos & 63).astype(np.uint64)) & np.uint64(1)
        return hit.astype(bool).all(axis=1) & ok

    def contains_any(self, word_ids) -> bool:
        return bool(self.contains(word_ids).any())

    def contains_any_probe(self, probe: QueryProbe) -> bool:
        """Same verdict as ``contains_any(probe source ids)`` reusing the
        probe's precomputed h1/h2 — only the ``% n_bits`` fold and the
        word gather are paid per segment (must stay bit-compatible with
        ``_bit_positions``)."""
        if probe.ids.size == 0:
            return False
        ks = np.arange(self.n_hashes, dtype=np.uint64)
        with np.errstate(over="ignore"):
            hh = probe.h1[:, None] + ks[None, :] * probe.h2[:, None]
        pos = (hh % np.uint64(self.n_bits)).astype(np.int64)
        hit = (self.words[pos >> 6]
               >> (pos & 63).astype(np.uint64)) & np.uint64(1)
        return bool(hit.astype(bool).all(axis=1).any())

    def estimated_fpr(self) -> float:
        """Estimated false-positive rate from the observed bit load:
        fpr ~= (set_bits / n_bits) ** n_hashes, the standard Bloom
        estimate for a membership probe of an absent key."""
        if self.n_bits == 0:
            return 1.0
        set_bits = int(np.unpackbits(
            self.words.view(np.uint8)).sum())
        # words may over-allocate past n_bits; those bits are never set
        load = min(1.0, set_bits / float(self.n_bits))
        return float(load ** self.n_hashes)

    def to_bytes(self) -> bytes:
        return self.words.tobytes()

    def meta(self) -> Dict:
        return {"kind": self.kind, "n_bits": self.n_bits,
                "n_hashes": self.n_hashes}


VocabFilter = (BitmapFilter, BloomFilter)


def build_filter(word_ids, vocab_size: Optional[int] = None,
                 kind: str = "auto", **bloom_kw):
    """Build the segment summary. ``auto`` prefers the exact bitmap when
    the vocab is bounded (<= 2^21 words = 256 KB bitmap), else Bloom."""
    if kind == "auto":
        kind = "bitmap" if vocab_size and vocab_size <= (1 << 21) else "bloom"
    if kind == "bitmap":
        if not vocab_size:
            raise ValueError("bitmap filter needs vocab_size")
        return BitmapFilter.build(word_ids, vocab_size)
    if kind == "bloom":
        return BloomFilter.build(word_ids, **bloom_kw)
    raise ValueError(f"unknown filter kind {kind!r}")


def from_meta(meta: Dict, raw: bytes):
    """Reconstruct a filter from its footer metadata + raw bytes."""
    if meta["kind"] == "bitmap":
        return BitmapFilter(np.frombuffer(raw, np.uint8).copy(),
                            meta["vocab_size"])
    if meta["kind"] == "bloom":
        return BloomFilter(np.frombuffer(raw, np.uint64).copy(),
                           meta["n_bits"], meta["n_hashes"])
    raise ValueError(f"unknown filter kind {meta['kind']!r}")
