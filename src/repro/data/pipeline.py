"""Deterministic, resumable data pipeline with epoch-tagged prefetch.

Batches are a pure function of (seed, step) — Philox counter-based — so a
restarted/rescaled job regenerates the identical stream from any step
(fault-tolerance requirement), with no state files to lose.

The background prefetcher mirrors the paper's prefetch predictor (Fig. 10):
it speculatively prepares batch(step+1), tagging each buffer with an epoch;
``seek`` (on restore/reshard) bumps the epoch, and stale prefetches are
identified by tag and discarded rather than flushed synchronously.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.meshctx import MeshCtx


class SyntheticLMData:
    """Token batches ~ Zipf(1.2) over the vocab (realistic logits scale)."""

    def __init__(self, cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed + 2**32,
                                                   counter=step))
        V = self.cfg.vocab_size
        toks = rng.zipf(1.2, size=(self.global_batch, self.seq_len))
        toks = (toks - 1) % V
        batch = {"tokens": toks.astype(np.int32)}
        if self.cfg.embeds_input:
            batch["labels"] = batch.pop("tokens")
            batch["embeds"] = rng.standard_normal(
                (self.global_batch, self.seq_len, self.cfg.d_model),
                np.float32) * 0.02
        if self.cfg.family == "vlm":
            batch["image_embeds"] = rng.standard_normal(
                (self.global_batch, self.cfg.n_image_tokens,
                 self.cfg.d_model), np.float32) * 0.02
        return batch


def shard_batch(batch: Dict[str, np.ndarray], ctx: MeshCtx):
    sh = NamedSharding(ctx.mesh, P(ctx.dp_axes))
    def put(x):
        spec = P(ctx.dp_axes, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(ctx.mesh, spec))
    return {k: put(v) for k, v in batch.items()}


class PrefetchingLoader:
    """Epoch-tagged double-buffered loader over a batch_at(step) source."""

    def __init__(self, source, ctx: MeshCtx, depth: int = 2):
        self.source = source
        self.ctx = ctx
        self.depth = depth
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._epoch = 0
        self._next_step = 0
        self._lock = threading.Lock()
        self._stop = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop:
            with self._lock:
                epoch, step = self._epoch, self._next_step
                self._next_step += 1
            batch = self.source.batch_at(step)
            batch = shard_batch(batch, self.ctx)
            try:
                self._q.put((epoch, step, batch), timeout=0.5)
            except queue.Full:
                with self._lock:  # nobody consumed: rewind our speculation
                    if self._epoch == epoch:
                        self._next_step = step
                continue

    def seek(self, step: int):
        """Restart/reshard: bump epoch; stale prefetches get discarded."""
        with self._lock:
            self._epoch += 1
            self._next_step = step

    def next(self, expected_step: int):
        while True:
            epoch, step, batch = self._q.get()
            with self._lock:
                cur = self._epoch
            if epoch == cur and step == expected_step:
                return batch
            # mispredicted prefetch (stale epoch or wrong step): discard
            if epoch == cur and step > expected_step:
                self.seek(expected_step)

    def close(self):
        self._stop = True
