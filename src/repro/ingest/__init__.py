"""Live ingestion tier: WAL-backed appends, memtable + delta segments,
and online compaction under serving (DESIGN.md §6)."""
from repro.ingest.memtable import MemTable
from repro.ingest.pipeline import (IngestConfig, IngestPipeline,
                                   IngestStats, Snapshot, WAL_NAME)
from repro.ingest.wal import WriteAheadLog

__all__ = [
    "MemTable",
    "IngestConfig", "IngestPipeline", "IngestStats", "Snapshot", "WAL_NAME",
    "WriteAheadLog",
]
