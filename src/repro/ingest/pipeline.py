"""IngestPipeline — WAL-backed appends, delta-segment seals, and online
compaction over a FlashStore, without ever blocking or perturbing
readers (DESIGN.md §6).

The write path is the LSM split SpANNS applies to sparse indices:

    append(doc) ──▶ WriteAheadLog (durable tail, §6.1)
                └─▶ MemTable (searchable tail)
    seal: memtable ──▶ immutable delta segment(s) (Fig. 8 format + vocab
          filter, exactly §3.1) ──▶ manifest swap ──▶ WAL reset
    Compactor: folds the store's underfull tail run into full segments,
          commits with the same atomic manifest swap, GCs the replaced
          files afterwards (§6.2)

Concurrency contract (two locks, lock order write → state):

- ``_write_lock`` serializes *writers*: appends, seal commits, and the
  compactor's commit step. Held across file I/O only on the write path.
- ``_state_lock`` guards the shared in-memory state — the manifest's
  segment list and the memtable — and is held only for list swaps and
  snapshot capture (microseconds). Readers touch no other lock.

A query calls ``capture()`` and gets a ``Snapshot``: the segment entry
list plus a copy of the memtable, taken in one ``_state_lock`` section,
registered with the pipeline. While any snapshot is registered the
compactor parks replaced files in a graveyard instead of unlinking
them (drained when the last snapshot closes), so a snapshot opens its
segments lazily — one fd at a time, like the cold read path — and
still sees exactly the manifest generation + sealed deltas + memtable
state of capture time no matter how many folds commit underneath it.
Because a seal moves documents from memtable to manifest inside one
``_state_lock`` section, a snapshot can never see a document twice or
lose one mid-seal.

Crash recovery ordering (each arrow is a durability point):

    segment file rename ──▶ durable manifest (+``ingest_seq``) ──▶ WAL reset

A crash before the manifest swap leaves an orphan segment (GC'd by
compaction) and an intact WAL; a crash after it but before the WAL
reset is idempotent because replay skips records with
``seq <= manifest["ingest_seq"]``.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.corpus import Corpus
from repro.ingest.memtable import MemTable
from repro.ingest.wal import WriteAheadLog
from repro.obs import Obs, default_obs
from repro.storage import segment as segment_lib
from repro.storage.store import FlashStore, SegmentEntry

WAL_NAME = "wal.log"

log = logging.getLogger(__name__)

Doc = Tuple[int, Sequence[Tuple[int, int]]]


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    """Knobs for one store's write path.

    ``seal_docs``: memtable size that triggers a seal (delta segments of
    roughly this many documents). ``fold_min_segments``: the compactor
    folds the store's underfull tail run once it is at least this many
    segments long. ``fsync``: fsync the WAL on every append (durable to
    the platter) — off by default, matching the flash tier's
    mmap-not-NVMe simplification (DESIGN.md §14). ``auto_compact``
    starts the background compactor thread; ``compact_poll_s`` is its
    idle poll interval (seals nudge it immediately)."""
    seal_docs: int = 512
    fold_min_segments: int = 4
    auto_compact: bool = True
    compact_poll_s: float = 0.25
    fsync: bool = False


@dataclasses.dataclass
class IngestStats:
    appended: int = 0          # documents accepted this process
    replayed: int = 0          # documents recovered from the WAL on open
    seals: int = 0             # memtable -> delta-segment commits
    compactions: int = 0       # background/manual folds committed
    segments_folded: int = 0   # segments rewritten by those folds


class Snapshot:
    """One query's frozen view of a live store: the segment entry list
    plus the memtable documents, captured atomically under the state
    lock. Segment handles open *lazily* (``segment``), one at a time
    like the non-ingest read path, so a snapshot costs zero fds up
    front and the bounded-descriptor invariant of the plan executor's
    loader (``storage/plan.py``) holds on live stores too. The
    pipeline defers compaction GC while any snapshot is registered
    (``_snapshot_closed``), so a lazily opened file is guaranteed to
    still exist. ``close()`` is idempotent."""

    def __init__(self, entries: List[SegmentEntry], mem_docs: List[Doc],
                 mem_key: Tuple[int, int], generation: int,
                 pipeline: "IngestPipeline"):
        self.entries = entries
        self.mem_docs = mem_docs
        self._mem_key = mem_key
        self._generation = generation
        self._pipeline = pipeline
        self._segments: Dict[str, segment_lib.Segment] = {}

    @property
    def max_segment_docs(self) -> int:
        return max((e.n_docs for e in self.entries), default=0)

    @property
    def cache_token(self):
        """Slab-cache identity (DESIGN.md §4.2): snapshot segments are
        the store's own immutable files, so they share its token."""
        return self._pipeline.store.cache_token

    @property
    def generation(self) -> int:
        """The store generation this segment list was captured at
        (under the state lock) — what the plan records. Compared
        against ``live_generation`` at cache-admission time, so a
        snapshot straggling past a fold (even one landing between
        capture and planning) can never re-admit graveyard slabs the
        fold just invalidated."""
        return self._generation

    @property
    def live_generation(self) -> int:
        return self._pipeline.store.generation

    @property
    def memo_state(self):
        """Memo-cache key component (storage/memo.py): capture-time
        generation plus the memtable fingerprint, so a memoized result
        can never outlive an append, seal, or compaction."""
        return (self._generation, self._mem_key)

    def segment(self, name: str) -> segment_lib.Segment:
        if name not in self._segments:
            self._segments[name] = segment_lib.Segment(
                os.path.join(self._pipeline.store.root, name))
        return self._segments[name]

    def release(self, name: str):
        seg = self._segments.pop(name, None)
        if seg is not None:
            seg.close()

    def memtable_corpus(self, nnz_pad: int) -> Tuple[Optional[Corpus], int]:
        return self._pipeline._memtable_corpus(
            self.mem_docs, self._mem_key, nnz_pad)

    def close(self):
        for seg in self._segments.values():
            seg.close()
        self._segments = {}
        if self._pipeline is not None:
            self._pipeline._snapshot_closed()
            self._pipeline = None


class IngestPipeline:
    def __init__(self, store: FlashStore, cfg: Optional[IngestConfig] = None,
                 obs: Optional[Obs] = None):
        self.store = store
        self.cfg = cfg or IngestConfig()
        if self.cfg.seal_docs < 1:
            raise ValueError("seal_docs must be >= 1")
        self._write_lock = threading.Lock()
        self._state_lock = threading.Lock()
        self._compact_lock = threading.Lock()   # one fold at a time
        self._closed = False
        self.stats = IngestStats()
        # §8 registry handles, resolved once — append() touches exactly
        # one pre-bound counter beyond its existing work
        self.obs = obs if obs is not None else default_obs()
        reg = self.obs.registry
        self._c_append = reg.counter("ingest_appends")
        self._c_seal = reg.counter("ingest_seals")
        self._c_sealed_docs = reg.counter("ingest_docs_sealed")
        self._c_fold = reg.counter("ingest_compactions")
        self._c_folded = reg.counter("ingest_segments_folded")
        self._h_seal = reg.histogram("ingest_seal_ms")
        self._h_fold = reg.histogram("ingest_fold_ms")
        self.wal = WriteAheadLog(os.path.join(store.root, WAL_NAME),
                                 fsync=self.cfg.fsync)
        if self.wal.repairs:
            reg.counter("ingest_wal_repairs").inc(self.wal.repairs)
        self.memtable = MemTable()
        # replay: only records newer than what seals already made durable
        # (an empty WAL after a post-seal crash must not rewind last_seq
        # below the manifest's high-water mark, or fresh appends would be
        # skipped by the next replay)
        ingest_seq = int(store.manifest.get("ingest_seq", 0))
        self.wal.last_seq = max(self.wal.last_seq, ingest_seq)
        for seq, doc in self.wal.records(after_seq=ingest_seq):
            self.memtable.add(seq, doc)
            self.stats.replayed += 1
        if self.stats.replayed:
            reg.counter("ingest_wal_replayed").inc(self.stats.replayed)
            log.info("ingest(%s): replayed %d document(s) from the WAL",
                     store.root, self.stats.replayed)
        self._compact_wake = threading.Event()
        self._compactor: Optional[threading.Thread] = None
        # snapshot bookkeeping: while any snapshot is registered, files a
        # fold replaced go to the graveyard instead of being unlinked, so
        # lazily opened snapshot segments can never hit a missing file
        self._live_snapshots = 0
        self._graveyard: List[str] = []
        # last memtable ELL build, keyed (n_docs, last_seq, nnz_pad): a
        # read-heavy workload re-scores an unchanged memtable every query
        # and must not pay the codec again each time
        self._mem_corpus_cache: Dict[Tuple[int, int, int],
                                     Tuple[Optional[Corpus], int]] = {}
        with self._write_lock:
            if len(self.memtable) >= self.cfg.seal_docs:
                self._seal_locked()
        if self.cfg.auto_compact:
            self._compactor = threading.Thread(
                target=self._compact_loop, daemon=True,
                name=f"compactor-{os.path.basename(store.root) or 'store'}")
            self._compactor.start()

    # -- write path ----------------------------------------------------
    def append(self, doc_id: int, pairs: Sequence[Tuple[int, int]]) -> int:
        """Durably log + make searchable one document; returns its WAL
        sequence number. Seals synchronously when the memtable reaches
        ``seal_docs`` (writers pay the seal; readers never do)."""
        pairs = sorted((int(w), int(c)) for w, c in pairs)
        if pairs and pairs[-1][0] >= self.store.vocab_size:
            raise ValueError(
                f"word id {pairs[-1][0]} >= store vocab_size "
                f"{self.store.vocab_size}")
        with self._write_lock:
            # checked under the lock: close() also takes it, so a writer
            # can never reach the WAL after close() shut it
            if self._closed:
                raise RuntimeError("ingest pipeline is closed")
            seq = self.wal.append((int(doc_id), pairs))
            with self._state_lock:
                self.memtable.add(seq, (int(doc_id), pairs))
            self.stats.appended += 1
            self._c_append.inc()
            if len(self.memtable) >= self.cfg.seal_docs:
                self._seal_locked()
        return seq

    def seal(self) -> int:
        """Fold the current memtable into delta segment(s) now (e.g.
        before a planned shutdown or a cluster rebalance). Returns the
        number of documents sealed."""
        with self._write_lock:
            return self._seal_locked()

    def _seal_locked(self) -> int:
        """Memtable -> immutable delta segment(s) -> durable manifest ->
        WAL reset. Caller holds ``_write_lock``; with it held the
        memtable can only be ours, so copy-then-clear is exact."""
        if self._closed:
            raise RuntimeError("ingest pipeline is closed")
        docs = self.memtable.docs()
        if not docs:
            return 0
        t0 = time.perf_counter()
        last_seq = self.memtable.last_seq
        per = self.store.manifest["docs_per_segment"]
        entries = []
        for lo in range(0, len(docs), per):
            with self._state_lock:
                name = self.store._reserve_segment_name()
            # durable: the manifest below is fsynced, so the data it
            # references must hit disk first or power loss leaves a
            # durable manifest naming torn pages
            entries.append(self.store._write_segment_file(
                name, docs[lo:lo + per], durable=True))
        # disk first, then memory: a crash at the commit point leaves the
        # in-memory state (and therefore live snapshots) strictly behind
        # disk — replay reconciles; docs are never visible twice
        segs = self.store.manifest["segments"] + entries
        new_manifest = dict(self.store.manifest, segments=segs,
                            ingest_seq=last_seq)
        self.store._write_manifest(durable=True,        # commit point
                                   manifest=new_manifest)
        with self._state_lock:
            self.store.manifest["segments"] = segs
            self.store.manifest["ingest_seq"] = last_seq
            self.memtable.clear_prefix(len(docs))
            # inside the state lock so a concurrent capture never pairs
            # the new segment list with the old generation (seal adds,
            # replaces nothing — this is a pure counter bump)
            self.store.bump_generation()
        self.wal.reset()
        self.stats.seals += 1
        self._c_seal.inc()
        self._c_sealed_docs.inc(len(docs))
        self._h_seal.observe((time.perf_counter() - t0) * 1e3)
        self._compact_wake.set()
        return len(docs)

    flush = seal

    # -- read path -----------------------------------------------------
    def capture(self) -> Snapshot:
        """Atomically freeze (segment entries, memtable) for one query —
        a list copy plus a registration bump under the state lock, so
        appends never stall behind a capture and a capture costs no
        file descriptors. Registration is what keeps the view valid:
        the compactor defers GC of replaced files while any snapshot is
        live, so the snapshot's lazily opened segments always exist.
        Callers must ``close()`` the snapshot (idempotent) or deferred
        GC never drains."""
        with self._state_lock:
            entries = self.store.entries
            mem_docs = self.memtable.docs()
            mem_key = (len(mem_docs), self.memtable.last_seq)
            generation = self.store.generation
            self._live_snapshots += 1
        return Snapshot(entries, mem_docs, mem_key, generation, self)

    def _snapshot_closed(self):
        with self._state_lock:
            self._live_snapshots -= 1
            doomed = []
            if self._live_snapshots == 0 and self._graveyard:
                doomed, self._graveyard = self._graveyard, []
        for name in doomed:
            try:
                os.unlink(os.path.join(self.store.root, name))
            except FileNotFoundError:
                pass

    def _memtable_corpus(self, docs: List[Doc], key: Tuple[int, int],
                         nnz_pad: int) -> Tuple[Optional[Corpus], int]:
        """Cached ELL build of the memtable (pure function of its
        contents, which ``key`` fingerprints). Only the latest build is
        retained; a concurrent-miss recompute is benign."""
        k = key + (nnz_pad,)
        hit = self._mem_corpus_cache.get(k)
        if hit is None:
            hit = MemTable.docs_to_corpus(docs, nnz_pad)
            self._mem_corpus_cache = {k: hit}
        return hit

    # -- compaction ----------------------------------------------------
    def _fold_range(self) -> Tuple[int, List[SegmentEntry]]:
        """(start index, tail entries) of the underfull tail run worth
        folding, or (len, [])."""
        per = self.store.manifest["docs_per_segment"]
        with self._state_lock:
            entries = self.store.entries
        i = len(entries)
        for j, e in enumerate(entries):
            if e.n_docs < per:
                i = j
                break
        tail = entries[i:]
        if len(tail) < max(self.cfg.fold_min_segments, 2):
            return len(entries), []
        return i, tail

    def compact_once(self) -> int:
        """Fold the underfull tail run into full segments. Streaming and
        segment writes happen with no lock held; only the manifest swap
        takes the write lock, so appends stall for microseconds and
        readers never stall at all. Returns segments folded (0 = no-op).
        Serialized by ``_compact_lock`` (compactor thread vs manual
        calls)."""
        with self._compact_lock:
            return self._compact_once_locked()

    def _compact_once_locked(self) -> int:
        i, tail = self._fold_range()
        if not tail:
            return 0
        t0 = time.perf_counter()
        per = self.store.manifest["docs_per_segment"]
        buf: List[Doc] = []
        new_entries: List[Dict] = []

        def flush_chunk(final=False):
            while len(buf) >= per or (final and buf):
                with self._state_lock:
                    name = self.store._reserve_segment_name()
                # durable: the fold's commit unlinks the old (possibly
                # long-durable) tail, so its replacement must be on disk
                # before the fsynced manifest references it
                new_entries.append(self.store._write_segment_file(
                    name, buf[:per], durable=True))
                del buf[:per]

        for e in tail:       # immutable files: no lock while streaming
            with segment_lib.Segment(
                    os.path.join(self.store.root, e.name)) as seg:
                buf.extend(seg.docs())
            flush_chunk()
        flush_chunk(final=True)
        with self._write_lock:
            # stable with the write lock held: only seals and other
            # commits mutate the list, and they all take this lock
            cur = self.store.manifest["segments"]
            # seals only ever append, so [i : i+len(tail)] is still
            # exactly the run we folded; anything after it arrived
            # during the fold and must survive the swap
            assert [e["name"] for e in cur[i:i + len(tail)]] \
                == [e.name for e in tail]
            segs = cur[:i] + new_entries + cur[i + len(tail):]
            self.store._write_manifest(                 # commit point
                durable=True,
                manifest=dict(self.store.manifest, segments=segs))
            with self._state_lock:
                self.store.manifest["segments"] = segs
                # GC the replaced files — unless a registered snapshot
                # may still lazily open them, in which case they wait in
                # the graveyard until the last snapshot closes (a crash
                # before then leaves orphans; compact() GCs those)
                doomed = [] if self._live_snapshots else \
                    [e.name for e in tail]
                if not doomed:
                    self._graveyard.extend(e.name for e in tail)
            # precise cache invalidation (DESIGN.md §4.2): the folded
            # tail names are out of the live manifest; a snapshot that
            # still scores one reloads it from the graveyard (a miss)
            self.store.bump_generation(removed=[e.name for e in tail])
        for name in doomed:
            try:
                os.unlink(os.path.join(self.store.root, name))
            except FileNotFoundError:
                pass
        self.stats.compactions += 1
        self.stats.segments_folded += len(tail)
        self._c_fold.inc()
        self._c_folded.inc(len(tail))
        self._h_fold.observe((time.perf_counter() - t0) * 1e3)
        log.info("compactor(%s): folded %d tail segment(s) into %d",
                 self.store.root, len(tail), len(new_entries))
        return len(tail)

    def _compact_loop(self):
        while not self._closed:
            self._compact_wake.wait(timeout=self.cfg.compact_poll_s)
            self._compact_wake.clear()
            if self._closed:
                return
            try:
                self.compact_once()
            except Exception:               # keep serving; next seal retries
                log.exception("compactor(%s): fold failed", self.store.root)

    # -- lifecycle -----------------------------------------------------
    def close(self, *, seal: bool = False):
        """Stop the compactor and close the WAL. Unsealed documents stay
        in the WAL and are replayed on the next open; pass ``seal=True``
        to fold them into segments first."""
        if self._closed:
            return
        if seal:
            self.seal()
        with self._write_lock:
            # under the write lock: an append that lost the race to us
            # sees _closed and raises instead of writing a closed WAL
            if self._closed:
                return
            self._closed = True
            self.wal.close()
        # join outside the lock — a mid-fold compactor needs it to commit
        self._compact_wake.set()
        if self._compactor is not None:
            self._compactor.join(timeout=10)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
