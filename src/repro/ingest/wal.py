"""Write-ahead log for single-document appends (DESIGN.md §6.1).

The flash tier's segment format is append-hostile by design: its pages,
vocabulary filter, and footer are immutable once written, which is what
makes in-storage filtering fast. Live appends therefore land in a plain
append-only log first and become segments later (seal), the classic
LSM/WAL split SpANNS applies to sparse-vector indices.

Layout (`wal.log` in the store root):

    [magic "RSPWAL1\\n"]
    [record 0 | record 1 | ...]

    record: [u32 LE payload_len][u32 LE crc32(seq || payload)]
            [u64 LE seq][payload]

The payload is one document in the Fig. 8 stream encoding
(``core/stream_format``), so the WAL reuses the exact codec the
segments persist — replay cannot drift from the segment write path.
``seq`` is monotonically increasing; the store manifest records the
highest sequence folded into durable segments (``ingest_seq``), so
replay after a crash skips records the seal already committed and a
crash between manifest swap and WAL reset cannot duplicate documents.

Torn tails are expected (a crash mid-record): ``open`` scans records,
verifies each CRC, truncates the file back to the last intact record,
and replays the survivors. A torn record loses only the single
not-yet-acknowledged document it held.
"""
from __future__ import annotations

import logging
import os
import struct
import zlib
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import stream_format

MAGIC = b"RSPWAL1\n"
_HDR = struct.Struct("<II")      # payload_len, crc32
_SEQ = struct.Struct("<Q")       # sequence number

log = logging.getLogger(__name__)

Doc = Tuple[int, Sequence[Tuple[int, int]]]


class WriteAheadLog:
    """Append-only, checksummed document log. Not thread-safe: the
    ingest pipeline serializes writers behind its write lock."""

    def __init__(self, path: str, *, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        self._records: List[Tuple[int, Doc]] = []
        self.last_seq = 0
        # repairs performed while opening (torn header rewrites + torn
        # tail truncations); the pipeline mirrors this into the §8
        # registry so crash-recovery events are visible fleet-wide
        self.repairs = 0
        if os.path.exists(path):
            self._records = self._scan_and_repair()
            if self._records:
                self.last_seq = self._records[-1][0]
            self._f = open(path, "ab")
        else:
            self._f = open(path, "wb")
            self._f.write(MAGIC)
            self._f.flush()

    # -- recovery ------------------------------------------------------
    def _scan_and_repair(self) -> List[Tuple[int, Doc]]:
        """Read every intact record; truncate a torn tail in place."""
        with open(self.path, "rb") as f:
            raw = f.read()
        if len(raw) < len(MAGIC):
            # crash between creating the file and the magic reaching
            # disk: a torn *header* is as expected as a torn tail —
            # rewrite as a fresh, empty log rather than bricking ingest
            log.warning("wal(%s): torn %d-byte header; rewriting empty",
                        self.path, len(raw))
            self.repairs += 1
            with open(self.path, "wb") as f:
                f.write(MAGIC)
            return []
        if raw[:len(MAGIC)] != MAGIC:
            # a full header that reads differently is a foreign file,
            # not a torn write — refuse to clobber it
            raise ValueError(f"{self.path}: bad WAL magic")
        records: List[Tuple[int, Doc]] = []
        off = len(MAGIC)
        good = off
        while off + _HDR.size <= len(raw):
            n, crc = _HDR.unpack_from(raw, off)
            body = raw[off + _HDR.size:off + _HDR.size + _SEQ.size + n]
            if len(body) < _SEQ.size + n or zlib.crc32(body) != crc:
                break                      # torn tail: stop at last good
            (seq,) = _SEQ.unpack_from(body)
            payload = np.frombuffer(body, dtype="<u4", offset=_SEQ.size)
            docs = stream_format.decode(payload)
            if len(docs) != 1:
                break                      # garbled but CRC-valid? stop
            records.append((seq, docs[0]))
            off += _HDR.size + _SEQ.size + n
            good = off
        if good < len(raw):
            log.warning("wal(%s): truncating %d torn byte(s) at offset %d",
                        self.path, len(raw) - good, good)
            self.repairs += 1
            with open(self.path, "r+b") as f:
                f.truncate(good)
        return records

    # -- write path ----------------------------------------------------
    def append(self, doc: Doc) -> int:
        """Durably (modulo ``fsync``) log one document; returns its seq."""
        seq = self.last_seq + 1
        payload = stream_format.encode([doc]).astype("<u4").tobytes()
        body = _SEQ.pack(seq) + payload
        self._f.write(_HDR.pack(len(payload), zlib.crc32(body)))
        self._f.write(body)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.last_seq = seq
        self._records.append((seq, doc))
        return seq

    def reset(self):
        """Discard every record (they are durable in segments now). The
        caller must have committed the manifest first; ``last_seq`` keeps
        counting so sequence numbers never repeat within a process."""
        self._f.close()
        self._f = open(self.path, "wb")
        self._f.write(MAGIC)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._records = []

    # -- read path -----------------------------------------------------
    def records(self, after_seq: int = 0) -> List[Tuple[int, Doc]]:
        """(seq, doc) for every logged record with seq > ``after_seq``."""
        return [(s, d) for s, d in self._records if s > after_seq]

    @property
    def n_records(self) -> int:
        return len(self._records)

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
