"""MemTable — the searchable, not-yet-durable tail of a live store
(DESIGN.md §6.1).

Documents a writer has appended (and the WAL has logged) but no seal has
folded into a segment yet. It is a plain ordered list of ``(seq, doc)``
pairs; ``to_corpus`` round-trips through the Fig. 8 codec
(``encode`` → ``decode_to_ell``) so a memtable document is scored with
*exactly* the truncation and dtype behavior a segment-resident copy
would get — the bit-equivalence contract of the ingest tier rests on
that shared codec.

Mutations happen only under the ingest pipeline's state lock; snapshot
capture copies the (immutable-tuple) doc list, so a reader never
observes a half-applied append or seal.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import stream_format
from repro.core.corpus import Corpus

Doc = Tuple[int, Sequence[Tuple[int, int]]]


class MemTable:
    def __init__(self):
        self._entries: List[Tuple[int, Doc]] = []

    def add(self, seq: int, doc: Doc):
        self._entries.append((seq, doc))

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def last_seq(self) -> int:
        return self._entries[-1][0] if self._entries else 0

    def docs(self) -> List[Doc]:
        """Copy of the documents in append order (tuples are immutable,
        so the copy is safe to use outside the state lock)."""
        return [d for _, d in self._entries]

    def clear_prefix(self, n: int):
        """Drop the ``n`` oldest entries (just sealed into a segment)."""
        del self._entries[:n]

    @staticmethod
    def docs_to_corpus(docs: Sequence[Doc],
                       nnz_pad: int) -> Tuple[Optional[Corpus], int]:
        """Docs -> (Corpus, pairs_truncated) via the segment codec, or
        (None, 0) when empty."""
        if not docs:
            return None, 0
        stream = stream_format.encode(docs)
        doc_ids, ids, vals, norms, n_trunc = stream_format.decode_to_ell(
            stream, nnz_pad)
        return Corpus(doc_ids, ids, vals, norms), n_trunc
