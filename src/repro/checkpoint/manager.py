"""Sharded, atomic, async checkpointing with elastic resharding.

Layout:  <dir>/step_<N>/
           manifest.json        tree structure, shapes, dtypes, mesh info
           arr_<i>.npy          one file per leaf (gathered host value)
         <dir>/step_<N>.tmp/    written first, atomically renamed

- Atomic commit: a checkpoint is visible iff the rename completed, so a
  preemption mid-write can never corrupt the latest checkpoint.
- Async: ``save_async`` snapshots to host (jax.device_get) then writes on a
  background thread — training continues during serialization.
- Elastic: ``restore`` takes the *current* mesh/shardings; arrays saved on
  any mesh shape restore onto any other (the host .npy is the full logical
  array; device_put reshards). For multi-TB runs this becomes per-shard
  files keyed by PartitionSpec — the manifest already records the spec to
  allow that extension.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

from repro.train.optimizer import QTensor


def _to_disk(a: np.ndarray):
    """numpy can't serialize bfloat16 natively: store as uint16 view."""
    a = np.asarray(a)
    if a.dtype == ml_dtypes.bfloat16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _from_disk(a: np.ndarray, dtype: str):
    if dtype == "bfloat16":
        return a.view(ml_dtypes.bfloat16)
    return a


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QTensor))


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # -- write ---------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        host = self._snapshot(tree)
        self._write(step, host, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        host = self._snapshot(tree)   # device->host copy happens here
        t = threading.Thread(target=self._write, args=(step, host,
                                                       extra or {}))
        t.start()
        self._pending = t

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _snapshot(self, tree):
        flat, treedef = _flatten(tree)
        leaves = []
        for path, leaf in flat:
            if isinstance(leaf, QTensor):
                leaves.append((path, "qtensor",
                               (np.asarray(jax.device_get(leaf.q)),
                                np.asarray(jax.device_get(leaf.scale)),
                                leaf.shape)))
            else:
                leaves.append((path, "array",
                               np.asarray(jax.device_get(leaf))))
        return leaves, treedef

    def _write(self, step: int, host, extra: Dict):
        leaves, treedef = host
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for i, (path, kind, val) in enumerate(leaves):
            entry = {"path": _path_str(path), "kind": kind, "files": []}
            if kind == "qtensor":
                q, s, shape = val
                np.save(os.path.join(tmp, f"arr_{i}_q.npy"), q)
                np.save(os.path.join(tmp, f"arr_{i}_s.npy"), s)
                entry["files"] = [f"arr_{i}_q.npy", f"arr_{i}_s.npy"]
                entry["shape"] = list(shape)
            else:
                raw, dt = _to_disk(val)
                np.save(os.path.join(tmp, f"arr_{i}.npy"), raw)
                entry["files"] = [f"arr_{i}.npy"]
                entry["dtype"] = dt
            manifest["leaves"].append(entry)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None):
        """Restore into the structure of ``like`` (values replaced), placed
        with ``shardings`` (tree of NamedSharding or None) — mesh shape may
        differ from save time (elastic resharding)."""
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        flat, treedef = _flatten(like)
        sh_flat = None
        if shardings is not None:
            sh_flat = [s for _, s in _flatten(shardings)[0]]
        out = []
        for i, (path, leaf) in enumerate(flat):
            e = by_path[_path_str(path)]
            if e["kind"] == "qtensor":
                q = np.load(os.path.join(d, e["files"][0]))
                s = np.load(os.path.join(d, e["files"][1]))
                val = QTensor(q=q, scale=s, shape=tuple(e["shape"]))
            else:
                val = np.load(os.path.join(d, e["files"][0]))
                val = _from_disk(val, e.get("dtype", str(val.dtype)))
                if hasattr(leaf, "dtype") and val.dtype != leaf.dtype:
                    val = val.astype(leaf.dtype)
            if sh_flat is not None and sh_flat[i] is not None:
                if isinstance(val, QTensor):
                    val = QTensor(q=jax.device_put(val.q, sh_flat[i].q),
                                  scale=jax.device_put(val.scale,
                                                       sh_flat[i].scale),
                                  shape=val.shape)
                else:
                    val = jax.device_put(val, sh_flat[i])
            out.append(val)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
