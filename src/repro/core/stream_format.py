"""The paper's 32-bit stream encoding (Fig. 8).

Each 32-bit item is either a pattern (document) identifier or a key/value
pair. We use bit 31 as the header flag:

    header:  [1 | docID (31 bits)]
    pair:    [0 | wordID (19 bits) | count (12 bits, saturating)]

19 bits of wordID covers the paper's 141k-word vocabulary (and up to 512k);
12-bit counts saturate at 4095 (word frequencies beyond that carry no
cosine-relevant information at these sparsities). A document is one header
followed by its (sorted) key/value pairs — the paper measured ~50% storage-
bandwidth savings over the UCI one-tuple-per-line format, which we verify in
tests/test_stream_format.py.

The numpy codec is the host/storage data plane; ``decode_to_ell`` is the
device-side ingest ("flash interface logic" analogue) producing MXU-aligned
ELL tiles.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import numpy as np

HEADER_BIT = np.uint32(1 << 31)
KEY_BITS = 19
VAL_BITS = 12
KEY_MASK = (1 << KEY_BITS) - 1
VAL_MASK = (1 << VAL_BITS) - 1
MAX_DOC_ID = (1 << 31) - 1


def encode(docs: Sequence[Tuple[int, Sequence[Tuple[int, int]]]]) -> np.ndarray:
    """docs: [(doc_id, [(word_id, count), ...]), ...] -> uint32 stream.
    Pairs are sorted by word_id (the paper streams sorted keys)."""
    out: List[np.ndarray] = []
    for doc_id, pairs in docs:
        if not 0 <= doc_id <= MAX_DOC_ID:
            raise ValueError(f"doc_id {doc_id} out of range")
        arr = np.empty(len(pairs) + 1, np.uint32)
        arr[0] = HEADER_BIT | np.uint32(doc_id)
        sp = sorted(pairs)
        for i, (w, c) in enumerate(sp):
            if not 0 <= w <= KEY_MASK:
                raise ValueError(f"word_id {w} out of range")
            arr[i + 1] = (np.uint32(w) << VAL_BITS) | np.uint32(min(c, VAL_MASK))
        out.append(arr)
    return np.concatenate(out) if out else np.empty(0, np.uint32)


def decode(stream: np.ndarray):
    """uint32 stream -> [(doc_id, [(word_id, count), ...]), ...]."""
    stream = np.asarray(stream, np.uint32)
    is_hdr = (stream & HEADER_BIT) != 0
    docs = []
    cur = None
    for item, hdr in zip(stream.tolist(), is_hdr.tolist()):
        if hdr:
            cur = (item & MAX_DOC_ID, [])
            docs.append(cur)
        else:
            if cur is None:
                raise ValueError("pair before any header")
            cur[1].append(((item >> VAL_BITS) & KEY_MASK, item & VAL_MASK))
    return docs


def decode_to_ell(stream: np.ndarray, nnz_pad: int):
    """Vectorized stream -> ELL tiles (ids padded with -1, float32 values,
    fp32 L2 norms) plus the number of pairs dropped because their document
    exceeded ``nnz_pad``. This is the ingest path the engine uses; callers
    that care about exactness must check ``n_truncated == 0``.

    Returns ``(doc_ids, ids, vals, norms, n_truncated)``.
    """
    stream = np.asarray(stream, np.uint32)
    is_hdr = (stream & HEADER_BIT) != 0
    n_docs = int(is_hdr.sum())
    if n_docs == 0:
        return (np.empty((0,), np.int64), np.full((0, nnz_pad), -1, np.int32),
                np.zeros((0, nnz_pad), np.float32), np.zeros((0,), np.float32),
                0)
    hdr_pos = np.flatnonzero(is_hdr)
    doc_ids = (stream[hdr_pos] & MAX_DOC_ID).astype(np.int64)
    # for every item, which document segment it belongs to
    seg = np.cumsum(is_hdr) - 1
    pair_mask = ~is_hdr
    pair_seg = seg[pair_mask]
    words = ((stream[pair_mask] >> VAL_BITS) & KEY_MASK).astype(np.int32)
    counts = (stream[pair_mask] & VAL_MASK).astype(np.float32)
    # position of each pair within its document
    idx = np.arange(stream.size)[pair_mask]
    pos = idx - hdr_pos[pair_seg] - 1
    keep = pos < nnz_pad  # truncate docs longer than the pad
    n_truncated = int((~keep).sum())
    ids = np.full((n_docs, nnz_pad), -1, np.int32)
    vals = np.zeros((n_docs, nnz_pad), np.float32)
    ids[pair_seg[keep], pos[keep]] = words[keep]
    vals[pair_seg[keep], pos[keep]] = counts[keep]
    norms = np.sqrt((vals.astype(np.float64) ** 2).sum(1)).astype(np.float32)
    return doc_ids, ids, vals, norms, n_truncated


def stream_bytes(docs) -> int:
    """Size of the Fig. 8 encoding."""
    return sum(4 * (1 + len(p)) for _, p in docs)


def uci_bytes(docs) -> int:
    """Size of the UCI-style (docID, wordID, count) per-line binary format
    the paper compares against (8 bytes/tuple with 32-bit docID+packed
    word/count — we charge 2 items of 4B per tuple)."""
    return sum(8 * len(p) for _, p in docs)
