"""Corpus construction: bag-of-words datasets, the paper's synthesizer,
protein 3-mer encoding (Fig. 6) and subgraph-edge encoding (Fig. 5).

A corpus is held in ELL form (DESIGN.md §2): ``ids [n_docs, K]`` int32
(-1 padding), ``vals [n_docs, K]`` float32, ``doc_ids [n_docs]``,
``norms [n_docs]`` — K a multiple of the kernel tile so HBM->VMEM streaming
is aligned.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import stream_format


@dataclasses.dataclass
class Corpus:
    doc_ids: np.ndarray   # [n] int64
    ids: np.ndarray       # [n, K] int32, -1 padded, sorted per row
    vals: np.ndarray      # [n, K] float32
    norms: np.ndarray     # [n] float32

    @property
    def n_docs(self) -> int:
        return self.ids.shape[0]

    @property
    def nnz_pad(self) -> int:
        return self.ids.shape[1]

    @classmethod
    def empty(cls, nnz_pad: int) -> "Corpus":
        return cls(np.empty(0, np.int64),
                   np.full((0, nnz_pad), -1, np.int32),
                   np.zeros((0, nnz_pad), np.float32),
                   np.zeros(0, np.float32))

    def slice_rows(self, lo: int, hi: int) -> "Corpus":
        return Corpus(self.doc_ids[lo:hi], self.ids[lo:hi],
                      self.vals[lo:hi], self.norms[lo:hi])

    def pad_docs_to(self, n: int) -> "Corpus":
        """Pad with empty documents (id -1) so n_docs divides the mesh."""
        extra = n - self.n_docs
        if extra <= 0:
            return self
        K = self.nnz_pad
        return Corpus(
            np.concatenate([self.doc_ids, np.full(extra, -1, np.int64)]),
            np.concatenate([self.ids, np.full((extra, K), -1, np.int32)]),
            np.concatenate([self.vals, np.zeros((extra, K), np.float32)]),
            np.concatenate([self.norms, np.zeros(extra, np.float32)]),
        )


def from_stream(stream: np.ndarray, nnz_pad: int, *,
                strict: bool = False) -> Corpus:
    """Fig. 8 uint32 stream -> Corpus. ``strict`` raises if any document
    had pairs truncated to fit ``nnz_pad`` (decode_to_ell reports the
    count; silent truncation changes scores)."""
    doc_ids, ids, vals, norms, n_trunc = stream_format.decode_to_ell(
        stream, nnz_pad)
    if strict and n_trunc:
        raise ValueError(
            f"{n_trunc} pairs truncated decoding stream at nnz_pad={nnz_pad}")
    return Corpus(doc_ids, ids, vals, norms)


def from_tuples(tuples: Sequence[Tuple[int, int, int]], nnz_pad: int) -> Corpus:
    """UCI-style {docID, wordID, count} tuples -> Corpus (via the Fig. 8
    stream, exercising the paper's ingest path)."""
    by_doc: Dict[int, List[Tuple[int, int]]] = {}
    for d, w, c in tuples:
        by_doc.setdefault(d, []).append((w, c))
    docs = sorted(by_doc.items())
    stream = stream_format.encode(docs)
    return from_stream(stream, nnz_pad)


def synthesize(n_docs: int, vocab_size: int, avg_nnz: int, nnz_pad: int,
               seed: int = 0, zipf: float = 1.1) -> Corpus:
    """The paper's dataset synthesizer (§IV.A): generate documents as
    permutations of word sets with random add/remove and random counts.
    Word frequencies follow a Zipf-ish distribution like real text."""
    rng = np.random.default_rng(seed)
    n_base = max(1, n_docs // 16)
    lens = np.clip(rng.poisson(avg_nnz, n_docs), 1, nnz_pad).astype(np.int64)
    ids = np.full((n_docs, nnz_pad), -1, np.int32)
    vals = np.zeros((n_docs, nnz_pad), np.float32)
    # base "topics": each a word set; documents permute a base set
    ranks = rng.zipf(zipf, size=(n_base, nnz_pad * 2)) % vocab_size
    for i in range(n_docs):
        base = ranks[rng.integers(n_base)]
        take = lens[i]
        words = rng.choice(base, take, replace=False) if take <= base.size \
            else base
        # random add/remove (the paper's permutation step)
        n_mut = max(1, take // 8)
        words[:n_mut] = rng.integers(0, vocab_size, n_mut)
        words = np.unique(words.astype(np.int32))
        k = words.size
        ids[i, :k] = np.sort(words)
        vals[i, :k] = rng.integers(1, 30, k).astype(np.float32)
    norms = np.sqrt((vals ** 2).sum(1)).astype(np.float32)
    return Corpus(np.arange(n_docs, dtype=np.int64), ids, vals, norms)


# ---------------------------------------------------------------------------
# protein 3-mers (Fig. 6)
# ---------------------------------------------------------------------------
AMINO = "ACDEFGHIKLMNPQRSTVWY"
_A2I = {c: i for i, c in enumerate(AMINO)}


def protein_to_bow(seq: str) -> List[Tuple[int, int]]:
    """Bag-of-words of all 3-mers; wordID = base-20 encoding of the 3-mer
    (vocab 8000, well inside the 19-bit key space)."""
    counts: Dict[int, int] = {}
    s = [c for c in seq.upper() if c in _A2I]
    for i in range(len(s) - 2):
        wid = _A2I[s[i]] * 400 + _A2I[s[i + 1]] * 20 + _A2I[s[i + 2]]
        counts[wid] = counts.get(wid, 0) + 1
    return sorted(counts.items())


def proteins_corpus(seqs: Sequence[str], nnz_pad: int = 256) -> Corpus:
    docs = [(i, protein_to_bow(s)) for i, s in enumerate(seqs)]
    stream = stream_format.encode(docs)
    return from_stream(stream, nnz_pad)


# ---------------------------------------------------------------------------
# subgraph edges (Fig. 5)
# ---------------------------------------------------------------------------
def subgraph_to_bow(edges: Sequence[Tuple[int, int]], n_labels: int
                    ) -> List[Tuple[int, int]]:
    """Each edge becomes a 'word' of its two vertex labels (order-free)."""
    counts: Dict[int, int] = {}
    for a, b in edges:
        lo, hi = min(a, b) % n_labels, max(a, b) % n_labels
        wid = lo * n_labels + hi
        counts[wid] = counts.get(wid, 0) + 1
    return sorted(counts.items())


def subgraphs_corpus(graphs: Sequence[Sequence[Tuple[int, int]]],
                     n_labels: int = 512, nnz_pad: int = 128) -> Corpus:
    docs = [(i, subgraph_to_bow(g, n_labels)) for i, g in enumerate(graphs)]
    stream = stream_format.encode(docs)
    return from_stream(stream, nnz_pad)


# ---------------------------------------------------------------------------
# queries
# ---------------------------------------------------------------------------
def make_query(corpus: Corpus, doc_index: int, max_nnz: int):
    """Query = an existing document (self-search must return itself)."""
    ids = corpus.ids[doc_index]
    vals = corpus.vals[doc_index]
    keep = ids >= 0
    q_ids = np.full(max_nnz, -1, np.int32)
    q_vals = np.zeros(max_nnz, np.float32)
    k = min(int(keep.sum()), max_nnz)
    q_ids[:k] = ids[keep][:k]
    q_vals[:k] = vals[keep][:k]
    return q_ids, q_vals
