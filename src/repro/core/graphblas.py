"""GraphBLAS-style kernels over the engine's ELL format (paper §VI:
"We are also currently developing GraphBLAS compliant operations in our
system for common graph and sparse linear algebra problems").

The adjacency matrix reuses the corpus ELL layout (ids [n, K] = neighbor
indices, -1 padded; vals [n, K] = edge weights), so the same sharded
streaming machinery (rows over (pod, data)) serves graph kernels. Three
core semirings + PageRank (the paper cites the PageRank Pipeline Benchmark
[22]) and BFS as worked examples.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

INF = jnp.float32(jnp.inf)


def _gather(x: Array, ids: Array, fill: float) -> Array:
    """x[ids] with -1 padding -> fill."""
    safe = jnp.clip(ids, 0, x.shape[0] - 1)
    return jnp.where(ids >= 0, x[safe], fill)


def spmv_plus_times(ids: Array, vals: Array, x: Array) -> Array:
    """Standard (+, *) semiring: y = A @ x. ids/vals: [n, K]."""
    g = _gather(x, ids, 0.0)
    return (vals * g).sum(axis=1)


def spmv_min_plus(ids: Array, vals: Array, x: Array) -> Array:
    """(min, +) semiring: shortest-path relaxation step."""
    g = _gather(x, ids, INF)
    cand = jnp.where(ids >= 0, vals + g, INF)
    return jnp.minimum(x, cand.min(axis=1))


def spmv_max_times(ids: Array, vals: Array, x: Array) -> Array:
    """(max, *) semiring: max-reliability / widest-path style."""
    g = _gather(x, ids, 0.0)
    return jnp.maximum(x, (vals * g).max(axis=1))


def out_degree(ids: Array) -> Array:
    return (ids >= 0).sum(axis=1)


def pagerank(ids_in: Array, vals_in: Array, out_deg: Array, *,
             damping: float = 0.85, iters: int = 50) -> Array:
    """PageRank over an *incoming*-edges ELL (row r lists sources s with
    edge weight 1): pr = (1-d)/n + d * A_in @ (pr / out_deg)."""
    n = ids_in.shape[0]
    pr = jnp.full((n,), 1.0 / n, jnp.float32)
    deg = jnp.maximum(out_deg.astype(jnp.float32), 1.0)

    def body(pr, _):
        contrib = spmv_plus_times(ids_in, vals_in, pr / deg)
        # dangling mass redistributed uniformly
        dangling = jnp.where(out_deg == 0, pr, 0.0).sum()
        pr = (1 - damping) / n + damping * (contrib + dangling / n)
        return pr, None

    pr, _ = jax.lax.scan(body, pr, None, length=iters)
    return pr


def bfs_levels(ids_out: Array, src: int, max_iters: int = 0) -> Array:
    """BFS level per vertex via (min, +) relaxation on unit weights."""
    n = ids_out.shape[0]
    iters = max_iters or n
    dist = jnp.full((n,), INF).at[src].set(0.0)
    ones = jnp.ones(ids_out.shape, jnp.float32)

    def body(d, _):
        return spmv_min_plus(ids_out, ones, d), None

    # relax along OUT edges: dist[v] = min(dist[v], min_u->v dist[u]+1);
    # ids_out rows must list incoming neighbors for pull-style relaxation,
    # so callers pass the reversed adjacency (see tests)
    dist, _ = jax.lax.scan(body, dist, None, length=iters)
    return dist
