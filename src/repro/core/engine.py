"""PatternSearchEngine — the paper's in-storage accelerator as a sharded
TPU service (DESIGN.md §2).

The corpus lives sharded across chip HBM: doc rows over the (pod, data)
mesh axes — the paper's K corpus partitions — and the merged query batch's
L value-columns over the ``model`` axis — the paper's L. Each device is one
"accelerator kernel": it scores its corpus shard against its query slice
(Pallas kernel on TPU, gather path on CPU), takes a local top-k, and a
hierarchical reduction returns the global winners. Only queries (in) and
top-k (out) cross the interconnect; the corpus never moves.

Streaming mode handles corpora larger than aggregate HBM: fixed-size
resident slabs are scored while the next slab is transferred
(double-buffered, epoch-tagged — the prefetch-predictor analogue at host
scope), with top-k merged across slabs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.paper_search import SearchConfig
from repro.core import topk as topk_lib
from repro.core.corpus import Corpus
from repro.distributed.meshctx import MeshCtx
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass
class SearchResult:
    doc_ids: np.ndarray   # [L, k] int64 (-1 for no result)
    scores: np.ndarray    # [L, k] cosine


class PatternSearchEngine:
    def __init__(self, corpus: Corpus, cfg: SearchConfig, ctx: MeshCtx,
                 backend: str = "jnp"):
        self.cfg = cfg
        self.ctx = ctx
        self.backend = backend
        if corpus.ids.size and int(corpus.ids.max()) >= cfg.vocab_size:
            raise ValueError(
                f"corpus word ids reach {int(corpus.ids.max())} but "
                f"cfg.vocab_size={cfg.vocab_size}")
        ndev = ctx.mesh.size
        rows = ctx.dp_size
        n = -(-corpus.n_docs // rows) * rows
        corpus = corpus.pad_docs_to(n)
        self.corpus = corpus
        spec = P(ctx.dp_axes, None)
        self.d_ids = jax.device_put(corpus.ids,
                                    NamedSharding(ctx.mesh, spec))
        self.d_vals = jax.device_put(corpus.vals,
                                     NamedSharding(ctx.mesh, spec))
        self.d_norms = jax.device_put(corpus.norms,
                                      NamedSharding(ctx.mesh, P(ctx.dp_axes)))
        self.d_docids = jax.device_put(corpus.doc_ids.astype(np.int32),
                                       NamedSharding(ctx.mesh, P(ctx.dp_axes)))
        self._search_fn = self._build(ndev)

    # ------------------------------------------------------------------
    def _build(self, ndev: int):
        cfg, ctx, backend = self.cfg, self.ctx, self.backend
        tp = ctx.tp_axis
        dp = ctx.dp_axes

        def local_score(ids, vals, norms, docids, q_ids, q_vals, q_norms):
            """Per-device: score local corpus shard x local query columns."""
            corr = kops.correlate(
                ids, vals, q_ids, q_vals, backend=backend,
                vocab_size=cfg.vocab_size, block_docs=cfg.block_docs,
                block_query=cfg.block_query)
            cos = kops.cosine_scores(corr, norms, q_norms)
            v, i = topk_lib.local_topk(cos, docids, cfg.top_k)
            # reduce across the corpus-shard (K) axes — paper's report path
            for ax in dp:
                v, i = topk_lib.tree_topk(v, i, cfg.top_k, ax)
            return v, i

        qcols_spec = P(None, tp)  # L value-columns over the model axis

        @jax.jit
        def search(ids, vals, norms, docids, q_ids, q_vals, q_norms):
            f = shard_map(
                local_score, mesh=ctx.mesh,
                in_specs=(P(dp, None), P(dp, None), P(dp), P(dp),
                          P(None), qcols_spec, P(tp)),
                out_specs=(P(tp, None), P(tp, None)),
                check_vma=False)
            return f(ids, vals, norms, docids, q_ids, q_vals, q_norms)

        return search

    # ------------------------------------------------------------------
    def search(self, q_ids: np.ndarray, q_vals: np.ndarray) -> SearchResult:
        """q_ids/q_vals: [L, Qn] (pad < 0). L is padded to the model-axis
        size (the paper's L query batch)."""
        L_ = q_ids.shape[0]
        tp = self.ctx.tp_size
        Lp = -(-L_ // tp) * tp
        if Lp != L_:
            pad_i = np.full((Lp - L_, q_ids.shape[1]), -1, q_ids.dtype)
            pad_v = np.zeros((Lp - L_, q_vals.shape[1]), q_vals.dtype)
            q_ids = np.concatenate([q_ids, pad_i])
            q_vals = np.concatenate([q_vals, pad_v])
        mi, mv = kops.merge_queries(q_ids, q_vals)
        # pad the merged stream to the query block
        pad = -(-mi.size // self.cfg.block_query) * self.cfg.block_query
        mi = np.pad(mi, (0, pad - mi.size), constant_values=-2)
        mv = np.pad(mv, ((0, pad - mv.shape[0]), (0, 0)))
        q_norms = np.sqrt((np.where(q_vals > 0, q_vals, 0) ** 2).sum(1))
        q_norms = np.maximum(q_norms, 1e-12).astype(np.float32)
        v, i = self._search_fn(
            self.d_ids, self.d_vals, self.d_norms, self.d_docids,
            jnp.asarray(mi), jnp.asarray(mv), jnp.asarray(q_norms))
        v = np.asarray(v)[:L_]
        i = np.asarray(i)[:L_]
        i = np.where(np.isfinite(v), i, -1)
        return SearchResult(doc_ids=i.astype(np.int64), scores=v)

    # ------------------------------------------------------------------
    def search_streaming(self, q_ids, q_vals, corpus_slabs) -> SearchResult:
        """Score a sequence of corpus slabs larger than resident memory.
        Double-buffers the next slab's device_put against the current
        score (epoch-tagged host prefetch — DESIGN.md §2)."""
        best: Optional[SearchResult] = None
        next_dev = None
        slabs = list(corpus_slabs)
        for idx, slab in enumerate(slabs):
            if next_dev is None:
                next_dev = self._put_slab(slab)
            cur = next_dev
            if idx + 1 < len(slabs):  # prefetch the next slab (async)
                next_dev = self._put_slab(slabs[idx + 1])
            else:
                next_dev = None
            eng = self._with_slab(cur)
            r = eng_search(eng, q_ids, q_vals)
            best = r if best is None else _merge_results(best, r,
                                                         self.cfg.top_k)
        return best

    def _put_slab(self, slab: Corpus):
        rows = self.ctx.dp_size
        slab = slab.pad_docs_to(-(-slab.n_docs // rows) * rows)
        sh = NamedSharding(self.ctx.mesh, P(self.ctx.dp_axes, None))
        sh1 = NamedSharding(self.ctx.mesh, P(self.ctx.dp_axes))
        return (jax.device_put(slab.ids, sh), jax.device_put(slab.vals, sh),
                jax.device_put(slab.norms, sh1),
                jax.device_put(slab.doc_ids.astype(np.int32), sh1))

    def _with_slab(self, dev):
        eng = object.__new__(PatternSearchEngine)
        eng.__dict__.update(self.__dict__)
        eng.d_ids, eng.d_vals, eng.d_norms, eng.d_docids = dev
        return eng


def eng_search(eng: PatternSearchEngine, q_ids, q_vals) -> SearchResult:
    return PatternSearchEngine.search(eng, q_ids, q_vals)


def _merge_results(a: SearchResult, b: SearchResult, k: int) -> SearchResult:
    ids = np.concatenate([a.doc_ids, b.doc_ids], axis=1)
    sc = np.concatenate([a.scores, b.scores], axis=1)
    order = np.argsort(-sc, axis=1)[:, :k]
    return SearchResult(np.take_along_axis(ids, order, 1),
                        np.take_along_axis(sc, order, 1))
