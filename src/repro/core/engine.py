"""PatternSearchEngine — the paper's in-storage accelerator as a sharded
TPU service (DESIGN.md §2).

The corpus lives sharded across chip HBM: doc rows over the (pod, data)
mesh axes — the paper's K corpus partitions — and the merged query batch's
L value-columns over the ``model`` axis — the paper's L. Each device is one
"accelerator kernel": it scores its corpus shard against its query slice
(Pallas kernel on TPU, gather path on CPU), takes a local top-k, and a
hierarchical reduction returns the global winners. Only queries (in) and
top-k (out) cross the interconnect; the corpus never moves.

Streaming mode handles corpora larger than aggregate HBM: fixed-size
resident slabs are scored while the next slab is transferred
(double-buffered, epoch-tagged — the prefetch-predictor analogue at host
scope), with top-k merged across slabs.

Serving mode (DESIGN.md §7) feeds ``search`` micro-batches of varying L
from the SearchService coalescer. To keep variable L cheap, query shapes
are *bucketed*: L pads to the next power-of-two multiple of the model
axis, and the merged id stream pads to a capacity proportional to that L
bucket — so a session that serves batches of any size up to ``max_batch``
compiles at most ``log2(max_batch) + 1`` programs instead of one per
distinct shape. ``compile_stats`` reports the traces actually taken.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from repro.distributed.compat import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.paper_search import SearchConfig
from repro.core import topk as topk_lib
from repro.core.corpus import Corpus
from repro.distributed.meshctx import MeshCtx
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass
class SearchResult:
    doc_ids: np.ndarray   # [L, k] int64 (-1 for no result)
    scores: np.ndarray    # [L, k] cosine


class DeviceSlab(NamedTuple):
    """A corpus slab already uploaded and sharded over the mesh — the unit
    the streaming path scores. Produced by ``put_slab`` (or by the storage
    prefetcher's background thread, DESIGN.md §3)."""
    ids: jax.Array        # [n, K] int32
    vals: jax.Array       # [n, K] float32
    norms: jax.Array      # [n] float32
    doc_ids: jax.Array    # [n] int32


SlabLike = Union[Corpus, DeviceSlab]


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class PatternSearchEngine:
    def __init__(self, corpus: Optional[Corpus], cfg: SearchConfig,
                 ctx: MeshCtx, backend: str = "jnp", obs=None):
        """``corpus=None`` builds a streaming-only engine (no resident
        corpus): callers must use ``search_streaming`` / ``put_slab``.
        ``obs`` (a ``repro.obs.Obs``) mirrors compile traces into the
        shared metrics registry; None uses the process default."""
        from repro.obs import default_obs
        self.cfg = cfg
        self.ctx = ctx
        self.backend = backend
        self.obs = obs if obs is not None else default_obs()
        if corpus is None:
            corpus = Corpus.empty(cfg.nnz_pad)
        if corpus.ids.size and int(corpus.ids.max()) >= cfg.vocab_size:
            raise ValueError(
                f"corpus word ids reach {int(corpus.ids.max())} but "
                f"cfg.vocab_size={cfg.vocab_size}")
        ndev = ctx.mesh.size
        rows = ctx.dp_size
        n = -(-corpus.n_docs // rows) * rows
        corpus = corpus.pad_docs_to(n)
        self.corpus = corpus
        spec = P(ctx.dp_axes, None)
        self.d_ids = jax.device_put(corpus.ids,
                                    NamedSharding(ctx.mesh, spec))
        self.d_vals = jax.device_put(corpus.vals,
                                     NamedSharding(ctx.mesh, spec))
        self.d_norms = jax.device_put(corpus.norms,
                                      NamedSharding(ctx.mesh, P(ctx.dp_axes)))
        self.d_docids = jax.device_put(corpus.doc_ids.astype(np.int32),
                                       NamedSharding(ctx.mesh, P(ctx.dp_axes)))
        # compile-cache bookkeeping: one program per (L-bucket, Q-capacity,
        # n_docs) key; _trace_keys is appended at *trace* time inside the
        # jitted body, so it counts real recompiles, not call shapes
        self._trace_keys: list = []
        self._search_fn = self._build(ndev)

    # ------------------------------------------------------------------
    def _build(self, ndev: int):
        cfg, ctx, backend = self.cfg, self.ctx, self.backend
        tp = ctx.tp_axis
        dp = ctx.dp_axes

        def local_score(ids, vals, norms, docids, q_ids, q_vals, q_norms):
            """Per-device: score local corpus shard x local query columns."""
            corr = kops.correlate(
                ids, vals, q_ids, q_vals, backend=backend,
                vocab_size=cfg.vocab_size, block_docs=cfg.block_docs,
                block_query=cfg.block_query)
            cos = kops.cosine_scores(corr, norms, q_norms)
            v, i = topk_lib.local_topk(cos, docids, cfg.top_k)
            # reduce across the corpus-shard (K) axes — paper's report path
            for ax in dp:
                v, i = topk_lib.tree_topk(v, i, cfg.top_k, ax)
            return v, i

        qcols_spec = P(None, tp)  # L value-columns over the model axis
        trace_keys = self._trace_keys
        # registry handle resolved once: the jitted body's python side
        # effect stays one list append + one counter inc per real trace
        trace_counter = self.obs.registry.counter("engine_compile_traces")

        @jax.jit
        def search(ids, vals, norms, docids, q_ids, q_vals, q_norms):
            # python side effect: runs once per trace (i.e. per compiled
            # program), never on a jit cache hit
            trace_keys.append((q_norms.shape[0], q_ids.shape[0],
                               ids.shape[0]))
            trace_counter.inc()
            f = shard_map(
                local_score, mesh=ctx.mesh,
                in_specs=(P(dp, None), P(dp, None), P(dp), P(dp),
                          P(None), qcols_spec, P(tp)),
                out_specs=(P(tp, None), P(tp, None)),
                check_vma=False)
            return f(ids, vals, norms, docids, q_ids, q_vals, q_norms)

        return search

    # ------------------------------------------------------------------
    def bucket_L(self, L: int) -> int:
        """The L compile bucket: next power of two of ceil(L / tp), times
        tp — so any batch size up to ``max_batch`` lands in one of
        ``log2(max_batch) + 1`` program shapes (DESIGN.md §7)."""
        tp = self.ctx.tp_size
        return _next_pow2(-(-L // tp)) * tp

    def bucket_Q(self, q_items: int, Lp: int) -> int:
        """Merged-stream capacity for an L bucket: ``Lp * block_query``
        items, doubling (power-of-two blocks) only when the batch's merged
        stream overflows it. Queries with nnz <= block_query therefore
        never add a program shape beyond their L bucket's."""
        cap = Lp * self.cfg.block_query
        return _next_pow2(-(-max(q_items, 1) // cap)) * cap

    def search(self, q_ids: np.ndarray, q_vals: np.ndarray) -> SearchResult:
        """q_ids/q_vals: [L, Qn] (pad < 0). L is padded to its compile
        bucket (next power-of-two multiple of the model-axis size — the
        paper's L query batch, bucketed so the serving layer's variable
        batches reuse cached programs)."""
        L_ = q_ids.shape[0]
        Lp = self.bucket_L(L_)
        if Lp != L_:
            pad_i = np.full((Lp - L_, q_ids.shape[1]), -1, q_ids.dtype)
            pad_v = np.zeros((Lp - L_, q_vals.shape[1]), q_vals.dtype)
            q_ids = np.concatenate([q_ids, pad_i])
            q_vals = np.concatenate([q_vals, pad_v])
        mi, mv = kops.merge_queries(q_ids, q_vals)
        # pad the merged stream to the bucket's fixed capacity
        pad = self.bucket_Q(mi.size, Lp)
        mi = np.pad(mi, (0, pad - mi.size), constant_values=-2)
        mv = np.pad(mv, ((0, pad - mv.shape[0]), (0, 0)))
        q_norms = np.sqrt((np.where(q_vals > 0, q_vals, 0) ** 2).sum(1))
        q_norms = np.maximum(q_norms, 1e-12).astype(np.float32)
        v, i = self._search_fn(
            self.d_ids, self.d_vals, self.d_norms, self.d_docids,
            jnp.asarray(mi), jnp.asarray(mv), jnp.asarray(q_norms))
        v = np.asarray(v)[:L_]
        i = np.asarray(i)[:L_]
        i = np.where(np.isfinite(v), i, -1)
        return SearchResult(doc_ids=i.astype(np.int64), scores=v)

    # ------------------------------------------------------------------
    def search_streaming(self, q_ids, q_vals,
                         corpus_slabs: Iterable[SlabLike]) -> SearchResult:
        """Score a lazily-consumed sequence of corpus slabs larger than
        resident memory, merging top-k across slabs (DESIGN.md §2).

        Each element may be a host ``Corpus`` (uploaded here, with the next
        slab's async device_put overlapping the current slab's scoring) or
        an already-resident ``DeviceSlab`` (e.g. from the storage tier's
        background prefetcher, which overlaps disk read + decode + upload
        as well — DESIGN.md §3). The iterable is never materialized, so
        store-backed iterators stream arbitrarily large corpora."""
        best: Optional[SearchResult] = None
        it = iter(corpus_slabs)
        cur = self._as_device(next(it, None))
        if cur is None:
            return self.empty_result(q_ids.shape[0])
        while cur is not None:
            # start the next H2D transfer before scoring the current slab
            nxt = self._as_device(next(it, None))
            r = eng_search(self._with_slab(cur), q_ids, q_vals)
            best = r if best is None else _merge_results(best, r,
                                                         self.cfg.top_k)
            cur = nxt
        return best

    @property
    def compile_stats(self) -> dict:
        """Programs actually traced so far: ``n_traces`` plus the (Lp, Qp,
        n_docs) key of each. The serving acceptance bound is
        ``n_traces <= log2(max_batch) + 1`` for a session whose queries
        stay within one Q capacity per L bucket."""
        return {"n_traces": len(self._trace_keys),
                "buckets": list(self._trace_keys)}

    def empty_result(self, n_queries: int) -> SearchResult:
        """The [L, k] no-result sentinel (id -1, score -inf)."""
        k = self.cfg.top_k
        return SearchResult(np.full((n_queries, k), -1, np.int64),
                            np.full((n_queries, k), -np.inf, np.float32))

    def put_slab(self, slab: Corpus) -> DeviceSlab:
        """Upload a host slab, sharded like the resident corpus. device_put
        is async: the transfer overlaps whatever is already enqueued."""
        rows = self.ctx.dp_size
        slab = slab.pad_docs_to(-(-slab.n_docs // rows) * rows)
        sh = NamedSharding(self.ctx.mesh, P(self.ctx.dp_axes, None))
        sh1 = NamedSharding(self.ctx.mesh, P(self.ctx.dp_axes))
        return DeviceSlab(
            jax.device_put(slab.ids, sh), jax.device_put(slab.vals, sh),
            jax.device_put(slab.norms, sh1),
            jax.device_put(slab.doc_ids.astype(np.int32), sh1))

    def _as_device(self, slab: Optional[SlabLike]) -> Optional[DeviceSlab]:
        if slab is None or isinstance(slab, DeviceSlab):
            return slab
        return self.put_slab(slab)

    def _with_slab(self, dev: DeviceSlab):
        eng = object.__new__(PatternSearchEngine)
        eng.__dict__.update(self.__dict__)
        eng.d_ids, eng.d_vals, eng.d_norms, eng.d_docids = dev
        return eng


def eng_search(eng: PatternSearchEngine, q_ids, q_vals) -> SearchResult:
    return PatternSearchEngine.search(eng, q_ids, q_vals)


def _merge_results(a: SearchResult, b: SearchResult, k: int) -> SearchResult:
    """Merge two [L, k] candidate sets into the best k per row.

    Deterministic: descending score, stable within ties (a's candidates
    win over b's). Duplicate doc ids keep only their best-scoring entry,
    and no-result fillers (id < 0) never displace real candidates — any
    unfilled tail stays (-1, -inf).

    Vectorized (this runs once per slab on the serving hot path; the
    per-row Python loop it replaced was O(L*k*slabs) interpreter time —
    tests/test_merge_equivalence.py holds it to the loop's exact output)."""
    ids = np.concatenate([a.doc_ids, b.doc_ids], axis=1).astype(np.int64)
    sc = np.concatenate([a.scores, b.scores], axis=1).astype(np.float32)
    L, M = ids.shape
    # rank every candidate by descending score; stable, so a's candidates
    # win ties against b's and order within each input is preserved
    order = np.argsort(-sc, axis=1, kind="stable")
    rid = np.take_along_axis(ids, order, axis=1)
    rsc = np.take_along_axis(sc, order, axis=1)
    # keep a candidate iff it is valid (id >= 0) and the best-ranked
    # occurrence of its doc id: stable-sorting the ranked ids groups
    # duplicates while preserving rank order inside each group
    by_id = np.argsort(rid, axis=1, kind="stable")
    sid = np.take_along_axis(rid, by_id, axis=1)
    first = np.ones((L, M), bool)
    first[:, 1:] = sid[:, 1:] != sid[:, :-1]
    keep = np.zeros((L, M), bool)
    np.put_along_axis(keep, by_id, first & (sid >= 0), axis=1)
    # compact the keepers leftward in rank order into the [L, k] output
    pos = np.cumsum(keep, axis=1) - 1
    out_i = np.full((L, k), -1, np.int64)
    out_s = np.full((L, k), -np.inf, np.float32)
    rows, cols = np.nonzero(keep & (pos < k))
    out_i[rows, pos[rows, cols]] = rid[rows, cols]
    out_s[rows, pos[rows, cols]] = rsc[rows, cols]
    return SearchResult(out_i, out_s)
