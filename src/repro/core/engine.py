"""PatternSearchEngine — the paper's in-storage accelerator as a sharded
TPU service (DESIGN.md §2).

The corpus lives sharded across chip HBM: doc rows over the (pod, data)
mesh axes — the paper's K corpus partitions — and the merged query batch's
L value-columns over the ``model`` axis — the paper's L. Each device is one
"accelerator kernel": it scores its corpus shard against its query slice
(Pallas kernel on TPU, gather path on CPU), takes a local top-k, and a
hierarchical reduction returns the global winners. Only queries (in) and
top-k (out) cross the interconnect; the corpus never moves.

Streaming mode handles corpora larger than aggregate HBM: fixed-size
resident slabs are scored while the next slab is transferred
(double-buffered, epoch-tagged — the prefetch-predictor analogue at host
scope), with top-k merged across slabs.

Serving mode (DESIGN.md §7) feeds ``search`` micro-batches of varying L
from the SearchService coalescer. To keep variable L cheap, query shapes
are *bucketed*: L pads to the next power-of-two multiple of the model
axis, and the merged id stream pads to a capacity proportional to that L
bucket — so a session that serves batches of any size up to ``max_batch``
compiles at most ``log2(max_batch) + 1`` programs instead of one per
distinct shape. ``compile_stats`` reports the traces actually taken.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Iterable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from repro.distributed.compat import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.paper_search import SearchConfig
from repro.core import topk as topk_lib
from repro.core.corpus import Corpus
from repro.core.stream_format import VAL_MASK
from repro.distributed.meshctx import MeshCtx
from repro.kernels import fused as kfused
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.fused import PackedSlab
from repro.kernels.sparse_match_packed import pack as pack_ell
from repro.kernels.tiling import FixedTiling, TilingStrategy


@dataclasses.dataclass
class SearchResult:
    doc_ids: np.ndarray   # [L, k] int64 (-1 for no result)
    scores: np.ndarray    # [L, k] cosine


class DeviceSlab(NamedTuple):
    """A corpus slab already uploaded and sharded over the mesh — the unit
    the streaming path scores. Produced by ``put_slab`` (or by the storage
    prefetcher's background thread, DESIGN.md §3)."""
    ids: jax.Array        # [n, K] int32
    vals: jax.Array       # [n, K] float32
    norms: jax.Array      # [n] float32
    doc_ids: jax.Array    # [n] int32


SlabLike = Union[Corpus, DeviceSlab, PackedSlab]


def _require_integral_counts(vals: np.ndarray, backend: str):
    """The packed/fused backends carry values in the Fig. 8 12-bit count
    field — arbitrary floats would be silently clipped/rounded."""
    v = vals[vals != 0]
    if v.size and (not np.all(v == np.round(v)) or v.min() < 0
                   or v.max() > VAL_MASK):
        raise ValueError(
            f"backend={backend!r} needs integral counts in "
            f"[0, {VAL_MASK}] (Fig. 8 packing); use backend='jnp' or "
            "'pallas' for arbitrary float values")


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class PatternSearchEngine:
    def __init__(self, corpus: Optional[Corpus], cfg: SearchConfig,
                 ctx: MeshCtx, backend: str = "jnp", obs=None,
                 tiling: Optional[TilingStrategy] = None):
        """``corpus=None`` builds a streaming-only engine (no resident
        corpus): callers must use ``search_streaming`` / ``put_slab``.
        ``obs`` (a ``repro.obs.Obs``) mirrors compile traces into the
        shared metrics registry; None uses the process default.
        ``tiling`` picks the fused backend's tile shapes (DESIGN.md
        §12.3); None uses ``FixedTiling`` at the config's shapes."""
        from repro.obs import default_obs
        self.cfg = cfg
        self.ctx = ctx
        self.backend = backend
        self.obs = obs if obs is not None else default_obs()
        if corpus is None:
            corpus = Corpus.empty(cfg.nnz_pad)
        if corpus.ids.size and int(corpus.ids.max()) >= cfg.vocab_size:
            raise ValueError(
                f"corpus word ids reach {int(corpus.ids.max())} but "
                f"cfg.vocab_size={cfg.vocab_size}")
        ndev = ctx.mesh.size
        rows = ctx.dp_size
        n = -(-corpus.n_docs // rows) * rows
        corpus = corpus.pad_docs_to(n)
        self.corpus = corpus
        self.tiling = tiling if tiling is not None else FixedTiling(
            cfg.block_docs, cfg.block_query)
        self.f_tiles: Optional[jax.Array] = None
        if backend == "pallas_fused":
            # the fused kernel scores a single device's packed tiles;
            # sharded meshes keep the staged per-device kernels
            if ctx.mesh.size != 1:
                raise ValueError(
                    "backend='pallas_fused' is single-device (packed doc "
                    f"tiles are not mesh-sharded); mesh has {ctx.mesh.size}"
                    " devices — use 'pallas' or 'jnp' there")
            self._block_docs = self.tiling.doc_tile(
                nnz_pad=cfg.nnz_pad, n_docs=corpus.n_docs)
            tiles, _, self._resident_trunc = kfused.tile_stream(
                kfused.corpus_to_stream(corpus),
                block_docs=self._block_docs, nnz_pad=cfg.nnz_pad,
                pad_docs_to=corpus.n_docs)
            # no host ELL staging, no per-array uploads: one uint32
            # tile matrix is the whole resident corpus
            self.f_tiles = jax.device_put(tiles)
            self.d_ids = self.d_vals = None
            self.d_norms = self.d_docids = None
        else:
            self._block_docs = cfg.block_docs
            spec = P(ctx.dp_axes, None)
            up_ids = corpus.ids
            if backend == "pallas_packed":
                # the packed kernel consumes Fig. 8 uint32 words, not
                # ELL int32 ids — uploading the raw ids scored every
                # document as all-zero (word 19-bit fields never match)
                _require_integral_counts(corpus.vals, backend)
                up_ids = pack_ell(corpus.ids, corpus.vals)
            self.d_ids = jax.device_put(up_ids,
                                        NamedSharding(ctx.mesh, spec))
            self.d_vals = jax.device_put(corpus.vals,
                                         NamedSharding(ctx.mesh, spec))
            self.d_norms = jax.device_put(
                corpus.norms, NamedSharding(ctx.mesh, P(ctx.dp_axes)))
            self.d_docids = jax.device_put(
                corpus.doc_ids.astype(np.int32),
                NamedSharding(ctx.mesh, P(ctx.dp_axes)))
        # compile-cache bookkeeping: one program per (L-bucket, Q-capacity,
        # n_docs) key; _trace_keys is appended at *trace* time inside the
        # jitted body, so it counts real recompiles, not call shapes
        self._trace_keys: list = []
        self._search_fn = (self._build_fused() if backend == "pallas_fused"
                           else self._build(ndev))

    # ------------------------------------------------------------------
    def _build(self, ndev: int):
        cfg, ctx, backend = self.cfg, self.ctx, self.backend
        tp = ctx.tp_axis
        dp = ctx.dp_axes

        def local_score(ids, vals, norms, docids, q_ids, q_vals, q_norms):
            """Per-device: score local corpus shard x local query columns."""
            corr = kops.correlate(
                ids, vals, q_ids, q_vals, backend=backend,
                vocab_size=cfg.vocab_size, block_docs=cfg.block_docs,
                block_query=cfg.block_query)
            cos = kops.cosine_scores(corr, norms, q_norms)
            v, i = topk_lib.local_topk(cos, docids, cfg.top_k)
            # reduce across the corpus-shard (K) axes — paper's report path
            for ax in dp:
                v, i = topk_lib.tree_topk(v, i, cfg.top_k, ax)
            return v, i

        qcols_spec = P(None, tp)  # L value-columns over the model axis
        trace_keys = self._trace_keys
        # registry handle resolved once: the jitted body's python side
        # effect stays one list append + one counter inc per real trace
        trace_counter = self.obs.registry.counter("engine_compile_traces")

        @jax.jit
        def search(ids, vals, norms, docids, q_ids, q_vals, q_norms):
            # python side effect: runs once per trace (i.e. per compiled
            # program), never on a jit cache hit
            trace_keys.append((q_norms.shape[0], q_ids.shape[0],
                               ids.shape[0]))
            trace_counter.inc()
            f = shard_map(
                local_score, mesh=ctx.mesh,
                in_specs=(P(dp, None), P(dp, None), P(dp), P(dp),
                          P(None), qcols_spec, P(tp)),
                out_specs=(P(tp, None), P(tp, None)),
                check_vma=False)
            return f(ids, vals, norms, docids, q_ids, q_vals, q_norms)

        return search

    def _build_fused(self):
        """The fused path's one dispatch: packed tiles + merged stream ->
        folded winners (kernels.fused, DESIGN.md §12). ``block_query``
        is static — the tiling strategy resolves it per L bucket, so it
        adds no program shapes beyond the bucket's."""
        cfg = self.cfg
        bd = self._block_docs
        trace_keys = self._trace_keys
        trace_counter = self.obs.registry.counter("engine_compile_traces")

        @functools.partial(jax.jit, static_argnames=("block_query",))
        def search(tiles, q_ids, q_vals, q_norms, block_query):
            trace_keys.append((q_norms.shape[0], q_ids.shape[0],
                               tiles.shape[0] * bd))
            trace_counter.inc()
            return kops.fused_topk(tiles, q_ids, q_vals, q_norms,
                                   k=cfg.top_k, block_docs=bd,
                                   block_query=block_query)

        return search

    # ------------------------------------------------------------------
    def bucket_L(self, L: int) -> int:
        """The L compile bucket: next power of two of ceil(L / tp), times
        tp — so any batch size up to ``max_batch`` lands in one of
        ``log2(max_batch) + 1`` program shapes (DESIGN.md §7)."""
        tp = self.ctx.tp_size
        return _next_pow2(-(-L // tp)) * tp

    def bucket_Q(self, q_items: int, Lp: int) -> int:
        """Merged-stream capacity for an L bucket: ``Lp * block_query``
        items, doubling (power-of-two blocks) only when the batch's merged
        stream overflows it. Queries with nnz <= block_query therefore
        never add a program shape beyond their L bucket's."""
        cap = Lp * self.cfg.block_query
        return _next_pow2(-(-max(q_items, 1) // cap)) * cap

    def search(self, query, q_vals=None, *, options=None):
        """Public search surface. Typed form — ``search(Query(ids,
        vals), options=QueryOptions(...))`` — returns a
        ``SearchResponse``; positional ``search(q_ids, q_vals)``
        ``[L, Qn]`` arrays (pad < 0) remain as a deprecation shim
        returning the bare ``SearchResult`` (repro/serve/api.py). The
        resident engine is pure compute, so of the scheduling options
        only ``k`` applies here; deadlines/admission act in the serving
        layer above (DESIGN.md §7.3)."""
        # serve.api imported lazily: repro.serve imports this module
        # (SearchService stacks batches into engine calls), so a
        # module-level import here would be circular
        from repro.serve.api import (QueryStats, SearchResponse,
                                     coerce_request, truncate_k)
        q, options = coerce_request(query, q_vals, options,
                                    surface="PatternSearchEngine.search")
        res = self._search_arrays(*q.rows())
        if options is None:
            return res
        return SearchResponse(truncate_k(res, options.k), QueryStats(
            deadline_ms=options.deadline_ms, tenant=options.tenant))

    def search_typed(self, query, options=None, *, _span=None
                     ) -> SearchResult:
        """The raw typed surface the coalescing service dispatches to:
        no wrapping, no shim warning (see serve/search_service.py)."""
        return self._search_arrays(*query.rows())

    def _search_arrays(self, q_ids: np.ndarray,
                       q_vals: np.ndarray) -> SearchResult:
        """q_ids/q_vals: [L, Qn] (pad < 0). L is padded to its compile
        bucket (next power-of-two multiple of the model-axis size — the
        paper's L query batch, bucketed so the serving layer's variable
        batches reuse cached programs)."""
        L_ = q_ids.shape[0]
        if L_ == 0:
            # an empty batch has a well-defined answer, not a degenerate
            # program shape (bucket_L would still pad to tp, but the
            # [0, k] result needs no kernel at all)
            return self.empty_result(0)
        Lp = self.bucket_L(L_)
        if Lp != L_:
            pad_i = np.full((Lp - L_, q_ids.shape[1]), -1, q_ids.dtype)
            pad_v = np.zeros((Lp - L_, q_vals.shape[1]), q_vals.dtype)
            q_ids = np.concatenate([q_ids, pad_i])
            q_vals = np.concatenate([q_vals, pad_v])
        mi, mv = kops.merge_queries(q_ids, q_vals)
        # pad the merged stream to the bucket's fixed capacity
        pad = self.bucket_Q(mi.size, Lp)
        mi = np.pad(mi, (0, pad - mi.size), constant_values=-2)
        mv = np.pad(mv, ((0, pad - mv.shape[0]), (0, 0)))
        q_norms = np.sqrt((np.where(q_vals > 0, q_vals, 0) ** 2).sum(1))
        q_norms = np.maximum(q_norms, 1e-12).astype(np.float32)
        # optional device-stage split (DESIGN.md §8.5): with the fence
        # on, the async dispatch is timed separately from the device
        # compute it enqueues. Off by default — block_until_ready
        # serializes work the np.asarray below would have overlapped.
        fence = getattr(self.obs, "device_fence", False)
        t0 = time.perf_counter() if fence else 0.0
        if self.backend == "pallas_fused":
            tq = self.tiling.query_tile(Lp)
            v, i = self._search_fn(self.f_tiles, jnp.asarray(mi),
                                   jnp.asarray(mv), jnp.asarray(q_norms),
                                   block_query=tq)
        else:
            v, i = self._search_fn(
                self.d_ids, self.d_vals, self.d_norms, self.d_docids,
                jnp.asarray(mi), jnp.asarray(mv), jnp.asarray(q_norms))
        if fence:
            t1 = time.perf_counter()
            jax.block_until_ready((v, i))
            t2 = time.perf_counter()
            reg = self.obs.registry
            reg.histogram("stage_ms", stage="score_dispatch").observe(
                (t1 - t0) * 1e3)
            reg.histogram("stage_ms", stage="score_device").observe(
                (t2 - t1) * 1e3)
        v = np.asarray(v)[:L_]
        # ids come from local_topk / the fused epilogue already masked by
        # row validity; re-masking by isfinite here renamed real docs
        # with non-finite fp32 scores to -1 (see core.topk.local_topk)
        i = np.asarray(i)[:L_]
        return SearchResult(doc_ids=i.astype(np.int64), scores=v)

    # ------------------------------------------------------------------
    def search_streaming(self, q_ids, q_vals,
                         corpus_slabs: Iterable[SlabLike]) -> SearchResult:
        """Score a lazily-consumed sequence of corpus slabs larger than
        resident memory, merging top-k across slabs (DESIGN.md §2).

        Each element may be a host ``Corpus`` (uploaded here, with the next
        slab's async device_put overlapping the current slab's scoring) or
        an already-resident ``DeviceSlab`` (e.g. from the storage tier's
        background prefetcher, which overlaps disk read + decode + upload
        as well — DESIGN.md §3). The iterable is never materialized, so
        store-backed iterators stream arbitrarily large corpora."""
        best: Optional[SearchResult] = None
        it = iter(corpus_slabs)
        cur = self._as_device(next(it, None))
        if cur is None:
            return self.empty_result(q_ids.shape[0])
        while cur is not None:
            # start the next H2D transfer before scoring the current slab
            nxt = self._as_device(next(it, None))
            r = eng_search(self._with_slab(cur), q_ids, q_vals)
            best = r if best is None else _merge_results(best, r,
                                                         self.cfg.top_k)
            cur = nxt
        return best

    @property
    def compile_stats(self) -> dict:
        """Programs actually traced so far: ``n_traces`` plus the (Lp, Qp,
        n_docs) key of each. The serving acceptance bound is
        ``n_traces <= log2(max_batch) + 1`` for a session whose queries
        stay within one Q capacity per L bucket."""
        return {"n_traces": len(self._trace_keys),
                "buckets": list(self._trace_keys)}

    def empty_result(self, n_queries: int) -> SearchResult:
        """The [L, k] no-result sentinel (id -1, score -inf)."""
        k = self.cfg.top_k
        return SearchResult(np.full((n_queries, k), -1, np.int64),
                            np.full((n_queries, k), -np.inf, np.float32))

    @property
    def slab_fmt(self) -> str:
        """The device-slab layout this engine scores — part of the slab
        cache key, so an ELL slab can never satisfy a fused lookup (the
        fused layout also depends on the doc-tile side)."""
        if self.backend == "pallas_fused":
            return f"fused:{self._block_docs}"
        return "ell"

    def put_slab(self, slab: Corpus) -> SlabLike:
        """Upload a host slab, sharded like the resident corpus. device_put
        is async: the transfer overlaps whatever is already enqueued.
        The fused backend re-encodes the corpus rows into packed doc
        tiles (``PackedSlab``); ELL backends upload the row arrays."""
        rows = self.ctx.dp_size
        slab = slab.pad_docs_to(-(-slab.n_docs // rows) * rows)
        if self.backend == "pallas_fused":
            tiles, _, _ = kfused.tile_stream(
                kfused.corpus_to_stream(slab),
                block_docs=self._block_docs, nnz_pad=self.cfg.nnz_pad,
                pad_docs_to=slab.n_docs)
            return PackedSlab(jax.device_put(tiles))
        ids = slab.ids
        if self.backend == "pallas_packed":
            _require_integral_counts(slab.vals, self.backend)
            ids = pack_ell(slab.ids, slab.vals)
        sh = NamedSharding(self.ctx.mesh, P(self.ctx.dp_axes, None))
        sh1 = NamedSharding(self.ctx.mesh, P(self.ctx.dp_axes))
        return DeviceSlab(
            jax.device_put(ids, sh), jax.device_put(slab.vals, sh),
            jax.device_put(slab.norms, sh1),
            jax.device_put(slab.doc_ids.astype(np.int32), sh1))

    def put_stream_slab(self, stream: np.ndarray, *,
                        pad_docs_to: Optional[int] = None
                        ) -> Tuple[PackedSlab, int, int]:
        """Fused-backend ingest straight from the Fig. 8 byte stream: a
        segment file becomes device tiles with *no* host ELL decode —
        the storage executor's fused load path (DESIGN.md §12.2).
        Returns ``(slab, n_docs, n_truncated)`` with the exact counts
        ``decode_to_ell`` would have reported."""
        if self.backend != "pallas_fused":
            raise ValueError("put_stream_slab is the fused-backend "
                             f"ingest; engine backend is {self.backend!r}")
        tiles, n_docs, n_trunc = kfused.tile_stream(
            stream, block_docs=self._block_docs, nnz_pad=self.cfg.nnz_pad,
            pad_docs_to=pad_docs_to)
        return PackedSlab(jax.device_put(tiles)), n_docs, n_trunc

    def _as_device(self, slab: Optional[SlabLike]) -> Optional[SlabLike]:
        if slab is None or isinstance(slab, (DeviceSlab, PackedSlab)):
            return slab
        return self.put_slab(slab)

    def _with_slab(self, dev: SlabLike):
        eng = object.__new__(PatternSearchEngine)
        eng.__dict__.update(self.__dict__)
        if isinstance(dev, PackedSlab):
            eng.f_tiles = dev.tiles
        else:
            eng.d_ids, eng.d_vals, eng.d_norms, eng.d_docids = dev
        return eng


def eng_search(eng: PatternSearchEngine, q_ids, q_vals) -> SearchResult:
    # the streaming hot loop's internal entry: positional arrays without
    # the public shim's deprecation machinery
    return PatternSearchEngine._search_arrays(eng, q_ids, q_vals)


def _merge_results(a: SearchResult, b: SearchResult, k: int) -> SearchResult:
    """Merge two [L, k] candidate sets into the best k per row.

    Deterministic: descending score, stable within ties (a's candidates
    win over b's). Duplicate doc ids keep only their best-scoring entry,
    and no-result fillers (id < 0) never displace real candidates — any
    unfilled tail stays (-1, -inf).

    Vectorized (this runs once per slab on the serving hot path; the
    per-row Python loop it replaced was O(L*k*slabs) interpreter time —
    tests/test_merge_equivalence.py holds it to the loop's exact output)."""
    ids = np.concatenate([a.doc_ids, b.doc_ids], axis=1).astype(np.int64)
    sc = np.concatenate([a.scores, b.scores], axis=1).astype(np.float32)
    L, M = ids.shape
    # rank every candidate by descending score; stable, so a's candidates
    # win ties against b's and order within each input is preserved
    order = np.argsort(-sc, axis=1, kind="stable")
    rid = np.take_along_axis(ids, order, axis=1)
    rsc = np.take_along_axis(sc, order, axis=1)
    # keep a candidate iff it is valid (id >= 0) and the best-ranked
    # occurrence of its doc id: stable-sorting the ranked ids groups
    # duplicates while preserving rank order inside each group
    by_id = np.argsort(rid, axis=1, kind="stable")
    sid = np.take_along_axis(rid, by_id, axis=1)
    first = np.ones((L, M), bool)
    first[:, 1:] = sid[:, 1:] != sid[:, :-1]
    keep = np.zeros((L, M), bool)
    np.put_along_axis(keep, by_id, first & (sid >= 0), axis=1)
    # compact the keepers leftward in rank order into the [L, k] output
    pos = np.cumsum(keep, axis=1) - 1
    out_i = np.full((L, k), -1, np.int64)
    out_s = np.full((L, k), -np.inf, np.float32)
    rows, cols = np.nonzero(keep & (pos < k))
    out_i[rows, pos[rows, cols]] = rid[rows, cols]
    out_s[rows, pos[rows, cols]] = rsc[rows, cols]
    return SearchResult(out_i, out_s)
