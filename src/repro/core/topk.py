"""Hierarchical distributed top-k (the paper's result reporting path).

Per-chip top-k over the local corpus shard, then a tree reduction along the
mesh axes so only O(k) values cross each ICI link — the in-pod analogue of
"only documentIDs with high scores are reported to the computer". The MoE
router's top-k dispatch (repro.models.moe) shares this primitive family.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def local_topk(scores: Array, doc_ids: Array, k: int) -> Tuple[Array, Array]:
    """scores: [D, L]; doc_ids: [D] -> (vals [L, k], ids [L, k])."""
    vals, idx = jax.lax.top_k(scores.T, k)        # [L, k]
    return vals, doc_ids[idx]


def merge_topk(vals_a, ids_a, vals_b, ids_b, k: int):
    """Merge two [L, k] candidate sets."""
    vals = jnp.concatenate([vals_a, vals_b], axis=1)
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    v, idx = jax.lax.top_k(vals, k)
    return v, jnp.take_along_axis(ids, idx, axis=1)


def tree_topk(vals: Array, ids: Array, k: int, axis_name: str):
    """Reduce [L, k] candidates across a mesh axis inside shard_map.

    all_gather + re-top_k; with k << D_local the gathered tensor is tiny
    (k * axis_size entries), so a single gather is cheaper than a log-depth
    ppermute tree on real ICI — both are provided, the tree variant is used
    when k * axis_size would exceed the VMEM-friendly threshold."""
    g_vals = jax.lax.all_gather(vals, axis_name, axis=1, tiled=True)
    g_ids = jax.lax.all_gather(ids, axis_name, axis=1, tiled=True)
    v, idx = jax.lax.top_k(g_vals, k)
    return v, jnp.take_along_axis(g_ids, idx, axis=1)


def tree_topk_ppermute(vals: Array, ids: Array, k: int, axis_name: str,
                       axis_size: int):
    """Log-depth butterfly merge via ppermute (collective-light variant for
    very large meshes / large k)."""
    step = 1
    while step < axis_size:
        perm = [(i, i ^ step) for i in range(axis_size)]
        ov = jax.lax.ppermute(vals, axis_name, perm)
        oi = jax.lax.ppermute(ids, axis_name, perm)
        vals, ids = merge_topk(vals, ids, ov, oi, k)
        step *= 2
    return vals, ids
