"""Hierarchical distributed top-k (the paper's result reporting path).

Per-chip top-k over the local corpus shard, then a tree reduction along the
mesh axes so only O(k) values cross each ICI link — the in-pod analogue of
"only documentIDs with high scores are reported to the computer". The MoE
router's top-k dispatch (repro.models.moe) shares this primitive family.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def local_topk(scores: Array, doc_ids: Array, k: int) -> Tuple[Array, Array]:
    """scores: [D, L]; doc_ids: [D] -> (vals [L, k], ids [L, k]).

    Padding rows (doc_id < 0, from pad_docs_to) are masked to -inf so they
    never outrank a real document, and their reported id is forced to -1.
    When k exceeds the shard's row count the candidate list is padded with
    (-inf, -1) placeholders so every shard reports the same [L, k] shape.

    Id masking is by *row validity* (doc_id >= 0), never by score
    finiteness: a real document whose fp32 score overflowed to +inf (or
    went NaN on non-finite input values) is still a real document and
    must report its real id — masking on isfinite(vals) silently renamed
    the best-scoring candidate to -1 (tests/test_topk.py pins this).
    """
    scores = jnp.where(doc_ids[:, None] >= 0, scores, -jnp.inf)
    k_eff = min(k, scores.shape[0])
    vals, idx = jax.lax.top_k(scores.T, k_eff)    # [L, k_eff]
    ids = jnp.where(doc_ids[idx] >= 0, doc_ids[idx], -1)
    if k_eff < k:
        pad = ((0, 0), (0, k - k_eff))
        vals = jnp.pad(vals, pad, constant_values=-jnp.inf)
        ids = jnp.pad(ids, pad, constant_values=-1)
    return vals, ids


def fold_topk(vals: Array, ids: Array, k: int) -> Tuple[Array, Array]:
    """Fold an [L, C] candidate list down to the best [L, k].

    ``top_k`` breaks ties by lower column index, so candidates must be
    concatenated in priority order (earlier shard / tile / fold slot
    first) — that is what keeps the fused kernel's per-tile partial
    top-k bit-identical to a flat global top-k. A list shorter than k
    is padded with (-inf, -1) placeholders."""
    c = vals.shape[1]
    if c < k:
        pad = ((0, 0), (0, k - c))
        vals = jnp.pad(vals, pad, constant_values=-jnp.inf)
        ids = jnp.pad(ids, pad, constant_values=-1)
    v, idx = jax.lax.top_k(vals, k)
    return v, jnp.take_along_axis(ids, idx, axis=1)


def merge_topk(vals_a, ids_a, vals_b, ids_b, k: int):
    """Merge two [L, k] candidate sets."""
    return fold_topk(jnp.concatenate([vals_a, vals_b], axis=1),
                     jnp.concatenate([ids_a, ids_b], axis=1), k)


def tree_topk(vals: Array, ids: Array, k: int, axis_name: str):
    """Reduce [L, k] candidates across a mesh axis inside shard_map.

    all_gather + re-top_k; with k << D_local the gathered tensor is tiny
    (k * axis_size entries), so a single gather is cheaper than a log-depth
    ppermute tree on real ICI — both are provided, the tree variant is used
    when k * axis_size would exceed the VMEM-friendly threshold."""
    g_vals = jax.lax.all_gather(vals, axis_name, axis=1, tiled=True)
    g_ids = jax.lax.all_gather(ids, axis_name, axis=1, tiled=True)
    v, idx = jax.lax.top_k(g_vals, k)
    return v, jnp.take_along_axis(g_ids, idx, axis=1)


def tree_topk_ppermute(vals: Array, ids: Array, k: int, axis_name: str,
                       axis_size: int):
    """Log-depth butterfly merge via ppermute (collective-light variant for
    very large meshes / large k)."""
    step = 1
    while step < axis_size:
        perm = [(i, i ^ step) for i in range(axis_size)]
        ov = jax.lax.ppermute(vals, axis_name, perm)
        oi = jax.lax.ppermute(ids, axis_name, perm)
        vals, ids = merge_topk(vals, ids, ov, oi, k)
        step *= 2
    return vals, ids
