"""Exporters and the shared post-run summary (DESIGN.md §8.3).

Three consumers, one data source (the ``Obs`` bundle):

- ``write_metrics`` — Prometheus text exposition to a file
  (``search_serve --metrics-out``);
- ``write_traces`` — JSON dump of the tracer's retained ``QueryTrace``
  trees (written next to the metrics file when ``--trace-sample`` is on);
- ``render_summary`` — the one human-readable post-run block every
  ``search_serve`` target (single store, cluster, service-wrapped
  engine) prints, replacing the divergent per-target code paths;
  ``render_trace`` pretty-prints one trace tree for the console.

Everything here only *reads* instruments; nothing in this module is on
a query path.
"""
from __future__ import annotations

import json
from typing import List, Optional

from . import Obs
from .trace import QueryTrace


def write_metrics(obs: Obs, path: str, prefix: str = "repro") -> None:
    """Dump the registry in Prometheus text exposition format."""
    with open(path, "w") as f:
        f.write(obs.registry.to_prometheus(prefix=prefix))


def write_traces(obs: Obs, path: str) -> int:
    """Dump the tracer's retained traces as JSON; returns how many."""
    traces = obs.tracer.export()
    with open(path, "w") as f:
        json.dump({"schema": "repro-traces-v1", "traces": traces}, f,
                  indent=1)
    return len(traces)


def render_trace(trace: Optional[QueryTrace]) -> str:
    """Indented timeline of one QueryTrace (start offset + duration per
    span, then its attrs) — the README's sample dump."""
    if trace is None:
        return "(no trace sampled)"
    lines: List[str] = []

    def walk(node: dict, depth: int) -> None:
        attrs = " ".join(f"{k}={v}" for k, v in node["attrs"].items())
        lines.append(f"{'  ' * depth}{node['name']:<8} "
                     f"+{node['start_ms']:>8.3f}ms "
                     f"{node['dur_ms']:>9.3f}ms  {attrs}".rstrip())
        for child in node["children"]:
            walk(child, depth + 1)

    walk(trace.to_dict()["root"], 0)
    return "\n".join(lines)


def _fmt_labels(labels: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


def render_summary(searcher, obs: Optional[Obs] = None) -> str:
    """The unified post-run block: query/stage latency percentiles from
    the registry, slab cache state, engine compile traces, and the slow
    query ring — identical shape whichever target ``searcher`` is (the
    resident engine, a FlashSearchSession, a FlashClusterSession, or a
    SearchService wrapping any of them)."""
    if obs is None:
        obs = getattr(searcher, "obs", None)
    lines: List[str] = ["== observability summary =="]
    if obs is None or not getattr(obs, "enabled", False):
        lines.append("observability disabled")
        return "\n".join(lines)

    hists = [(name, labels, m)
             for name, labels, kind, m in obs.registry.items()
             if kind == "histogram" and m.count]
    for name, labels, m in hists:
        if name != "query_ms":
            continue
        lines.append(
            f"queries[{_fmt_labels(labels)}]: n={m.count} "
            f"p50={m.p50:.2f}ms p95={m.p95:.2f}ms p99={m.p99:.2f}ms")
    stage = [(labels.get("stage", "?"), m) for name, labels, m in hists
             if name == "stage_ms"]
    if stage:
        lines.append("stage latency (ms):")
        for sname, m in stage:
            lines.append(f"  {sname:<14} n={m.count:<6} p50={m.p50:8.3f} "
                         f"p95={m.p95:8.3f} p99={m.p99:8.3f}")
    for name, labels, m in hists:
        if name in ("serve_queue_wait_ms", "cluster_shard_ms"):
            lines.append(
                f"{name}[{_fmt_labels(labels)}]: n={m.count} "
                f"p50={m.p50:.3f}ms p95={m.p95:.3f}ms p99={m.p99:.3f}ms")

    # slab cache: every tier exposes the same cache_stats surface
    cache = getattr(searcher, "slab_cache", None)
    cstats = getattr(searcher, "cache_stats", None)
    if cstats is not None:
        obs.publish_cache(cache)
        extra = (f" bytes={cache.nbytes} entries={len(cache)}"
                 if cache is not None else "")
        lines.append(
            f"slab cache: hit_rate={cstats.hit_rate:.3f} "
            f"hits={cstats.hits} misses={cstats.misses} "
            f"evictions={cstats.evictions}"
            f" invalidations={cstats.invalidations}{extra}")

    # compile traces: one consistent accessor for every target — the
    # engine, both session tiers, and SearchService (via its searcher)
    target = searcher
    cs = getattr(target, "compile_stats", None)
    if cs is None:
        target = getattr(searcher, "searcher", None)
        cs = getattr(target, "compile_stats", None)
    if cs is not None:
        line = f"engine traces: {cs['n_traces']}"
        if "per_shard" in cs:
            line += f" (per-shard max: {cs['per_shard']})"
        reg_traces = obs.registry.counter("engine_compile_traces").value
        line += f" [registry: {reg_traces}]"
        lines.append(line)

    slow = obs.slow_query_log()
    if slow:
        lines.append(f"slow queries (>= {obs.slow_ms:g}ms): {len(slow)}; "
                     "worst:")
        for rec in slow[:3]:
            extras = " ".join(f"{k}={v}" for k, v in rec.items()
                              if k not in ("surface", "wall_ms", "time"))
            lines.append(f"  {rec['wall_ms']:9.2f}ms "
                         f"[{rec['surface']}] {extras}".rstrip())
    return "\n".join(lines)
