"""Exporters and the shared post-run summary (DESIGN.md §8.3).

Three consumers, one data source (the ``Obs`` bundle):

- ``write_metrics`` — Prometheus text exposition to a file
  (``search_serve --metrics-out``);
- ``write_traces`` — JSON dump of the tracer's retained ``QueryTrace``
  trees (written next to the metrics file when ``--trace-sample`` is on);
- ``render_summary`` — the one human-readable post-run block every
  ``search_serve`` target (single store, cluster, service-wrapped
  engine) prints, replacing the divergent per-target code paths;
  ``render_trace`` pretty-prints one trace tree for the console.

Everything here only *reads* instruments; nothing in this module is on
a query path.

The file writers are atomic (write a ``.tmp`` sibling, fsync, then
``os.replace`` — the store-manifest publish idiom): a concurrent reader
of ``metrics.prom`` sees the previous complete file or the new one,
never a torn prefix.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

from . import Obs
from .trace import QueryTrace


def _atomic_write(path: str, text: str) -> None:
    """tmp + fsync + rename, same durability contract as the store
    manifest: readers never observe a partially-written file."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def write_metrics(obs: Obs, path: str, prefix: str = "repro") -> None:
    """Dump the registry in Prometheus text exposition format
    (atomically — scrapers tailing the file never see a torn dump)."""
    _atomic_write(path, obs.registry.to_prometheus(prefix=prefix))


def write_traces(obs: Obs, path: str) -> int:
    """Dump the tracer's retained traces as JSON (atomically);
    returns how many."""
    traces = obs.tracer.export()
    _atomic_write(path, json.dumps(
        {"schema": "repro-traces-v1", "traces": traces}, indent=1))
    return len(traces)


def _fmt_ms(v: float, width: int = 9) -> str:
    """A span duration for the timeline. Sub-0.1 ms spans (an all-
    cache-hit load, a no-op merge) rendered at ms precision collapse to
    ``0.000ms`` — print those in µs so the timeline stays readable."""
    if 0 < abs(v) < 0.1:
        return f"{v * 1e3:>{width}.1f}µs"
    return f"{v:>{width}.3f}ms"


def render_trace(trace: Optional[QueryTrace]) -> str:
    """Indented timeline of one QueryTrace (start offset + duration per
    span, then its attrs) — the README's sample dump."""
    if trace is None:
        return "(no trace sampled)"
    lines: List[str] = []

    def walk(node: dict, depth: int) -> None:
        attrs = " ".join(f"{k}={v}" for k, v in node["attrs"].items())
        lines.append(f"{'  ' * depth}{node['name']:<8} "
                     f"+{_fmt_ms(node['start_ms'], 8)} "
                     f"{_fmt_ms(node['dur_ms'])}  {attrs}".rstrip())
        for child in node["children"]:
            walk(child, depth + 1)

    walk(trace.to_dict()["root"], 0)
    return "\n".join(lines)


def _fmt_labels(labels: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


def render_summary(searcher, obs: Optional[Obs] = None,
                   slo_monitor=None) -> str:
    """The unified post-run block: query/stage latency percentiles from
    the registry, rolling-window rates, SLO burn states (when a monitor
    is passed), slab cache state, engine compile traces, and the slow
    query ring — identical shape whichever target ``searcher`` is (the
    resident engine, a FlashSearchSession, a FlashClusterSession, or a
    SearchService wrapping any of them)."""
    if obs is None:
        obs = getattr(searcher, "obs", None)
    lines: List[str] = ["== observability summary =="]
    if obs is None or not getattr(obs, "enabled", False):
        lines.append("observability disabled")
        return "\n".join(lines)

    hists = [(name, labels, m)
             for name, labels, kind, m in obs.registry.items()
             if kind == "histogram" and m.count]
    served = False
    for name, labels, m in hists:
        if name != "query_ms":
            continue
        served = True
        lines.append(
            f"queries[{_fmt_labels(labels)}]: n={m.count} "
            f"p50={m.p50:.2f}ms p95={m.p95:.2f}ms p99={m.p99:.2f}ms")
        w = obs.registry.windowed(name, **labels)
        if w is not None and w.count:
            ws = w.stats()
            lines.append(
                f"  last {w.window_s:g}s: n={ws['count']} "
                f"rate={ws['rate_per_s']:.2f}/s p50={ws['p50']:.2f}ms "
                f"p95={ws['p95']:.2f}ms p99={ws['p99']:.2f}ms")
    if not served:
        # a run that served zero queries still prints a complete,
        # well-formed block — not a bare header (and never a divide)
        lines.append("no queries served")
    if slo_monitor is not None:
        for st in slo_monitor.evaluate():
            gf = ("-" if st.good_fraction is None
                  else f"{st.good_fraction:.4f}")
            lines.append(
                f"slo {st.name}: {st.state} good={gf} "
                f"burn={st.burn_rate:.2f} "
                f"budget={st.budget_remaining:.3f} ({st.detail})")
    stage = [(labels.get("stage", "?"), m) for name, labels, m in hists
             if name == "stage_ms"]
    if stage:
        lines.append("stage latency (ms):")
        for sname, m in stage:
            lines.append(f"  {sname:<14} n={m.count:<6} p50={m.p50:8.3f} "
                         f"p95={m.p95:8.3f} p99={m.p99:8.3f}")
    for name, labels, m in hists:
        if name in ("serve_queue_wait_ms", "cluster_shard_ms"):
            lines.append(
                f"{name}[{_fmt_labels(labels)}]: n={m.count} "
                f"p50={m.p50:.3f}ms p95={m.p95:.3f}ms p99={m.p99:.3f}ms")

    # slab cache: every tier exposes the same cache_stats surface
    cache = getattr(searcher, "slab_cache", None)
    cstats = getattr(searcher, "cache_stats", None)
    if cstats is not None:
        obs.publish_cache(cache)
        extra = (f" bytes={cache.nbytes} entries={len(cache)}"
                 if cache is not None else "")
        lines.append(
            f"slab cache: hit_rate={cstats.hit_rate:.3f} "
            f"hits={cstats.hits} misses={cstats.misses} "
            f"evictions={cstats.evictions}"
            f" invalidations={cstats.invalidations}{extra}")

    # compile traces: one consistent accessor for every target — the
    # engine, both session tiers, and SearchService (via its searcher)
    target = searcher
    cs = getattr(target, "compile_stats", None)
    if cs is None:
        target = getattr(searcher, "searcher", None)
        cs = getattr(target, "compile_stats", None)
    if cs is not None:
        line = f"engine traces: {cs['n_traces']}"
        if "per_shard" in cs:
            line += f" (per-shard max: {cs['per_shard']})"
        reg_traces = obs.registry.counter("engine_compile_traces").value
        line += f" [registry: {reg_traces}]"
        lines.append(line)

    slow = obs.slow_query_log()
    if slow:
        lines.append(f"slow queries (>= {obs.slow_ms:g}ms): {len(slow)}; "
                     "worst:")
        for rec in slow[:3]:
            extras = " ".join(f"{k}={v}" for k, v in rec.items()
                              if k not in ("surface", "wall_ms", "time"))
            lines.append(f"  {rec['wall_ms']:9.2f}ms "
                         f"[{rec['surface']}] {extras}".rstrip())
    return "\n".join(lines)
