"""Per-query trace spans (DESIGN.md §8.2).

A ``QueryTrace`` is a tree of ``Span`` nodes mirroring the request
path: the root covers the whole query; children cover plan build,
per-segment loads (with slab source and decode/upload timings), scoring
calls, the final fold, and — on the cluster tier — one subtree per
shard with straggler attribution. Spans carry free-form ``attrs`` so a
stage can record its verdict (``source="cache"``, ``skipped=7``)
alongside its interval.

Two properties keep this safe on the hot path:

- **One lock per trace, not per span.** Spans are appended from the
  prefetch worker and shard-pool threads concurrently with the
  consumer; all children share the root's lock, taken only on
  ``child()``/``set()`` — never while the stage itself runs.
- **``NULL_SPAN`` when sampling is off.** ``Tracer.start`` returns
  ``None`` unless this query is sampled; callers thread ``NULL_SPAN``
  instead, whose ``child()`` returns itself. The instrumented path then
  costs one attribute call per stage and allocates nothing, which is
  how tracing-off stays inert (differential-tested bit-identical).

``Tracer`` owns the sampling decision (``sample_every=N``; 0 = off,
the default) and ring-buffers the finished traces (``recent``,
``last_trace``) so any session/service/router can hand back its most
recent ``QueryTrace`` without plumbing.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Dict, List, Optional


class Span:
    """One timed interval in a trace tree. Create via ``QueryTrace`` or
    ``parent.child(...)``; close with ``end()`` (idempotent) or use as a
    context manager."""
    __slots__ = ("name", "t0", "t1", "attrs", "children", "_lock")

    def __init__(self, name: str, _lock: threading.Lock, **attrs):
        self.name = name
        self.attrs: Dict = dict(attrs)
        self.children: List["Span"] = []
        self._lock = _lock
        self.t1: Optional[float] = None
        self.t0 = time.perf_counter()

    def child(self, name: str, **attrs) -> "Span":
        c = Span(name, self._lock, **attrs)
        with self._lock:
            self.children.append(c)
        return c

    def set(self, **attrs) -> "Span":
        with self._lock:
            self.attrs.update(attrs)
        return self

    def end(self, **attrs) -> "Span":
        if attrs:
            self.set(**attrs)
        if self.t1 is None:
            self.t1 = time.perf_counter()
        return self

    @property
    def duration_ms(self) -> float:
        return ((self.t1 if self.t1 is not None else time.perf_counter())
                - self.t0) * 1e3

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc):
        self.end()

    def to_dict(self, base: Optional[float] = None) -> Dict:
        """JSON-friendly node; times are ms offsets from ``base`` (the
        trace root's start) so a dump reads as a timeline."""
        if base is None:
            base = self.t0
        with self._lock:
            children = list(self.children)
            attrs = dict(self.attrs)
        return {
            "name": self.name,
            "start_ms": round((self.t0 - base) * 1e3, 3),
            "dur_ms": round(self.duration_ms, 3),
            "attrs": attrs,
            "children": [c.to_dict(base) for c in children],
        }


class _NullSpan:
    """Shared no-op span: ``child()`` returns itself, so an arbitrarily
    deep instrumented path allocates nothing when tracing is off."""
    __slots__ = ()
    name = "null"
    t0 = 0.0
    t1 = 0.0
    attrs: Dict = {}
    children: List = []
    duration_ms = 0.0

    def child(self, name, **attrs):
        return self

    def set(self, **attrs):
        return self

    def end(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        pass

    def to_dict(self, base=None):
        return {}


NULL_SPAN = _NullSpan()


_trace_ids = itertools.count(1)


class QueryTrace:
    """One sampled query: a root span plus the wall-clock timestamp the
    export needs. ``finish()`` closes the root and files the trace with
    the owning tracer. ``trace_id`` is a process-unique ordinal so logs
    and structured errors (ClusterSearchError) can name the trace they
    belong to without holding a reference."""

    def __init__(self, name: str, tracer: "Optional[Tracer]" = None,
                 **attrs):
        self._tracer = tracer
        self.trace_id = next(_trace_ids)
        self.wall_time = time.time()
        self._lock = threading.Lock()
        self.root = Span(name, self._lock, **attrs)

    def finish(self, **attrs) -> "QueryTrace":
        self.root.end(**attrs)
        if self._tracer is not None:
            self._tracer._record(self)
        return self

    @property
    def duration_ms(self) -> float:
        return self.root.duration_ms

    def to_dict(self) -> Dict:
        return {"wall_time": self.wall_time, "trace_id": self.trace_id,
                "root": self.root.to_dict(self.root.t0)}

    def well_formed(self) -> bool:
        """Every span ended with t1 >= t0, and every child interval
        nested within its parent's — the property test's invariant."""
        def check(span: Span) -> bool:
            if span.t1 is None or span.t1 < span.t0:
                return False
            for c in span.children:
                if c.t0 < span.t0 or c.t1 is None or c.t1 > span.t1:
                    return False
                if not check(c):
                    return False
            return True
        return check(self.root)


class Tracer:
    """Sampling decision + ring buffer of finished traces.

    ``sample_every=N`` keeps every Nth query starting with the first;
    0 (the default) disables tracing entirely — ``start`` returns None
    and callers fall back to ``NULL_SPAN``.
    """

    def __init__(self, sample_every: int = 0, keep: int = 32):
        self.sample_every = int(sample_every)
        self._lock = threading.Lock()
        self._n = 0
        self.recent: "deque[QueryTrace]" = deque(maxlen=keep)
        self.last_trace: Optional[QueryTrace] = None

    @property
    def enabled(self) -> bool:
        return self.sample_every > 0

    def start(self, name: str, **attrs) -> Optional[QueryTrace]:
        if self.sample_every <= 0:
            return None
        with self._lock:
            n = self._n
            self._n += 1
        if n % self.sample_every:
            return None
        return QueryTrace(name, tracer=self, **attrs)

    def _record(self, trace: QueryTrace) -> None:
        with self._lock:
            self.recent.append(trace)
            self.last_trace = trace

    def export(self) -> List[Dict]:
        """JSON-friendly dump of the retained traces (oldest first)."""
        with self._lock:
            traces = list(self.recent)
        return [t.to_dict() for t in traces]
