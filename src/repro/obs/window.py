"""Rolling-window instruments: time-decaying counters and histograms
(DESIGN.md §8.4).

The lifetime instruments in ``metrics.py`` answer "since process
start"; an operator (and the SLO evaluator, ``obs/slo.py``) needs "over
the last minute". Both windowed kinds keep a ring of ``slices``
fixed-size sub-accumulators, each covering ``window_s / slices``
seconds of wall clock: an observe lands in the slice owning the current
instant, and advancing time *lazily* rotates the ring — the slice(s)
that fell out of the window are zeroed on the next observe or read, so
there is no rotation thread and an idle instrument costs nothing.

The window therefore covers between ``(slices-1)/slices * window_s``
and ``window_s`` seconds of data (standard ring approximation: the
oldest live slice is partially expired). Reads merge the live slices
into one :class:`~repro.obs.metrics.HistState`, so the merged-window
p50/p95/p99 use the *same* bucket-interpolation rule as the lifetime
histogram (``percentile_from_state``) and the two are directly
comparable.

Lock discipline matches ``metrics.py``: one lock per instrument, held
for the counter bump / slice merge only — never across a clock read by
callers, never nested. The 16-thread hammer test pins down that
concurrent ``observe`` + rotation loses no events while the window
covers them.

Windowed mins/maxes are per-slice, so the merged extremes decay with
the window — a latency spike ages out of the p99 after ``window_s``
seconds instead of pinning it forever (the reason lifetime histograms
cannot drive admission control; ROADMAP "tail-latency SLOs").
"""
from __future__ import annotations

import bisect
import math
import threading
import time
from typing import Dict, Optional, Tuple

from .metrics import (DEFAULT_MS_BUCKETS, HistState, fraction_le_from_state,
                      percentile_from_state)


class _Ring:
    """Shared rotation bookkeeping: slice index of 'now', lazy zeroing.
    Subclass under the instrument lock only."""

    def __init__(self, window_s: float, slices: int, clock):
        if window_s <= 0 or slices < 1:
            raise ValueError("window_s must be > 0 and slices >= 1")
        self.window_s = float(window_s)
        self.n_slices = int(slices)
        self._slice_s = self.window_s / self.n_slices
        self.clock = clock
        self._head = 0                       # ring index of current slice
        self._cur = int(clock() / self._slice_s)   # absolute slice number

    def _advance_locked(self) -> None:
        """Zero every slice the clock has moved past; caller holds the
        instrument lock. O(slices) worst case, O(1) amortized."""
        k = int(self.clock() / self._slice_s)
        if k <= self._cur:                   # same slice (monotonic clock)
            return
        for _ in range(min(k - self._cur, self.n_slices)):
            self._head = (self._head + 1) % self.n_slices
            self._clear_slice(self._head)
        self._cur = k

    def _clear_slice(self, i: int) -> None:  # pragma: no cover - abstract
        raise NotImplementedError


class WindowedCounter(_Ring):
    """Event count over the trailing window; ``rate_per_s`` divides by
    the window length (the scrape-friendly QPS estimator)."""

    def __init__(self, window_s: float = 60.0, slices: int = 6,
                 clock=time.monotonic):
        self._lock = threading.Lock()
        self._counts = [0] * int(slices)
        super().__init__(window_s, slices, clock)

    def _clear_slice(self, i: int) -> None:
        self._counts[i] = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._advance_locked()
            self._counts[self._head] += n

    def total(self) -> int:
        with self._lock:
            self._advance_locked()
            return sum(self._counts)

    def rate_per_s(self) -> float:
        return self.total() / self.window_s

    def stats(self) -> Dict[str, float]:
        t = self.total()
        return {"total": t, "rate_per_s": round(t / self.window_s, 6)}


class _HistSlice:
    __slots__ = ("counts", "sum", "count", "lo", "hi")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets
        self.clear()

    def clear(self):
        for i in range(len(self.counts)):
            self.counts[i] = 0
        self.sum = 0.0
        self.count = 0
        self.lo = math.inf
        self.hi = -math.inf


class WindowedHistogram(_Ring):
    """Fixed-bucket histogram over the trailing window. Same bucket
    bounds and quantile interpolation as the lifetime ``Histogram`` it
    twins (the registry passes the parent's ``bounds`` in), so
    ``p99`` here is the rolling analogue of the lifetime ``p99``."""

    def __init__(self, buckets: Optional[Tuple[float, ...]] = None,
                 window_s: float = 60.0, slices: int = 6,
                 clock=time.monotonic):
        self._lock = threading.Lock()
        self.bounds = tuple(sorted(buckets or DEFAULT_MS_BUCKETS))
        self._slices = [_HistSlice(len(self.bounds) + 1)
                        for _ in range(int(slices))]
        super().__init__(window_s, slices, clock)

    def _clear_slice(self, i: int) -> None:
        self._slices[i].clear()

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._advance_locked()
            s = self._slices[self._head]
            s.counts[i] += 1
            s.sum += v
            s.count += 1
            if v < s.lo:
                s.lo = v
            if v > s.hi:
                s.hi = v

    # -- read side -----------------------------------------------------
    def state(self) -> HistState:
        """Merged live slices as one atomic HistState (same shape the
        lifetime histogram's ``state()`` returns)."""
        with self._lock:
            self._advance_locked()
            counts = [0] * (len(self.bounds) + 1)
            total = 0
            sm = 0.0
            lo, hi = math.inf, -math.inf
            for s in self._slices:
                if not s.count:
                    continue
                for i, c in enumerate(s.counts):
                    counts[i] += c
                total += s.count
                sm += s.sum
                lo = min(lo, s.lo)
                hi = max(hi, s.hi)
            return HistState(tuple(counts), total, sm, lo, hi)

    @property
    def count(self) -> int:
        return self.state().total

    def percentile(self, q: float) -> float:
        return percentile_from_state(self.bounds, self.state(), q)

    def fraction_le(self, threshold: float) -> float:
        """Fraction of windowed observations <= threshold (1.0 when the
        window is empty: no traffic violates no latency objective)."""
        return fraction_le_from_state(self.bounds, self.state(), threshold)

    @property
    def p50(self) -> float:
        return self.percentile(0.50)

    @property
    def p95(self) -> float:
        return self.percentile(0.95)

    @property
    def p99(self) -> float:
        return self.percentile(0.99)

    def stats(self) -> Dict[str, float]:
        """The gauge payload the /metrics window section renders."""
        st = self.state()
        mean = st.sum / st.total if st.total else 0.0
        return {
            "count": st.total,
            "rate_per_s": round(st.total / self.window_s, 6),
            "mean": round(mean, 6),
            "p50": round(percentile_from_state(self.bounds, st, .50), 6),
            "p95": round(percentile_from_state(self.bounds, st, .95), 6),
            "p99": round(percentile_from_state(self.bounds, st, .99), 6),
        }
