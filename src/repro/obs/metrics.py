"""Thread-safe metrics registry: counters, gauges, and fixed-bucket
latency histograms (DESIGN.md §8.1).

The paper's claims are measurements — each pipeline stage (in-storage
filter, decode, match, merge) is timed and bounded — so the repro needs
one place those timings accumulate instead of four ad-hoc stat surfaces.
A ``MetricsRegistry`` is a process-scope (or test-scope) bag of named,
labeled metrics:

    reg.counter("queries_total", surface="store").inc()
    reg.histogram("stage_ms", stage="decode").observe(3.2)
    reg.gauge("slab_cache_bytes").set(cache.nbytes)

Metrics are get-or-create: the first call with a (name, labels) pair
creates the instrument, later calls return the same object, so hot
paths can hold the handle and skip the lookup. Every instrument carries
its own lock (Python ``+=`` is not atomic across bytecodes), which the
16-thread hammer test pins down: no lost increments.

Histograms use fixed upper-bound buckets (defaults tuned for
millisecond latencies) so ``observe`` is O(log buckets) with no
allocation; p50/p95/p99 are extracted by linear interpolation within
the winning bucket, with the observed min/max tightening the open ends.

``to_prometheus()`` renders the standard text exposition format;
``to_dict()`` is the JSON-friendly mirror. ``NULL_REGISTRY`` is the
no-op twin every instrumented path falls back to when observability is
disabled outright (``Obs.disabled()``) — same surface, zero work — so
the overhead knob is a constructor argument, not an if-tree.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, List, NamedTuple, Optional, Tuple

# default latency buckets (milliseconds): half-decade steps from 100us
# to 5s cover every stage this tree times (a cache hit is ~0.1 ms, a
# cold cluster scatter ~1s); +Inf is implicit
DEFAULT_MS_BUCKETS = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                      100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0)

LabelItems = Tuple[Tuple[str, str], ...]


class HistState(NamedTuple):
    """One atomic read of a histogram: bucket counts (incl. overflow),
    total count, sum, and the observed extremes — everything a torn-free
    render or quantile needs, captured under a single lock acquisition."""
    counts: Tuple[int, ...]
    total: int
    sum: float
    lo: float            # observed min (inf when empty)
    hi: float            # observed max (-inf when empty)


def percentile_from_state(bounds: Tuple[float, ...], state: HistState,
                          q: float) -> float:
    """q in [0, 1] -> quantile interpolated linearly inside the winning
    bucket, with the observed min/max tightening the open-ended first
    and overflow buckets. The one interpolation rule both the lifetime
    ``Histogram`` and the rolling ``WindowedHistogram`` share, so a
    merged-window p99 is directly comparable to the lifetime one."""
    if not state.total:
        return 0.0
    rank = q * state.total
    cum = 0
    for i, c in enumerate(state.counts):
        cum += c
        if not c or cum < rank:
            continue
        lo = bounds[i - 1] if i > 0 else min(state.lo, bounds[0])
        hi = bounds[i] if i < len(bounds) else state.hi
        lo = min(max(lo, state.lo), state.hi)
        hi = max(min(hi, state.hi), lo)
        return lo + (hi - lo) * (rank - (cum - c)) / c
    return state.hi          # all mass below rank (rounding): worst case


def fraction_le_from_state(bounds: Tuple[float, ...], state: HistState,
                           threshold: float) -> float:
    """Fraction of observations <= ``threshold``, interpolating inside
    the straddling bucket (the latency-SLO good-event estimator; 1.0
    when empty — no traffic violates no objective)."""
    if not state.total:
        return 1.0
    if threshold >= state.hi:
        return 1.0
    if threshold < state.lo:
        return 0.0
    cum = 0.0
    for i, c in enumerate(state.counts):
        lo = bounds[i - 1] if i > 0 else min(state.lo, bounds[0])
        hi = bounds[i] if i < len(bounds) else state.hi
        lo = min(max(lo, state.lo), state.hi)
        hi = max(min(hi, state.hi), lo)
        if threshold >= hi:
            cum += c
            continue
        if threshold > lo and hi > lo:
            cum += c * (threshold - lo) / (hi - lo)
        break
    return min(cum / state.total, 1.0)


class Counter:
    """Monotonic counter. ``window`` (attached by the registry) is an
    optional rolling-window twin every ``inc`` forwards to."""
    __slots__ = ("_lock", "_value", "window")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0
        self.window = None

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n
        w = self.window
        if w is not None:
            w.inc(n)

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value."""
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram with quantile extraction.

    ``bounds`` are inclusive upper bounds; one overflow (+Inf) bucket is
    appended. Quantiles interpolate linearly inside the winning bucket,
    using the observed min/max to tighten the first and last buckets —
    exact enough for stage attribution (the use case), cheap enough for
    the hot path (one bisect + one lock per observe).

    ``state()`` is the torn-free read: counts, total, sum, min, max
    captured under one lock acquisition, so a /metrics scrape can never
    pair a bucket vector with a count from a different instant.
    ``window`` (attached by the registry) is an optional rolling-window
    twin every ``observe`` forwards to (DESIGN.md §8.4).
    """
    __slots__ = ("_lock", "bounds", "_counts", "_sum", "_count",
                 "_min", "_max", "window")

    def __init__(self, buckets: Optional[Tuple[float, ...]] = None):
        bounds = tuple(sorted(buckets or DEFAULT_MS_BUCKETS))
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self._lock = threading.Lock()
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)
        self._sum = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf
        self.window = None

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
        w = self.window
        if w is not None:
            w.observe(v)

    # -- read side -----------------------------------------------------
    def state(self) -> HistState:
        """Everything the read side needs, under ONE lock acquisition."""
        with self._lock:
            return HistState(tuple(self._counts), self._count, self._sum,
                             self._min, self._max)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 1] -> interpolated quantile (0.0 when empty)."""
        return percentile_from_state(self.bounds, self.state(), q)

    def fraction_le(self, threshold: float) -> float:
        """Estimated fraction of observations <= threshold (SLO input)."""
        return fraction_le_from_state(self.bounds, self.state(), threshold)

    @property
    def p50(self) -> float:
        return self.percentile(0.50)

    @property
    def p95(self) -> float:
        return self.percentile(0.95)

    @property
    def p99(self) -> float:
        return self.percentile(0.99)

    def summary(self) -> Dict[str, float]:
        """One JSON-friendly snapshot (the BENCH-row payload), computed
        from a single atomic state read."""
        st = self.state()
        mean = st.sum / st.total if st.total else 0.0
        return {"count": st.total, "sum": round(st.sum, 3),
                "mean": round(mean, 3),
                "p50": round(percentile_from_state(self.bounds, st, .50), 3),
                "p95": round(percentile_from_state(self.bounds, st, .95), 3),
                "p99": round(percentile_from_state(self.bounds, st, .99), 3)}

    def buckets(self) -> List[Tuple[float, int]]:
        """(upper bound, cumulative count) pairs, Prometheus-style."""
        counts = self.state().counts
        out, cum = [], 0
        for bound, c in zip(self.bounds + (math.inf,), counts):
            cum += c
            out.append((bound, cum))
        return out


_EMPTY_STATE = HistState((0,), 0, 0.0, math.inf, -math.inf)


class _NullMetric:
    """Shared no-op instrument: same surface as all three kinds."""
    __slots__ = ()
    value = 0
    count = 0
    sum = 0.0
    mean = 0.0
    p50 = p95 = p99 = 0.0
    window = None
    bounds = (math.inf,)

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def percentile(self, q):
        return 0.0

    def fraction_le(self, threshold):
        return 1.0

    def state(self):
        return _EMPTY_STATE

    def summary(self):
        return {}

    def buckets(self):
        return []


NULL_METRIC = _NullMetric()

_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named, labeled instruments with get-or-create semantics.

    When ``windows`` is on (the default), every counter and histogram
    gets a rolling-window twin (``obs/window.py``) attached at creation
    and forwarded to on each ``inc``/``observe`` — the lifetime
    instrument answers "since process start", the twin answers "over the
    last ``window_s`` seconds" (what SLO burn rates and live dashboards
    need; DESIGN.md §8.4). ``windowed(name, **labels)`` fetches a twin.
    """

    def __init__(self, *, windows: bool = True, window_s: float = 60.0,
                 window_slices: int = 6, clock=None):
        self._lock = threading.Lock()
        # (name, sorted label items) -> (kind, labels dict, instrument)
        self._metrics: Dict[Tuple[str, LabelItems], Tuple[str, Dict, object]] = {}
        self.window_s = float(window_s)
        self.window_slices = int(window_slices)
        self._windows = bool(windows)
        self._clock = clock

    def _attach_window(self, kind: str, metric) -> None:
        if not self._windows:
            return
        from .window import WindowedCounter, WindowedHistogram
        kw = {"window_s": self.window_s, "slices": self.window_slices}
        if self._clock is not None:
            kw["clock"] = self._clock
        if kind == "counter":
            metric.window = WindowedCounter(**kw)
        elif kind == "histogram":
            metric.window = WindowedHistogram(metric.bounds, **kw)

    def _get(self, kind: str, name: str, labels: Dict[str, str],
             **kwargs):
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        with self._lock:
            slot = self._metrics.get(key)
            if slot is None:
                metric = _KINDS[kind](**kwargs)
                self._attach_window(kind, metric)
                slot = (kind, dict(key[1]), metric)
                self._metrics[key] = slot
            elif slot[0] != kind:
                raise TypeError(
                    f"metric {name!r} already registered as {slot[0]}, "
                    f"not {kind}")
            return slot[2]

    def windowed(self, name: str, **labels):
        """The rolling-window twin of an existing counter/histogram, or
        None (unknown metric, gauge, or windows disabled). Never
        creates an instrument — the SLO evaluator must not invent
        series that no hot path feeds."""
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        with self._lock:
            slot = self._metrics.get(key)
        return getattr(slot[2], "window", None) if slot else None

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str,
                  buckets: Optional[Tuple[float, ...]] = None,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels, buckets=buckets)

    # -- introspection / export ----------------------------------------
    def items(self) -> List[Tuple[str, Dict[str, str], str, object]]:
        """(name, labels, kind, instrument), sorted by (name, labels)."""
        with self._lock:
            entries = sorted(self._metrics.items())
        return [(name, dict(labelitems), kind, metric)
                for (name, labelitems), (kind, _, metric) in entries]

    def __len__(self) -> int:
        with self._lock:
            return len(self._metrics)

    def to_dict(self) -> Dict:
        """JSON-friendly snapshot: name -> [{labels, value|summary}]."""
        out: Dict[str, List] = {}
        for name, labels, kind, metric in self.items():
            entry = {"labels": labels}
            if kind == "histogram":
                entry.update(metric.summary())
            else:
                entry["value"] = metric.value
            out.setdefault(name, []).append(entry)
        return out

    def to_prometheus(self, prefix: str = "repro",
                      include_windows: bool = False) -> str:
        """Standard Prometheus text exposition of every instrument.

        Each histogram is rendered from ONE atomic ``state()`` read, so
        a scrape never sees a ``_count`` inconsistent with its bucket
        vector (the torn-registry hazard the telemetry server's
        ``/metrics`` endpoint must never expose). With
        ``include_windows`` the rolling-window twins are appended as
        ``{name}_window`` gauges labeled with the window length and a
        ``stat`` (p50/p95/p99/count/rate_per_s for histograms,
        total/rate_per_s for counters)."""
        lines: List[str] = []
        window_lines: List[str] = []
        last_name = None
        for name, labels, kind, metric in self.items():
            full = f"{prefix}_{name}" if prefix else name
            if name != last_name:
                lines.append(f"# TYPE {full} {kind}")
                last_name = name
            if kind == "histogram":
                st = metric.state()
                cum = 0
                for bound, c in zip(metric.bounds + (math.inf,), st.counts):
                    cum += c
                    le = "+Inf" if math.isinf(bound) else f"{bound:g}"
                    lines.append(f"{full}_bucket"
                                 f"{_fmt_labels(labels, le=le)} {cum}")
                lines.append(f"{full}_sum{_fmt_labels(labels)} "
                             f"{st.sum:g}")
                lines.append(f"{full}_count{_fmt_labels(labels)} "
                             f"{st.total}")
            else:
                lines.append(f"{full}{_fmt_labels(labels)} "
                             f"{metric.value:g}")
            w = include_windows and getattr(metric, "window", None)
            if w:
                if not window_lines or not window_lines[-1].startswith(
                        f"{full}_window"):
                    window_lines.append(f"# TYPE {full}_window gauge")
                wtag = f"{w.window_s:g}s"
                for stat, v in w.stats().items():
                    window_lines.append(
                        f"{full}_window"
                        f"{_fmt_labels(labels, window=wtag, stat=stat)} "
                        f"{v:g}")
        lines.extend(window_lines)
        return "\n".join(lines) + ("\n" if lines else "")


class _NullRegistry:
    """No-op registry (``Obs.disabled()``): hot paths keep their handle
    pattern, every instrument is the shared ``NULL_METRIC``."""
    __slots__ = ()

    def counter(self, name, **labels):
        return NULL_METRIC

    def gauge(self, name, **labels):
        return NULL_METRIC

    def histogram(self, name, buckets=None, **labels):
        return NULL_METRIC

    def windowed(self, name, **labels):
        return None

    def items(self):
        return []

    def __len__(self):
        return 0

    def to_dict(self):
        return {}

    def to_prometheus(self, prefix="repro", include_windows=False):
        return ""


NULL_REGISTRY = _NullRegistry()


def _fmt_labels(labels: Dict[str, str], **extra) -> str:
    merged = dict(labels, **extra)
    if not merged:
        return ""
    body = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in sorted(merged.items()))
    return "{" + body + "}"
