"""Telemetry HTTP server: /metrics, /healthz, /slo, /debug/traces,
/debug/profile (DESIGN.md §8.5).

A stdlib ``ThreadingHTTPServer`` on a daemon thread — no new
dependencies — turning the in-process ``Obs`` bundle into the
scrapeable surface a multi-process cluster needs (ROADMAP "scale-out"):

- ``GET /metrics``   Prometheus text exposition, rolling-window gauges
  included. Rendering is snapshot-atomic per instrument (one locked
  ``state()`` read per histogram), so a scrape never observes a torn
  registry — a ``_count`` that disagrees with its bucket vector.
- ``GET /healthz``   JSON aggregation of registered health sources
  (ShardRouter replica rotation, ingest WAL/compactor liveness).
  Status ``ok``/``degraded`` answer 200, ``down`` answers 503, so a
  load balancer can act on the code alone.
- ``GET /slo``       JSON of every objective's burn state (§8.4).
- ``GET /debug/traces``  JSON dump of the tracer's retained traces.
- ``GET /debug/profile?ms=N``  opt-in ``jax.profiler`` capture: writes
  a trace of the next N ms (default 500, capped at 10 s) under the
  server's ``profile_dir``. 409 when profiling wasn't enabled, 423
  while another capture is running.

Handlers only *read* instruments (capture aside); nothing here is on a
query path. The server binds loopback by default — operators proxy it,
the repo never exposes raw telemetry on all interfaces by accident.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Optional
from urllib.parse import parse_qs, urlparse

HealthSource = Callable[[], Dict]

_STATUS_RANK = {"ok": 0, "degraded": 1, "down": 2}


def aggregate_health(components: Dict[str, Dict]) -> str:
    """Worst-of component statuses (missing/invalid counts as down)."""
    worst = "ok"
    for comp in components.values():
        s = comp.get("status", "down")
        if s not in _STATUS_RANK:       # an unknown status is not healthy
            s = "down"
        if _STATUS_RANK[s] > _STATUS_RANK[worst]:
            worst = s
    return worst


def router_health_source(router) -> HealthSource:
    """ShardRouter replica rotation -> health component. A shard with
    every replica out of rotation cannot serve: ``down``. Any replica
    out while a sibling covers it: ``degraded``."""
    def probe() -> Dict:
        health = router.health()          # [[in_rotation per replica]]
        dead_shards = [s for s, row in enumerate(health) if not any(row)]
        down_reps = sum(not ok for row in health for ok in row)
        status = ("down" if dead_shards
                  else "degraded" if down_reps else "ok")
        return {"status": status,
                "shards": len(health),
                "replicas_down": down_reps,
                "dead_shards": dead_shards,
                "failovers": router.failovers,
                "rotation": health}
    return probe


def ingest_health_source(pipelines_fn: Callable[[], List]) -> HealthSource:
    """Ingest pipeline liveness: WAL open + compactor thread alive for
    every live pipeline. ``pipelines_fn`` is called per probe so a
    pipeline attached after the server started is still covered."""
    def probe() -> Dict:
        pipes = [p for p in pipelines_fn() if p is not None]
        detail = []
        status = "ok"
        for p in pipes:
            closed = bool(getattr(p, "_closed", False))
            compactor = getattr(p, "_compactor", None)
            wants_compactor = bool(getattr(p.cfg, "auto_compact", False))
            compactor_ok = (not wants_compactor
                            or (compactor is not None and
                                compactor.is_alive()))
            if closed or not compactor_ok:
                status = "down" if closed else "degraded"
            detail.append({"root": getattr(p.store, "root", "?"),
                           "closed": closed,
                           "compactor_alive": bool(
                               compactor is not None and
                               compactor.is_alive()),
                           "wal_seq": getattr(p.wal, "last_seq", None),
                           "memtable_docs": len(p.memtable)})
        return {"status": status, "pipelines": len(pipes),
                "detail": detail}
    return probe


def register_searcher_health(server: "TelemetryServer", searcher) -> None:
    """Wire whichever health surfaces ``searcher`` exposes: a cluster
    session's router, or a store session's ingest pipeline(s)."""
    router = getattr(searcher, "router", None)
    if router is not None:
        server.add_health_source("router", router_health_source(router))
        server.add_health_source(
            "ingest", ingest_health_source(router.ingest_pipelines))
    elif hasattr(searcher, "ingest"):
        server.add_health_source(
            "ingest",
            ingest_health_source(lambda: [getattr(searcher, "ingest",
                                                  None)]))


class TelemetryServer:
    """The live scrape surface for one ``Obs`` bundle. ``port=0`` binds
    an ephemeral port (tests); the bound one is ``self.port``."""

    def __init__(self, obs, *, host: str = "127.0.0.1", port: int = 0,
                 slo_monitor=None, profile_dir: Optional[str] = None,
                 prefix: str = "repro"):
        self.obs = obs
        self.slo_monitor = slo_monitor
        self.profile_dir = profile_dir
        self.prefix = prefix
        self._health_sources: Dict[str, HealthSource] = {}
        self._health_lock = threading.Lock()
        self._profile_lock = threading.Lock()
        server = self

        class _Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):      # quiet: scrapes are periodic
                pass

            def do_GET(self):
                try:
                    server._route(self)
                except BrokenPipeError:     # scraper went away mid-write
                    pass
                except Exception as e:      # a probe must never kill the
                    try:                    # serving thread
                        self.send_error(500, explain=repr(e))
                    except Exception:
                        pass

        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.2},
            daemon=True, name=f"telemetry-:{self.port}")
        self._thread.start()

    # -- wiring --------------------------------------------------------
    def add_health_source(self, name: str, source: HealthSource) -> None:
        with self._health_lock:
            self._health_sources[name] = source

    def url(self, path: str = "/metrics") -> str:
        return f"http://{self.host}:{self.port}{path}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- routing -------------------------------------------------------
    def _route(self, h: BaseHTTPRequestHandler) -> None:
        parsed = urlparse(h.path)
        path = parsed.path.rstrip("/") or "/"
        if path == "/metrics":
            body = self.obs.registry.to_prometheus(
                prefix=self.prefix, include_windows=True)
            self._send(h, 200, body, "text/plain; version=0.0.4")
        elif path == "/healthz":
            status, payload = self.healthz()
            self._send_json(h, 200 if status != "down" else 503, payload)
        elif path == "/slo":
            self._send_json(h, 200, self.slo_snapshot())
        elif path == "/debug/traces":
            self._send_json(h, 200, {
                "schema": "repro-traces-v1",
                "traces": self.obs.tracer.export()})
        elif path == "/debug/profile":
            self._profile(h, parse_qs(parsed.query))
        else:
            self._send_json(h, 404, {
                "error": f"no route {path!r}",
                "routes": ["/metrics", "/healthz", "/slo",
                           "/debug/traces", "/debug/profile"]})

    # -- endpoint bodies (callable without HTTP for tests/summaries) ---
    def healthz(self):
        with self._health_lock:
            sources = dict(self._health_sources)
        components: Dict[str, Dict] = {}
        for name, probe in sources.items():
            try:
                components[name] = probe()
            except Exception as e:          # a broken probe is itself a
                components[name] = {"status": "down",   # health signal
                                    "error": repr(e)}
        status = aggregate_health(components) if components else "ok"
        return status, {"status": status, "components": components}

    def slo_snapshot(self) -> Dict:
        if self.slo_monitor is None:
            return {"slos": [], "note": "no SLO objectives configured"}
        return {"slos": [s.to_dict() for s in self.slo_monitor.evaluate()]}

    def _profile(self, h, query: Dict) -> None:
        if not self.profile_dir:
            self._send_json(h, 409, {
                "error": "profiling disabled: start the server with "
                         "profile_dir (search_serve --profile-dir)"})
            return
        ms = max(1, min(int(query.get("ms", ["500"])[0]), 10_000))
        if not self._profile_lock.acquire(blocking=False):
            self._send_json(h, 423, {"error": "capture already running"})
            return
        try:
            import time as _time

            import jax
            with jax.profiler.trace(self.profile_dir):
                _time.sleep(ms / 1e3)
        except Exception as e:
            self._send_json(h, 500, {"error": f"profiler failed: {e!r}"})
            return
        finally:
            self._profile_lock.release()
        self._send_json(h, 200, {"captured_ms": ms,
                                 "dir": self.profile_dir})

    # -- plumbing ------------------------------------------------------
    @staticmethod
    def _send(h, code: int, body: str, ctype: str) -> None:
        data = body.encode()
        h.send_response(code)
        h.send_header("Content-Type", ctype)
        h.send_header("Content-Length", str(len(data)))
        h.end_headers()
        h.wfile.write(data)

    @classmethod
    def _send_json(cls, h, code: int, payload) -> None:
        cls._send(h, code, json.dumps(payload, indent=1),
                  "application/json")


def start_telemetry(searcher, *, port: int = 0, host: str = "127.0.0.1",
                    slo_monitor=None,
                    profile_dir: Optional[str] = None) -> TelemetryServer:
    """One-call wiring for any serving target: build a server on the
    searcher's ``Obs`` bundle and register its health surfaces."""
    obs = getattr(searcher, "obs", None)
    if obs is None:
        raise ValueError("searcher has no obs bundle to serve")
    server = TelemetryServer(obs, host=host, port=port,
                             slo_monitor=slo_monitor,
                             profile_dir=profile_dir)
    register_searcher_health(server, searcher)
    return server
