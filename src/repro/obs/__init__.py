"""Unified observability layer (DESIGN.md §8): metrics registry,
per-query trace spans, and exporters.

``Obs`` bundles the two instruments every tier shares — a
``MetricsRegistry`` (always on; counters and stage-latency histograms
are cheap enough to leave running) and a ``Tracer`` (off by default;
``trace_sample=N`` samples every Nth query into a ``QueryTrace``
tree) — plus a ring buffer of recent query records that
``slow_query_log()`` filters by threshold.

Sessions, routers, services, and pipelines all take ``obs=None`` and
fall back to the process-wide ``default_obs()``, so sharing one
registry across a cluster's shard sessions needs no plumbing, while a
benchmark that wants clean numbers passes its own ``Obs()`` (or
``Obs.disabled()`` to measure the instrumentation floor).

PR 8 adds the live plane on top: every counter/histogram carries a
rolling-window twin (``obs/window.py``, §8.4), SLO burn states evaluate
against those windows (``obs/slo.py``), and ``obs/server.py`` serves
the whole bundle over HTTP. ``device_fence=True`` opts the engine into
``block_until_ready`` fencing so ``stage_ms`` splits score time into
dispatch vs device (default off: fencing serializes the pipeline).
"""
from __future__ import annotations

import math
import threading
import time
from collections import deque
from typing import Dict, List, Optional

from .metrics import (DEFAULT_MS_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, NULL_METRIC, NULL_REGISTRY)
from .trace import NULL_SPAN, QueryTrace, Span, Tracer

__all__ = [
    "DEFAULT_MS_BUCKETS", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_METRIC", "NULL_REGISTRY", "NULL_SPAN",
    "Obs", "QueryTrace", "Span", "Tracer", "default_obs",
]

# fields mirrored one-to-one from a per-query SearchStats (or the
# ClusterStats aggregate, which exposes the same names) into counters
_STAT_COUNTERS = ("segments_total", "segments_skipped", "segments_scored",
                  "docs_scored", "pairs_truncated", "memtable_docs",
                  "cache_hits", "cache_misses", "cache_evictions")


class Obs:
    """Registry + tracer + recent-query ring, shared down a tier."""

    def __init__(self, *, registry: Optional[MetricsRegistry] = None,
                 trace_sample: int = 0, slow_ms: float = 250.0,
                 keep_traces: int = 32, keep_queries: int = 256,
                 window_s: float = 60.0, window_slices: int = 6,
                 device_fence: bool = False):
        self.enabled = True
        self.registry = (MetricsRegistry(window_s=window_s,
                                         window_slices=window_slices)
                         if registry is None else registry)
        self.tracer = Tracer(sample_every=trace_sample, keep=keep_traces)
        self.slow_ms = float(slow_ms)
        self.device_fence = bool(device_fence)
        self._queries: deque = deque(maxlen=keep_queries)
        self._q_lock = threading.Lock()

    @classmethod
    def disabled(cls) -> "Obs":
        """The instrumentation floor: null registry, tracing off,
        ``note_query`` a no-op. Used by the storage_bench overhead gate
        to price the always-on half of the layer."""
        obs = cls.__new__(cls)
        obs.enabled = False
        obs.registry = NULL_REGISTRY
        obs.tracer = Tracer(sample_every=0, keep=1)
        obs.slow_ms = math.inf
        obs.device_fence = False
        obs._queries = deque(maxlen=1)
        obs._q_lock = threading.Lock()
        return obs

    # -- query accounting ----------------------------------------------
    def note_query(self, surface: str, wall_ms: float, **info) -> None:
        """Record one finished query: wall-time histogram + the recent
        ring ``slow_query_log`` reads."""
        if not self.enabled:
            return
        self.registry.histogram("query_ms", surface=surface).observe(wall_ms)
        rec = {"surface": surface, "wall_ms": round(float(wall_ms), 3),
               "time": time.time()}
        rec.update(info)
        with self._q_lock:
            self._queries.append(rec)

    def slow_query_log(self, threshold_ms: Optional[float] = None
                       ) -> List[Dict]:
        """Recent queries at least ``threshold_ms`` slow (default: the
        configured ``slow_ms``), slowest first."""
        thr = self.slow_ms if threshold_ms is None else float(threshold_ms)
        with self._q_lock:
            recs = list(self._queries)
        return sorted((r for r in recs if r["wall_ms"] >= thr),
                      key=lambda r: -r["wall_ms"])

    def publish_search_stats(self, stats, *, surface: str) -> None:
        """Mirror one query's SearchStats/ClusterStats deltas into the
        registry (monotonic counters, unlike the per-query dataclass)."""
        if not self.enabled or stats is None:
            return
        reg = self.registry
        reg.counter("queries_total", surface=surface).inc()
        for field in _STAT_COUNTERS:
            v = getattr(stats, field, 0) or 0
            if v:
                reg.counter(field + "_total", surface=surface).inc(int(v))

    def publish_cache(self, cache) -> None:
        """Snapshot a SlabCache's lifetime state into gauges (export
        time only — the cache keeps its own counters)."""
        if not self.enabled or cache is None:
            return
        reg = self.registry
        reg.gauge("slab_cache_bytes").set(cache.nbytes)
        reg.gauge("slab_cache_entries").set(len(cache))
        st = cache.stats_snapshot()
        reg.gauge("slab_cache_hits_lifetime").set(st.hits)
        reg.gauge("slab_cache_misses_lifetime").set(st.misses)
        reg.gauge("slab_cache_evictions_lifetime").set(st.evictions)
        reg.gauge("slab_cache_invalidations_lifetime").set(st.invalidations)


_DEFAULT_LOCK = threading.Lock()
_DEFAULT: Optional[Obs] = None


def default_obs() -> Obs:
    """Process-wide fallback bundle (created on first use)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = Obs()
        return _DEFAULT
