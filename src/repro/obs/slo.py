"""Declarative SLOs evaluated against the rolling windows
(DESIGN.md §8.4).

An :class:`SLObjective` names a target the serving plane must hold —
"99% of store queries under 250 ms", "99.9% of cluster queries
succeed" — and :class:`SLOMonitor` prices the live system against it
using the §8.4 window twins (burn rate: how fast is the error budget
being spent *right now*) and the lifetime instruments (budget: how much
has been spent since process start):

- **latency** objectives read a latency histogram (``query_ms`` by
  surface, ``cluster_shard_ms`` by shard, ...); the good-event fraction
  is the interpolated mass at or under ``threshold_ms``.
- **availability** objectives read an event counter and its error
  counter (``queries_total`` / ``query_errors_total``); good fraction
  is ``1 - errors/total``.

Each evaluation derives:

- ``good_fraction`` over the rolling window (None with no traffic);
- ``burn_rate`` = (window bad fraction) / (allowed bad fraction) — 1.0
  means the budget is being consumed exactly at the sustainable pace,
  >1 means the window is out of objective;
- ``budget_remaining`` = 1 - (lifetime bad fraction)/(allowed) — the
  cumulative error budget left, clamped to [-inf, 1];
- ``state``: ``ok`` (burn <= 1), ``burning`` (burn > 1 but budget
  left), ``exhausted`` (budget spent). No traffic is ``ok``: an idle
  window burns nothing.

``evaluate()`` also mirrors every status into registry gauges
(``slo_good_fraction`` / ``slo_burn_rate`` / ``slo_budget_remaining`` /
``slo_state`` with 0=ok 1=burning 2=exhausted), so a plain /metrics
scrape carries the SLO plane without calling /slo. This is deliberately
the enabling half of the ROADMAP's tail-latency item: admission control
and shedding act on these burn states.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

STATE_OK = "ok"
STATE_BURNING = "burning"
STATE_EXHAUSTED = "exhausted"
_STATE_CODE = {STATE_OK: 0, STATE_BURNING: 1, STATE_EXHAUSTED: 2}


@dataclasses.dataclass(frozen=True)
class SLObjective:
    """One declarative objective. ``labels`` selects the instrument
    series (e.g. ``(("surface", "store"),)``); use :func:`latency_slo` /
    :func:`availability_slo` instead of spelling the tuples out."""
    name: str
    kind: str                            # "latency" | "availability"
    metric: str                          # histogram or total-counter name
    labels: Tuple[Tuple[str, str], ...]
    target: float                        # good-event target in (0, 1]
    threshold_ms: float = 0.0            # latency only
    error_metric: str = ""               # availability only

    def __post_init__(self):
        if not 0.0 < self.target <= 1.0:
            raise ValueError(f"target must be in (0, 1], got {self.target}")
        if self.kind not in ("latency", "availability"):
            raise ValueError(f"unknown SLO kind {self.kind!r}")

    @property
    def label_dict(self) -> Dict[str, str]:
        return dict(self.labels)


def latency_slo(name: str, *, threshold_ms: float, target: float = 0.99,
                metric: str = "query_ms", **labels) -> SLObjective:
    """``target`` fraction of ``metric{labels}`` at or under
    ``threshold_ms``."""
    return SLObjective(name=name, kind="latency", metric=metric,
                       labels=tuple(sorted((k, str(v))
                                           for k, v in labels.items())),
                       target=target, threshold_ms=float(threshold_ms))


def availability_slo(name: str, *, target: float = 0.999,
                     metric: str = "queries_total",
                     error_metric: str = "query_errors_total",
                     **labels) -> SLObjective:
    """``target`` fraction of ``metric{labels}`` events without a
    matching ``error_metric{labels}`` error."""
    return SLObjective(name=name, kind="availability", metric=metric,
                       labels=tuple(sorted((k, str(v))
                                           for k, v in labels.items())),
                       target=target, error_metric=error_metric)


def default_slos(surface: str, *, latency_ms: float = 250.0,
                 latency_target: float = 0.99,
                 availability_target: float = 0.999) -> List[SLObjective]:
    """The stock per-surface pair every serving target starts with."""
    return [
        latency_slo(f"{surface}-latency", threshold_ms=latency_ms,
                    target=latency_target, surface=surface),
        availability_slo(f"{surface}-availability",
                         target=availability_target, surface=surface),
    ]


@dataclasses.dataclass
class SLOStatus:
    """One evaluation of one objective (JSON-friendly via ``to_dict``)."""
    name: str
    kind: str
    target: float
    state: str
    good_fraction: Optional[float]       # rolling window; None = idle
    burn_rate: float
    budget_remaining: float
    window_events: int
    lifetime_events: int
    detail: str = ""

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        for k in ("good_fraction", "burn_rate", "budget_remaining"):
            if d[k] is not None:
                d[k] = round(d[k], 6)
        return d


class SLOMonitor:
    """Evaluates objectives against an ``Obs`` bundle's registry.

    Stateless between evaluations — both the window and the lifetime
    numbers live in the instruments themselves, so any number of
    monitors (or scrapes) agree."""

    def __init__(self, obs, objectives: List[SLObjective]):
        self.obs = obs
        self.objectives = list(objectives)

    def add(self, objective: SLObjective) -> None:
        self.objectives.append(objective)

    # -- per-kind good/total extraction --------------------------------
    def _latency(self, o: SLObjective):
        reg = self.obs.registry
        hist = reg.histogram(o.metric, **o.label_dict)
        w = reg.windowed(o.metric, **o.label_dict)
        life_st = hist.state()
        life = (life_st.total,
                hist.fraction_le(o.threshold_ms) if life_st.total else None)
        if w is None:
            return (0, None), life
        wst = w.state()
        win = (wst.total,
               w.fraction_le(o.threshold_ms) if wst.total else None)
        return win, life

    def _availability(self, o: SLObjective):
        reg = self.obs.registry
        total_c = reg.counter(o.metric, **o.label_dict)
        err_c = reg.counter(o.error_metric, **o.label_dict)
        lt, le = total_c.value, err_c.value
        life = (lt, (1.0 - min(le, lt) / lt) if lt else None)
        wt_c = reg.windowed(o.metric, **o.label_dict)
        we_c = reg.windowed(o.error_metric, **o.label_dict)
        if wt_c is None:
            return (0, None), life
        wt = wt_c.total()
        we = we_c.total() if we_c is not None else 0
        win = (wt, (1.0 - min(we, wt) / wt) if wt else None)
        return win, life

    # -- evaluation ----------------------------------------------------
    def evaluate(self) -> List[SLOStatus]:
        out = []
        for o in self.objectives:
            (w_n, w_good), (l_n, l_good) = (
                self._latency(o) if o.kind == "latency"
                else self._availability(o))
            allowed = 1.0 - o.target           # tolerable bad fraction
            burn = 0.0
            if w_good is not None:
                bad = 1.0 - w_good
                burn = (bad / allowed) if allowed > 0 else (
                    float("inf") if bad > 0 else 0.0)
            remaining = 1.0
            if l_good is not None:
                l_bad = 1.0 - l_good
                remaining = (1.0 - l_bad / allowed) if allowed > 0 else (
                    1.0 if l_bad == 0 else float("-inf"))
            if remaining <= 0.0:
                state = STATE_EXHAUSTED
            elif burn > 1.0:
                state = STATE_BURNING
            else:
                state = STATE_OK
            detail = (f"{o.metric} p<= {o.threshold_ms:g}ms"
                      if o.kind == "latency"
                      else f"{o.error_metric}/{o.metric}")
            st = SLOStatus(name=o.name, kind=o.kind, target=o.target,
                           state=state, good_fraction=w_good,
                           burn_rate=burn, budget_remaining=remaining,
                           window_events=w_n, lifetime_events=l_n,
                           detail=detail)
            self._publish(st)
            out.append(st)
        return out

    def _publish(self, st: SLOStatus) -> None:
        reg = self.obs.registry
        if st.good_fraction is not None:
            reg.gauge("slo_good_fraction", slo=st.name).set(st.good_fraction)
        reg.gauge("slo_burn_rate", slo=st.name).set(
            st.burn_rate if st.burn_rate != float("inf") else 1e9)
        reg.gauge("slo_budget_remaining", slo=st.name).set(
            max(st.budget_remaining, -1e9))
        reg.gauge("slo_state", slo=st.name).set(_STATE_CODE[st.state])
