"""Int8 gradient compression with error feedback for the multi-pod DP axis.

Across pods the per-pod gradient replicas must be averaged over a link that
is far thinner than in-pod ICI (DCN in practice). We compress that
all-reduce: block-quantize (g + err) to int8, all_gather the int8 payloads
(+ fp32 block scales), dequantize + average locally, and keep the residual
as the next step's error feedback. Wire bytes drop ~3.7x vs fp32
all-reduce; error feedback keeps the long-run gradient unbiased
(1-bit Adam / EF-SGD lineage).

Implemented as a shard_map over only the ``pod`` axis so it composes with
the jit-SPMD sharding of everything else.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from repro.distributed.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.meshctx import MeshCtx
from repro.train.optimizer import QTensor, dequantize_block, quantize_block


def compressed_mean_tree(grads, err, ctx: MeshCtx, axis: str = "pod"):
    """Per-leaf compressed mean over ``axis``. grads/err: matching trees
    (err fp32). Returns (mean_grads, new_err). Must be called inside a
    shard_map (or jit program) where ``axis`` is a manual mesh axis."""
    n = ctx.mesh.shape[axis]

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        qt = quantize_block(gf)
        new_e = gf - dequantize_block(qt)
        gq = jax.lax.all_gather(qt.q, axis)          # [n, nb, B] int8 wire
        gs = jax.lax.all_gather(qt.scale, axis)      # [n, nb] fp32
        total = jnp.zeros(gf.shape, jnp.float32)
        for i in range(n):
            total = total + dequantize_block(
                QTensor(q=gq[i], scale=gs[i], shape=gf.shape))
        return (total / n).astype(g.dtype), new_e

    out = jax.tree.map(one, grads, err)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_err


def make_pod_grad_reducer(ctx: MeshCtx, grads_like, compress: bool):
    """Returns f(grads, err) -> (mean_grads, err') reducing over the 'pod'
    axis. Identity when there is no pod axis."""
    if "pod" not in ctx.mesh.axis_names:
        return lambda g, e: (g, e)

    if not compress:
        def psum_mean(grads, err):
            f = shard_map(
                lambda g: jax.tree.map(
                    lambda x: jax.lax.pmean(x, "pod"), g),
                mesh=ctx.mesh, in_specs=P(), out_specs=P(),
                axis_names={"pod"}, check_vma=False)
            return f(grads), err
        return psum_mean

    def reducer(grads, err):
        f = shard_map(
            lambda g, e: compressed_mean_tree(g, e, ctx, "pod"),
            mesh=ctx.mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            axis_names={"pod"}, check_vma=False)
        return f(grads, err)
    return reducer


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
