"""Mesh context threaded through model apply functions.

Carries the physical mesh plus the role of each axis so modules that need
explicit collectives (MoE all_to_all dispatch) can name them. ``dp_axes``
shard the batch (("pod","data") multi-pod, ("data",) single-pod), ``fsdp``
is the axis params are fully-sharded over, ``tp`` shards
heads / d_ff / experts / vocab.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh
    dp_axes: Tuple[str, ...] = ("data",)
    fsdp_axis: str = "data"
    tp_axis: str = "model"

    @property
    def dp_size(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.dp_axes)

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


def single_device_ctx() -> MeshCtx:
    """1-device mesh with production axis names — smoke tests run the exact
    same (shard_map-containing) code paths on CPU."""
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    return MeshCtx(mesh=mesh, dp_axes=("data",), fsdp_axis="data",
                   tp_axis="model")
