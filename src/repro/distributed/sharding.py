"""Parameter sharding rules (2-D: FSDP over ``data`` x TP over ``model``).

Rules are name-based over the param tree with divisibility guards: a dim is
sharded over an axis only if it divides evenly AND (for attention) head
boundaries stay aligned — otherwise that dim is replicated (e.g. qwen2's 14
heads and musicgen's 24 heads on a 16-way model axis: attention weights
replicate over ``model`` while FFN/vocab still shard; the small models'
attention doesn't need TP).

``pod`` is a pure-DP axis: params are replicated over it; gradients reduce
across it (optionally int8-compressed, distributed/compression.py).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.meshctx import MeshCtx

# weight classes: which of the last two dims carries TP
_OUT_TP = {"wq", "wk", "wv", "wg", "w_gate", "w_up", "wr"}
_IN_TP = {"wo", "w_down", "out_proj", "wv_cm"}
_REPLICATE = {"router", "wA", "wB", "conv_w", "A_log", "D", "dt_bias",
              "w0", "u", "in_proj"}


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _leaf_spec(path_names, shape, cfg: ModelConfig, ctx: MeshCtx):
    fsdp, tp = ctx.fsdp_axis, ctx.tp_axis
    fs, ts = ctx.mesh.shape[fsdp], ctx.mesh.shape[tp]
    name = path_names[-1]
    parent = path_names[-2] if len(path_names) > 1 else ""

    def guard(axis_ok, dim, size):
        return axis_ok and _div(shape[dim], size)

    # embedding
    if name == "table":
        return P(tp if _div(shape[0], ts) else None,
                 fsdp if _div(shape[1], fs) else None)
    if name == "head":
        return P(fsdp if _div(shape[0], fs) else None,
                 tp if _div(shape[1], ts) else None)

    # attention head guards
    attn_ok_q = _div(cfg.n_heads, ts)
    attn_ok_kv = _div(cfg.n_kv_heads, ts)
    in_attn = parent == "attn" or name in ("wq", "wk", "wv", "wo")

    # rwkv channel-mix value matrix is named "wv" but is [ff, d] (in-TP);
    # must be decided before the _OUT_TP branch
    if parent == "cm" and name == "wv":
        lead = (None,) * (len(shape) - 2)
        return P(*lead, tp if _div(shape[-2], ts) else None,
                 fsdp if _div(shape[-1], fs) else None)

    # mamba in_proj [L, d, 2*d_in+2N+nh]: FSDP on d, replicate the fused
    # out dim (sections are not TP-aligned)
    if name == "in_proj":
        return P(None, fsdp if _div(shape[1], fs) else None, None)

    if len(shape) >= 2 and name in _OUT_TP and name not in _REPLICATE:
        tp_ok = _div(shape[-1], ts)
        if in_attn and name == "wq":
            tp_ok = tp_ok and attn_ok_q
        if in_attn and name in ("wk", "wv") and parent == "attn":
            tp_ok = tp_ok and attn_ok_kv
        lead = (None,) * (len(shape) - 2)
        # MoE experts: [L, E, d, ff] — E carries TP (EP), d carries FSDP
        if len(shape) == 4:
            return P(None, tp if _div(shape[1], ts) else None,
                     fsdp if _div(shape[2], fs) else None, None)
        return P(*lead, fsdp if _div(shape[-2], fs) else None,
                 tp if tp_ok else None)

    if len(shape) >= 2 and name in _IN_TP:
        tp_ok = _div(shape[-2], ts)
        if name == "wo":
            tp_ok = tp_ok and attn_ok_q
        lead = (None,) * (len(shape) - 2)
        if len(shape) == 4:  # [L, E, ff, d]
            return P(None, tp if _div(shape[1], ts) else None, None,
                     fsdp if _div(shape[-1], fs) else None)
        return P(*lead, tp if tp_ok else None,
                 fsdp if _div(shape[-1], fs) else None)

    return P()  # biases, norms, router, small tensors: replicated


def opt_state_specs(opt_state, param_specs, ctx: MeshCtx):
    """Shardings for the optimizer state tree: fp32 moments mirror the
    param spec; int8 QTensor payloads shard their flat block dim over
    (fsdp x tp) jointly (divisibility-guarded)."""
    from repro.train.optimizer import QTensor

    fsdp, tp = ctx.fsdp_axis, ctx.tp_axis
    both = ctx.mesh.shape[fsdp] * ctx.mesh.shape[tp]

    def one(state_leaf, spec):
        if isinstance(state_leaf, QTensor):
            # int8 payload has the param's shape -> the param's spec; the
            # per-block scale shares the leading specs and keeps the last
            # (blocked) axis' sharding only if blocks divide across it
            qs = spec
            rank = len(state_leaf.q.shape)
            entries = list(spec) + [None] * (rank - len(list(spec)))

            def axes_size(e):
                if e is None:
                    return 1
                names = e if isinstance(e, tuple) else (e,)
                s = 1
                for nm in names:
                    s *= ctx.mesh.shape[nm]
                return s

            if state_leaf.scale.ndim == rank and rank > 0:
                n_blocks = state_leaf.scale.shape[-1]
                last = entries[-1]
                ok = n_blocks % axes_size(last) == 0
                ss = P(*entries[:-1], last if ok else None)
            else:
                ss = P(*entries[:state_leaf.scale.ndim])
            # keep the same static aux (shape) so the spec tree's treedef
            # matches the state tree's for in_shardings
            return QTensor(q=qs, scale=ss, shape=state_leaf.shape)
        return spec

    m = jax.tree.map(one, opt_state["m"], param_specs,
                     is_leaf=lambda x: isinstance(x, QTensor))
    v = jax.tree.map(one, opt_state["v"], param_specs,
                     is_leaf=lambda x: isinstance(x, QTensor))
    return {"step": P(), "m": m, "v": v}


def build_param_specs(params, cfg: ModelConfig, ctx: MeshCtx):
    """Mirror the param tree with PartitionSpecs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        specs.append(_leaf_spec(names, leaf.shape, cfg, ctx))
    return jax.tree_util.tree_unflatten(treedef, specs)


def build_param_shardings(params, cfg: ModelConfig, ctx: MeshCtx):
    specs = build_param_specs(params, cfg, ctx)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def sharded_init(key, cfg: ModelConfig, ctx: MeshCtx, init_fn):
    """jit the initializer with out_shardings so giant param trees are
    *born* sharded (no host-memory spike)."""
    shapes = jax.eval_shape(init_fn, key)
    shardings = build_param_specs(shapes, cfg, ctx)
    named = jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), shardings,
                         is_leaf=lambda x: isinstance(x, P))
    return jax.jit(init_fn, out_shardings=named)(key), named
