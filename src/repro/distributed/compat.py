"""Version compatibility for jax APIs the codebase targets.

The code is written against the modern ``jax.shard_map`` surface
(``check_vma``, ``axis_names``).  On older jax (< 0.6) only
``jax.experimental.shard_map`` exists, with ``check_rep`` instead of
``check_vma`` and ``auto`` (the complement set) instead of
``axis_names``.  This wrapper presents the modern keyword surface on
both and is the only ``shard_map`` import site the repo should use.
"""
from __future__ import annotations

import functools
from typing import Optional

try:  # jax >= 0.6: top-level export with the modern kwargs
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
    _MODERN = True
except ImportError:  # jax 0.4.x/0.5.x experimental API
    from jax.experimental.shard_map import shard_map as _shard_map
    _MODERN = False


def shard_map(f=None, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None,
              axis_names=None):
    """``jax.shard_map`` with a stable keyword surface across versions.

    ``axis_names``: the mesh axes that are manual inside ``f`` (all axes
    when None).  ``check_vma``: varying-manual-axes checking (named
    ``check_rep`` before jax 0.6).
    """
    if f is None:
        return functools.partial(
            shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, axis_names=axis_names)
    if _MODERN:
        kwargs = {}
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)
    kwargs = {}
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    if axis_names is not None:
        kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
