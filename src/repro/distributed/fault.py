"""Fault tolerance & straggler mitigation policies.

TPU pods fail and straggle differently from the paper's single box: a pod
is a single SPMD failure domain (one chip down = the whole pod's step
fails), so recovery is *restart-from-checkpoint* (checkpoint/manager.py:
atomic commits + elastic resharding onto however many pods remain), and
straggler handling happens at two levels:

1. **Step level** (in-SPMD): there is no per-chip work stealing inside a
   jit step — the mitigation is deterministic, balanced partitioning
   (equal-sized shards everywhere: batch, corpus rows, experts-capacity)
   so no chip is structurally slower. The MoE capacity factor bounds the
   worst-case expert hot-spot (perfcfg / EXPERIMENTS §Perf A4).

2. **Work-queue level** (the search engine): corpora stream in slabs; a
   slab assigned to a pod that misses its deadline is requeued to another
   pod. ``SlabScheduler`` below implements the deterministic requeue with
   at-least-once semantics + idempotent top-k merging (merging the same
   slab's results twice is a no-op because top-k is idempotent on
   duplicate candidates).

For cross-pod training, the preemption hook (train/loop.py) plus
deterministic counter-based data (data/pipeline.py) make restarts exact:
any surviving pod count resumes the identical token stream.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Set, Tuple


@dataclasses.dataclass
class SlabTask:
    slab_id: int
    epoch: int = 0           # bumped on requeue (paper's epoch tags)
    assigned_to: Optional[int] = None
    assigned_at: float = 0.0
    done: bool = False


class SlabScheduler:
    """Deterministic work queue for corpus slabs over worker pods with
    straggler requeue. Results merge idempotently (top-k)."""

    def __init__(self, n_slabs: int, timeout_s: float = 60.0,
                 now: Callable[[], float] = time.monotonic):
        self.tasks = [SlabTask(i) for i in range(n_slabs)]
        self.timeout_s = timeout_s
        self.now = now
        self._completed_epochs: Dict[int, int] = {}

    def next_task(self, worker: int) -> Optional[SlabTask]:
        t_now = self.now()
        # 1) unassigned slabs in deterministic order
        for t in self.tasks:
            if not t.done and t.assigned_to is None:
                t.assigned_to = worker
                t.assigned_at = t_now
                return t
        # 2) straggled slabs: requeue with a bumped epoch
        for t in self.tasks:
            if not t.done and t.assigned_to is not None and \
                    t_now - t.assigned_at > self.timeout_s and \
                    t.assigned_to != worker:
                t.epoch += 1
                t.assigned_to = worker
                t.assigned_at = t_now
                return t
        return None

    def complete(self, slab_id: int, epoch: int) -> bool:
        """Returns True if this completion is the accepted one (stale
        epochs from straggling workers are discarded — the paper's
        mispredict-discard, scheduler edition)."""
        t = self.tasks[slab_id]
        if t.done:
            return False
        if epoch != t.epoch:
            return False
        t.done = True
        self._completed_epochs[slab_id] = epoch
        return True

    @property
    def all_done(self) -> bool:
        return all(t.done for t in self.tasks)

    def pending(self) -> List[int]:
        return [t.slab_id for t in self.tasks if not t.done]
