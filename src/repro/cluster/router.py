"""ShardRouter — scatter/gather top-k over a ShardedStore
(DESIGN.md §5.2–§5.3).

One coalesced ``[L, Qn]`` query batch fans out to every shard on a
thread pool; each shard is a full FlashSearchSession (its own vocab
filters, prefetcher, and L-bucket compile cache — the per-slice
accelerator of the paper, untouched), reporting only its ``[L, k]``
candidates. The gather side folds shard candidates through the engine's
``_merge_results`` in shard order, so the cluster result is bit-identical
to a single-store scan of the union corpus: scoring is per-document,
the merge is deterministic, and duplicate doc ids keep their
best-scoring entry.

Replicas are the fault layer (the fail-over mirror of
``distributed/fault.py``'s requeue): each shard holds ``replicas``
byte-wise independent copies; a query tries replica 0 and a replica
that raises is retried on the next one within the same query — killing
a replica mid-run degrades latency, never correctness. A failed
replica is health-marked *down* (kept out of rotation) only once a
sibling succeeds on the same query, which localizes the fault to the
replica rather than the query. Only when every replica of a shard
fails does the query raise ``ClusterSearchError`` — and then nothing
is marked, so one malformed request cannot brick the cluster.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional, Tuple

import numpy as np

from repro.cluster.store import ShardedStore
from repro.configs.paper_search import SearchConfig
from repro.core.engine import SearchResult, _merge_results
from repro.obs import NULL_SPAN, Obs, default_obs
from repro.storage.session import FlashSearchSession, SearchStats
from repro.storage.slabcache import CacheStats, SlabCache

log = logging.getLogger(__name__)


class ClusterSearchError(RuntimeError):
    """Every replica of one shard failed the query."""


@dataclasses.dataclass
class ClusterStats:
    """Aggregate of the per-shard SearchStats for the last query batch.
    ``per_shard[s]`` is None until shard s has served a query.
    ``failovers`` snapshots the router's *lifetime* count of replicas
    taken out of rotation (confirmed failovers plus manual
    ``mark_down`` calls), not a per-batch figure."""
    per_shard: List[Optional[SearchStats]]
    failovers: int = 0

    def _sum(self, field: str) -> int:
        # `or 0` tolerates shards reporting partial stats (e.g. a
        # replica built with its cache disabled leaves cache fields
        # None-ish) — the aggregate must never raise on a healthy batch
        return sum(int(getattr(st, field, 0) or 0)
                   for st in self.per_shard if st is not None)

    @property
    def segments_total(self) -> int:
        return self._sum("segments_total")

    @property
    def segments_skipped(self) -> int:
        return self._sum("segments_skipped")

    @property
    def segments_scored(self) -> int:
        return self._sum("segments_scored")

    @property
    def docs_scored(self) -> int:
        return self._sum("docs_scored")

    @property
    def pairs_truncated(self) -> int:
        return self._sum("pairs_truncated")

    @property
    def memtable_docs(self) -> int:
        return self._sum("memtable_docs")

    @property
    def cache_hits(self) -> int:
        return self._sum("cache_hits")

    @property
    def cache_misses(self) -> int:
        return self._sum("cache_misses")

    @property
    def cache_evictions(self) -> int:
        return self._sum("cache_evictions")

    @property
    def skip_rate(self) -> float:
        """Aggregate skip-rate across every shard's segments."""
        total = self.segments_total
        return self.segments_skipped / total if total else 0.0

    @property
    def cache_hit_rate(self) -> float:
        """Aggregate slab-cache hit rate across every shard's probes
        for the last batch (DESIGN.md §4.2). 0.0 when no shard probed
        the cache at all (every segment filter-skipped, or caches
        disabled) — never a division error."""
        probes = self.cache_hits + self.cache_misses
        return self.cache_hits / probes if probes else 0.0


class ShardRouter:
    """Not thread-safe for concurrent ``search`` calls (each shard
    session is stateful); route concurrency through
    ``FlashClusterSession.submit`` like the single-store session."""

    def __init__(self, store: ShardedStore, cfg: SearchConfig, *,
                 backend: str = "jnp", use_filter: bool = True,
                 prefetch_depth: int = 2,
                 max_workers: Optional[int] = None,
                 slab_cache: Optional[SlabCache] = None,
                 cache_bytes: Optional[int] = None,
                 obs: Optional[Obs] = None):
        self.store = store
        self.cfg = cfg
        self.backend = backend
        self.use_filter = use_filter
        self.prefetch_depth = prefetch_depth
        # one observability bundle for the whole cluster (DESIGN.md §8):
        # shard sessions share it, so their stage histograms aggregate,
        # while query-level accounting stays with the router
        self.obs = obs if obs is not None else default_obs()
        # one device slab cache for the whole cluster (DESIGN.md §4.2):
        # every shard-replica session shares the byte budget, so a hot
        # shard can hold more resident slabs than a cold one
        self.slab_cache = SlabCache.resolve(slab_cache, cache_bytes)
        n, r = store.n_shards, store.replicas
        self._sessions: List[List[Optional[FlashSearchSession]]] = \
            [[None] * r for _ in range(n)]
        self._down: List[List[bool]] = [[False] * r for _ in range(n)]
        self._lock = threading.Lock()    # session creation + health marks
        # default concurrency adapts to the host: concurrent jax CPU
        # dispatch *loses* to serial below ~4 cores (client contention),
        # so small hosts get one worker (serialized shards, still correct)
        # and many-core hosts fan out up to one thread per shard
        workers = max_workers or min(n, max(1, (os.cpu_count() or 2) // 2))
        self._pool = ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="shard-router")
        self.failovers = 0
        self.last_stats = ClusterStats([None] * n)
        self._ingest_knobs: Optional[dict] = None
        self._part_cache: Optional[Tuple[int, object]] = None
        self._gen = store.generation

    # -- generation reconcile ------------------------------------------
    def _reconcile_generation(self):
        """An in-process ``ShardedStore.rebalance`` leaves every cached
        session pointing at directories the rebalance just deleted (and
        possibly the wrong shard count). Entry points call this first:
        when the manifest generation has moved, cached sessions are
        closed and the session/health arrays resized to the live
        topology, so searches and appends address the new generation.
        Not safe concurrently *with* the rebalance itself — quiesce
        traffic (and ``flush_ingest``) before rebalancing, as documented
        there."""
        if self._gen == self.store.generation:
            return
        stale: List[FlashSearchSession] = []
        with self._lock:
            # only the array swap happens under the lock — closing a
            # session can block on its compactor join, and concurrent
            # queries must not stall behind that
            if self._gen != self.store.generation:
                stale = [s for row in self._sessions for s in row
                         if s is not None]
                n, r = self.store.n_shards, self.store.replicas
                self._sessions = [[None] * r for _ in range(n)]
                self._down = [[False] * r for _ in range(n)]
                self.last_stats = ClusterStats([None] * n)
                self._gen = self.store.generation
        for sess in stale:
            sess.close()
        if stale:
            log.info("router(%s): generation %d live; %d stale session(s) "
                     "closed", self.store.root, self._gen, len(stale))

    # -- replica health ------------------------------------------------
    def _session(self, shard: int, replica: int) -> FlashSearchSession:
        with self._lock:
            if self._sessions[shard][replica] is None:
                sess = FlashSearchSession(
                    self.store.store(shard, replica), self.cfg,
                    backend=self.backend, use_filter=self.use_filter,
                    prefetch_depth=self.prefetch_depth,
                    slab_cache=self.slab_cache,
                    cache_bytes=None if self.slab_cache is not None else 0,
                    obs=self.obs)
                if self._ingest_knobs is not None:
                    sess.enable_ingest(**self._ingest_knobs)
                self._sessions[shard][replica] = sess
            return self._sessions[shard][replica]

    # -- live ingestion (DESIGN.md §6.3) -------------------------------
    def enable_ingest(self, **knobs):
        """Arm every shard session (existing and future) with a write
        path; each replica directory gets its own WAL + memtable +
        compactor, keeping replicas byte-wise independent."""
        with self._lock:
            self._ingest_knobs = knobs
            open_sessions = [s for row in self._sessions for s in row
                             if s is not None]
        for sess in open_sessions:
            sess.enable_ingest(**knobs)

    def _partitioner(self):
        """The live partitioner, re-read when the manifest generation
        moves — so appends issued after an in-process ``rebalance`` land
        on the *new* generation's owner shard."""
        gen = self.store.generation
        if self._part_cache is None or self._part_cache[0] != gen:
            self._part_cache = (gen, self.store.partitioner)
        return self._part_cache[1]

    def append(self, doc_id: int, pairs) -> int:
        """Route one document to its owner shard (pure function of the
        doc id, same policy the build used) and append it to every
        *in-rotation* replica, keeping those content-identical.

        A replica whose append fails while a sibling's succeeded is now
        content-divergent, so it is health-marked down — out of both
        read and write rotation until ``reset_health`` (which, as with
        read failover, is only correct after the replica directory has
        been repaired or rebuilt; §14). If every replica fails the error
        travels with the document and nothing is marked, mirroring the
        read path's poisoned-query rule. Returns the owner shard."""
        if self._ingest_knobs is None:
            raise RuntimeError(
                "append() needs enable_ingest() first — the cluster is "
                "read-only until a write path is attached")
        self._reconcile_generation()
        shard = int(self._partitioner().shard_of(
            np.asarray([doc_id], np.int64))[0])
        failed: List[Tuple[int, Exception]] = []
        wrote = 0
        for rep in range(self.store.replicas):
            if self._down[shard][rep]:
                continue
            try:
                self._session(shard, rep).append(doc_id, pairs)
                wrote += 1
            except Exception as e:
                log.warning("shard %d replica %d append failed (%s)",
                            shard, rep, e)
                failed.append((rep, e))
        if failed:
            if wrote:        # divergence: the failed copies are stale
                for rep, _ in failed:
                    self.mark_down(shard, rep)
            raise failed[0][1]
        if not wrote:
            raise ClusterSearchError(
                f"shard {shard}: no replica in rotation to append to")
        return shard

    def flush_ingest(self) -> int:
        """Seal every open shard session's memtable (call before a
        rebalance: rebalance streams segments, not WAL tails)."""
        return sum(s.flush_ingest() for s in self._open_sessions())

    def ingest_pipelines(self) -> List:
        """The live IngestPipelines of every opened replica session
        (introspection: the launcher aggregates their seal/fold stats)."""
        return [s.ingest for s in self._open_sessions()
                if s.ingest is not None]

    def _open_sessions(self) -> List[FlashSearchSession]:
        with self._lock:
            return [s for row in self._sessions for s in row
                    if s is not None]

    def mark_down(self, shard: int, replica: int):
        """Health-mark a replica out of rotation (also called by the
        failover path). A downed replica is never retried until
        ``reset_health``."""
        with self._lock:
            if not self._down[shard][replica]:
                self._down[shard][replica] = True
                self.failovers += 1

    def reset_health(self):
        with self._lock:
            for row in self._down:
                row[:] = [False] * len(row)

    def health(self) -> List[List[bool]]:
        """``health()[s][r]`` — True while the replica is in rotation."""
        with self._lock:
            return [[not d for d in row] for row in self._down]

    # -- scatter/gather ------------------------------------------------
    def _search_shard(self, shard: int, q_ids: np.ndarray,
                      q_vals: np.ndarray, span=NULL_SPAN
                      ) -> Tuple[SearchResult, SearchStats, float]:
        """Pool-thread body: primary replica first, fail over in replica
        order. A failed attempt contributes nothing to the merge (its
        candidates are discarded whole), so retried shards can never
        duplicate documents.

        A replica is health-marked down only when a *sibling* replica
        then succeeds on the same query — that localizes the fault to
        the replica. When every replica fails, the error almost
        certainly travels with the query (bad shape, poisoned input),
        so no marks are recorded and the next query gets every replica
        back: one malformed request must never brick the cluster.

        ``span`` is this shard's child of the cluster trace; each
        replica attempt nests one level deeper, so a fail-over shows up
        as sibling replica spans (the failed one attr'd with its
        error). Returns the shard wall time for straggler attribution."""
        t0 = time.perf_counter()
        try:
            last: Optional[Exception] = None
            failed: list = []
            for rep in range(self.store.replicas):
                if self._down[shard][rep]:
                    continue
                rspan = span.child("replica", replica=rep)
                try:
                    sess = self._session(shard, rep)
                    res = sess.search(q_ids, q_vals, _span=rspan)
                except Exception as e:
                    rspan.end(error=repr(e))
                    last = e
                    log.warning(
                        "shard %d replica %d failed (%s); failing over",
                        shard, rep, e)
                    failed.append(rep)
                    continue
                rspan.end()
                for r in failed:
                    self.mark_down(shard, r)
                wall_ms = (time.perf_counter() - t0) * 1e3
                span.end(replica=rep, wall_ms=round(wall_ms, 3))
                return res, dataclasses.replace(sess.last_stats), wall_ms
            raise ClusterSearchError(
                f"shard {shard}: all {self.store.replicas} replicas failed"
            ) from last
        except BaseException as e:
            span.end(error=repr(e))
            raise

    def search(self, q_ids: np.ndarray, q_vals: np.ndarray) -> SearchResult:
        """q_ids/q_vals ``[L, Qn]`` (pad < 0) -> global ``[L, k]`` top-k
        over every shard. Shards run concurrently; the merge folds in
        shard order, so results are deterministic regardless of which
        shard finishes first."""
        self._reconcile_generation()
        t_start = time.perf_counter()
        n = self.store.n_shards
        trace = self.obs.tracer.start("query", surface="cluster",
                                      L=int(q_ids.shape[0]), shards=n)
        root = trace.root if trace is not None else NULL_SPAN
        reg = self.obs.registry
        h_shard = reg.histogram("cluster_shard_ms")
        stats = ClusterStats([None] * n)
        walls: List[Optional[float]] = [None] * n
        try:
            futs = [self._pool.submit(self._search_shard, s, q_ids, q_vals,
                                      root.child("shard", shard=s))
                    for s in range(n)]
            # the gather span covers waiting out the stragglers plus the
            # shard-order fold — the scatter itself lives in the shard
            # children above
            gspan = root.child("gather")
            best: Optional[SearchResult] = None
            err: Optional[BaseException] = None
            for s, fut in enumerate(futs):
                try:
                    res, st, wall_ms = fut.result()
                except BaseException as e:
                    err = err or e
                    continue
                walls[s] = wall_ms
                h_shard.observe(wall_ms)
                # per-shard series feed the per-shard latency SLOs
                # (§8.4) and make a straggling shard visible in /metrics
                # without joining against the trace attrs
                reg.histogram("cluster_shard_ms", shard=str(s)).observe(
                    wall_ms)
                stats.per_shard[s] = st
                best = res if best is None else _merge_results(
                    best, res, self.cfg.top_k)
            done = [s for s, w in enumerate(walls) if w is not None]
            if done:
                straggler = max(done, key=lambda s: walls[s])
                reg.histogram("cluster_straggler_ms").observe(
                    walls[straggler])
                root.set(straggler_shard=straggler,
                         straggler_ms=round(walls[straggler], 3))
            gspan.end(shards_merged=len(done))
        finally:
            if trace is not None:
                trace.finish()
        stats.failovers = self.failovers
        self.last_stats = stats
        if err is not None:
            # the cluster availability-SLO bad-event stream (§8.4);
            # queries_total for the surface counts in publish_search_stats
            reg.counter("query_errors_total", surface="cluster").inc()
            reg.counter("queries_total", surface="cluster").inc()
            raise err
        assert best is not None          # n_shards >= 1
        self.obs.note_query(
            "cluster", (time.perf_counter() - t_start) * 1e3,
            shards=n, segments_scored=stats.segments_scored,
            cache_hits=stats.cache_hits)
        self.obs.publish_search_stats(stats, surface="cluster")
        return best

    # -- introspection -------------------------------------------------
    @property
    def last_trace(self):
        """Most recent sampled cluster QueryTrace (None unless the
        shared ``obs`` samples traces)."""
        return self.obs.tracer.last_trace

    @property
    def cache_stats(self) -> Optional[CacheStats]:
        """Locked snapshot of the cluster-shared slab cache's lifetime
        counters, or None when the cache is disabled. Shard sessions
        mutate the counters concurrently under the cache lock, so the
        lock-free live object could pair mid-flight hits/misses."""
        return (self.slab_cache.stats_snapshot()
                if self.slab_cache is not None else None)

    def compile_counts(self) -> List[List[int]]:
        """Engine traces per *opened* (shard, replica) session — the
        per-shard L-bucket bound (DESIGN.md §7.2) applies to each."""
        with self._lock:
            return [[s.engine.compile_stats["n_traces"]
                     for s in row if s is not None]
                    for row in self._sessions]

    def close(self):
        self._pool.shutdown(wait=True)
        with self._lock:
            for row in self._sessions:
                for sess in row:
                    if sess is not None:
                        sess.close()
            self._sessions = [[None] * self.store.replicas
                              for _ in range(self.store.n_shards)]
        self.store.close()
