"""ShardRouter — scatter/gather top-k over a ShardedStore
(DESIGN.md §5.2–§5.3).

One coalesced ``[L, Qn]`` query batch fans out to every shard on a
thread pool; each shard is a full FlashSearchSession (its own vocab
filters, prefetcher, and L-bucket compile cache — the per-slice
accelerator of the paper, untouched), reporting only its ``[L, k]``
candidates. The gather side folds shard candidates through the engine's
``_merge_results`` in shard order, so the cluster result is bit-identical
to a single-store scan of the union corpus: scoring is per-document,
the merge is deterministic, and duplicate doc ids keep their
best-scoring entry.

Replicas are the fault layer (the fail-over mirror of
``distributed/fault.py``'s requeue): each shard holds ``replicas``
byte-wise independent copies; a query tries replica 0 and a replica
that raises is retried on the next one within the same query — killing
a replica mid-run degrades latency, never correctness. A failed
replica is health-marked *down* (kept out of rotation) only once a
sibling succeeds on the same query, which localizes the fault to the
replica rather than the query. Only when every replica of a shard
fails does the query raise ``ClusterSearchError`` — and then nothing
is marked, so one malformed request cannot brick the cluster.

PR 9 makes the gather deadline-aware (DESIGN.md §7.3): a query carrying
``QueryOptions(deadline_ms=..., allow_partial=True)`` stops waiting on
stragglers at its budget and returns the merged top-k of the shards
that responded, flagged ``partial=True`` with the missing shard list in
``last_stats`` — bit-identical to the full gather whenever every shard
responds in time, because the merge still folds in shard order over
exactly the same per-shard candidates. Replica *hedging* attacks the
straggler before the budget does: when a replica attempt outlives the
straggler threshold (a percentile of the rolling-window
``cluster_shard_ms`` distribution — serve/hedging.py), the same query
fires at the next replica and the first result wins; replicas are
byte-identical, so a hedged result is still bit-identical. Abandoned
and losing attempts run to completion on their executor; per-replica
session locks serialize them against subsequent queries, so the
stateful FlashSearchSession is never raced.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor, wait
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.cluster.store import ShardedStore
from repro.configs.paper_search import SearchConfig
from repro.core.engine import SearchResult, _merge_results
from repro.obs import NULL_SPAN, Obs, default_obs
from repro.serve.api import (Query, QueryOptions, QueryStats, SearchResponse,
                             coerce_request, truncate_k)
from repro.serve.hedging import HedgePolicy, SpawnExecutor, run_hedged
from repro.storage.memo import MemoCache
from repro.storage.plan import DEFAULT_APPROX_MIN_DOCS
from repro.storage.session import FlashSearchSession, SearchStats
from repro.storage.slabcache import CacheStats, SlabCache

log = logging.getLogger(__name__)


class ClusterSearchError(RuntimeError):
    """Every replica of one shard failed the query (or no replica was
    in rotation to take it). Carries structured context so the partial
    and hedged paths — and operators reading logs — can attribute the
    failure: ``shard``, ``replica_errors`` (replica index -> exception
    summary), and the ``trace_id`` of the sampled cluster trace (None
    when this query wasn't sampled)."""

    def __init__(self, msg: str, *, shard: Optional[int] = None,
                 replica_errors: Optional[Dict[int, str]] = None,
                 trace_id: Optional[int] = None):
        super().__init__(msg)
        self.shard = shard
        self.replica_errors = dict(replica_errors or {})
        self.trace_id = trace_id


@dataclasses.dataclass
class ClusterStats:
    """Aggregate of the per-shard SearchStats for the last query batch.
    ``per_shard[s]`` is None until shard s has served a query.
    ``failovers`` snapshots the router's *lifetime* count of replicas
    taken out of rotation (confirmed failovers plus manual
    ``mark_down`` calls), not a per-batch figure. The scheduling fields
    (DESIGN.md §7.3) are per-batch: ``partial``/``shards_missing``
    record a deadline-bound gather that returned without every shard
    (a missing shard's ``per_shard`` slot stays None), ``hedges``/
    ``hedge_wins`` count straggler hedges fired and won."""
    per_shard: List[Optional[SearchStats]]
    failovers: int = 0
    partial: bool = False
    shards_missing: Tuple[int, ...] = ()
    hedges: int = 0
    hedge_wins: int = 0

    def _sum(self, field: str) -> int:
        # `or 0` tolerates shards reporting partial stats (e.g. a
        # replica built with its cache disabled leaves cache fields
        # None-ish) — the aggregate must never raise on a healthy batch
        return sum(int(getattr(st, field, 0) or 0)
                   for st in self.per_shard if st is not None)

    @property
    def segments_total(self) -> int:
        return self._sum("segments_total")

    @property
    def segments_skipped(self) -> int:
        return self._sum("segments_skipped")

    @property
    def segments_scored(self) -> int:
        return self._sum("segments_scored")

    @property
    def docs_scored(self) -> int:
        return self._sum("docs_scored")

    @property
    def pairs_truncated(self) -> int:
        return self._sum("pairs_truncated")

    @property
    def memtable_docs(self) -> int:
        return self._sum("memtable_docs")

    @property
    def cache_hits(self) -> int:
        return self._sum("cache_hits")

    @property
    def cache_misses(self) -> int:
        return self._sum("cache_misses")

    @property
    def cache_evictions(self) -> int:
        return self._sum("cache_evictions")

    @property
    def filter_fp_segments(self) -> int:
        """Scored-but-zero-overlap segments across every shard — the
        cluster-wide filter false-positive count for the last batch."""
        return self._sum("filter_fp_segments")

    @property
    def approx_segments(self) -> int:
        return self._sum("approx_segments")

    @property
    def candidates(self) -> int:
        return self._sum("candidates")

    @property
    def memo_hits(self) -> int:
        return self._sum("memo_hits")

    @property
    def skip_rate(self) -> float:
        """Aggregate skip-rate across every shard's segments."""
        total = self.segments_total
        return self.segments_skipped / total if total else 0.0

    @property
    def cache_hit_rate(self) -> float:
        """Aggregate slab-cache hit rate across every shard's probes
        for the last batch (DESIGN.md §4.2). 0.0 when no shard probed
        the cache at all (every segment filter-skipped, or caches
        disabled) — never a division error."""
        probes = self.cache_hits + self.cache_misses
        return self.cache_hits / probes if probes else 0.0


class ShardRouter:
    """Not thread-safe for concurrent ``search`` calls (each shard
    session is stateful); route concurrency through
    ``FlashClusterSession.submit`` like the single-store session."""

    def __init__(self, store: ShardedStore, cfg: SearchConfig, *,
                 backend: str = "jnp", use_filter: bool = True,
                 prefetch_depth: int = 2,
                 max_workers: Optional[int] = None,
                 slab_cache: Optional[SlabCache] = None,
                 cache_bytes: Optional[int] = None,
                 obs: Optional[Obs] = None,
                 hedge_policy: Optional[HedgePolicy] = None,
                 mode: str = "exact", candidates: int = 0,
                 approx_min_docs: Optional[int] = None,
                 memo_entries: int = 0):
        self.store = store
        self.cfg = cfg
        self.backend = backend
        self.use_filter = use_filter
        self.prefetch_depth = prefetch_depth
        # approximate-tier defaults for every shard session (§15): each
        # shard generates + exactly re-ranks its own candidate pool, and
        # the gather merges the per-shard exact top-k — equivalent to
        # merging the pools first, because re-rank scores are exact and
        # the global top-k of a union is the top-k of per-shard top-ks
        self.mode = mode
        self.candidates = candidates
        self.approx_min_docs = approx_min_docs
        # one memo cache for the whole cluster: shard stores have
        # distinct cache tokens, so entries can never alias across
        # shards, and the budget is shared like the slab cache's
        self._memo = (MemoCache(memo_entries) if memo_entries > 0
                      else None)
        # one observability bundle for the whole cluster (DESIGN.md §8):
        # shard sessions share it, so their stage histograms aggregate,
        # while query-level accounting stays with the router
        self.obs = obs if obs is not None else default_obs()
        # one device slab cache for the whole cluster (DESIGN.md §4.2):
        # every shard-replica session shares the byte budget, so a hot
        # shard can hold more resident slabs than a cold one
        self.slab_cache = SlabCache.resolve(slab_cache, cache_bytes)
        n, r = store.n_shards, store.replicas
        self._sessions: List[List[Optional[FlashSearchSession]]] = \
            [[None] * r for _ in range(n)]
        self._down: List[List[bool]] = [[False] * r for _ in range(n)]
        # per-(shard, replica) locks: a shard session is stateful, so a
        # hedge loser or an abandoned partial-gather straggler still
        # running must serialize against the next query's attempt on
        # the same replica (DESIGN.md §7.3)
        self._sess_locks: List[List[threading.Lock]] = \
            [[threading.Lock() for _ in range(r)] for _ in range(n)]
        self._lock = threading.Lock()    # session creation + health marks
        # the router's default straggler policy; per-query
        # QueryOptions.hedging overrides (False pins off, True forces
        # on with a default policy when none is configured)
        self.hedge_policy = hedge_policy
        # hedge attempts run on their own lazy spawn-per-attempt
        # executor: launching them on self._pool could deadlock (every
        # worker blocked in a gather waiting for a hedge that can't get
        # a thread), and a *bounded* hedge pool starves — an abandoned
        # loser sleeping inside a straggler holds a worker, so the next
        # query's hedge would queue behind the very straggler it was
        # meant to outrun
        self._hedge_pool: Optional[SpawnExecutor] = None
        # default concurrency adapts to the host: concurrent jax CPU
        # dispatch *loses* to serial below ~4 cores (client contention),
        # so small hosts get one worker (serialized shards, still correct)
        # and many-core hosts fan out up to one thread per shard
        workers = max_workers or min(n, max(1, (os.cpu_count() or 2) // 2))
        self._pool = ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="shard-router")
        self.failovers = 0
        self.last_stats = ClusterStats([None] * n)
        self._ingest_knobs: Optional[dict] = None
        self._part_cache: Optional[Tuple[int, object]] = None
        self._gen = store.generation

    # -- generation reconcile ------------------------------------------
    def _reconcile_generation(self):
        """An in-process ``ShardedStore.rebalance`` leaves every cached
        session pointing at directories the rebalance just deleted (and
        possibly the wrong shard count). Entry points call this first:
        when the manifest generation has moved, cached sessions are
        closed and the session/health arrays resized to the live
        topology, so searches and appends address the new generation.
        Not safe concurrently *with* the rebalance itself — quiesce
        traffic (and ``flush_ingest``) before rebalancing, as documented
        there."""
        if self._gen == self.store.generation:
            return
        stale: List[FlashSearchSession] = []
        with self._lock:
            # only the array swap happens under the lock — closing a
            # session can block on its compactor join, and concurrent
            # queries must not stall behind that
            if self._gen != self.store.generation:
                stale = [s for row in self._sessions for s in row
                         if s is not None]
                n, r = self.store.n_shards, self.store.replicas
                self._sessions = [[None] * r for _ in range(n)]
                self._down = [[False] * r for _ in range(n)]
                self._sess_locks = [[threading.Lock() for _ in range(r)]
                                    for _ in range(n)]
                self.last_stats = ClusterStats([None] * n)
                self._gen = self.store.generation
        for sess in stale:
            sess.close()
        if stale:
            log.info("router(%s): generation %d live; %d stale session(s) "
                     "closed", self.store.root, self._gen, len(stale))

    # -- replica health ------------------------------------------------
    def _session(self, shard: int, replica: int) -> FlashSearchSession:
        with self._lock:
            if self._sessions[shard][replica] is None:
                sess = FlashSearchSession(
                    self.store.store(shard, replica), self.cfg,
                    backend=self.backend, use_filter=self.use_filter,
                    prefetch_depth=self.prefetch_depth,
                    slab_cache=self.slab_cache,
                    cache_bytes=None if self.slab_cache is not None else 0,
                    obs=self.obs, mode=self.mode,
                    candidates=self.candidates,
                    approx_min_docs=(self.approx_min_docs
                                     if self.approx_min_docs is not None
                                     else DEFAULT_APPROX_MIN_DOCS),
                    memo=self._memo)
                if self._ingest_knobs is not None:
                    sess.enable_ingest(**self._ingest_knobs)
                self._sessions[shard][replica] = sess
            return self._sessions[shard][replica]

    # -- live ingestion (DESIGN.md §6.3) -------------------------------
    def enable_ingest(self, **knobs):
        """Arm every shard session (existing and future) with a write
        path; each replica directory gets its own WAL + memtable +
        compactor, keeping replicas byte-wise independent."""
        with self._lock:
            self._ingest_knobs = knobs
            open_sessions = [s for row in self._sessions for s in row
                             if s is not None]
        for sess in open_sessions:
            sess.enable_ingest(**knobs)

    def _partitioner(self):
        """The live partitioner, re-read when the manifest generation
        moves — so appends issued after an in-process ``rebalance`` land
        on the *new* generation's owner shard."""
        gen = self.store.generation
        if self._part_cache is None or self._part_cache[0] != gen:
            self._part_cache = (gen, self.store.partitioner)
        return self._part_cache[1]

    def append(self, doc_id: int, pairs) -> int:
        """Route one document to its owner shard (pure function of the
        doc id, same policy the build used) and append it to every
        *in-rotation* replica, keeping those content-identical.

        A replica whose append fails while a sibling's succeeded is now
        content-divergent, so it is health-marked down — out of both
        read and write rotation until ``reset_health`` (which, as with
        read failover, is only correct after the replica directory has
        been repaired or rebuilt; §14). If every replica fails the error
        travels with the document and nothing is marked, mirroring the
        read path's poisoned-query rule. Returns the owner shard."""
        if self._ingest_knobs is None:
            raise RuntimeError(
                "append() needs enable_ingest() first — the cluster is "
                "read-only until a write path is attached")
        self._reconcile_generation()
        shard = int(self._partitioner().shard_of(
            np.asarray([doc_id], np.int64))[0])
        failed: List[Tuple[int, Exception]] = []
        wrote = 0
        for rep in range(self.store.replicas):
            if self._down[shard][rep]:
                continue
            try:
                self._session(shard, rep).append(doc_id, pairs)
                wrote += 1
            except Exception as e:
                log.warning("shard %d replica %d append failed (%s)",
                            shard, rep, e)
                failed.append((rep, e))
        if failed:
            if wrote:        # divergence: the failed copies are stale
                for rep, _ in failed:
                    self.mark_down(shard, rep)
            raise failed[0][1]
        if not wrote:
            raise ClusterSearchError(
                f"shard {shard}: no replica in rotation to append to")
        return shard

    def flush_ingest(self) -> int:
        """Seal every open shard session's memtable (call before a
        rebalance: rebalance streams segments, not WAL tails)."""
        return sum(s.flush_ingest() for s in self._open_sessions())

    def ingest_pipelines(self) -> List:
        """The live IngestPipelines of every opened replica session
        (introspection: the launcher aggregates their seal/fold stats)."""
        return [s.ingest for s in self._open_sessions()
                if s.ingest is not None]

    def _open_sessions(self) -> List[FlashSearchSession]:
        with self._lock:
            return [s for row in self._sessions for s in row
                    if s is not None]

    def mark_down(self, shard: int, replica: int):
        """Health-mark a replica out of rotation (also called by the
        failover path). A downed replica is never retried until
        ``reset_health``."""
        with self._lock:
            if not self._down[shard][replica]:
                self._down[shard][replica] = True
                self.failovers += 1

    def reset_health(self):
        with self._lock:
            for row in self._down:
                row[:] = [False] * len(row)

    def health(self) -> List[List[bool]]:
        """``health()[s][r]`` — True while the replica is in rotation."""
        with self._lock:
            return [[not d for d in row] for row in self._down]

    # -- scatter/gather ------------------------------------------------
    def _hedge_executor(self) -> SpawnExecutor:
        with self._lock:
            if self._hedge_pool is None:
                self._hedge_pool = SpawnExecutor()
            return self._hedge_pool

    def _attempt(self, shard: int, rep: int, query: Query, span,
                 scoring_opts: Optional[QueryOptions] = None
                 ) -> Tuple[SearchResult, SearchStats, int]:
        """One replica attempt, serialized per (shard, replica): the
        session is stateful, so a losing hedge or an abandoned straggler
        still scoring must finish before the next query's attempt on
        the same replica starts. The stats snapshot is taken under the
        same lock, so it can't pair with a later query's counters.

        ``scoring_opts`` carries only the scoring-tier knobs (mode /
        recall_target / candidates, never k or deadlines — those belong
        to the gather); it is None unless the caller set one of them,
        so the legacy flow through the shard session is untouched."""
        rspan = span.child("replica", replica=rep)
        try:
            with self._sess_locks[shard][rep]:
                sess = self._session(shard, rep)
                # dispatch via .search (typed form: no shim, no warning)
                # so fault-injecting wrappers that intercept .search see
                # every replica attempt
                res = sess.search(query, options=scoring_opts,
                                  _span=rspan)
                if scoring_opts is not None:
                    res = res.results   # unwrap the SearchResponse
                st = dataclasses.replace(sess.last_stats)
        except BaseException as e:
            rspan.end(error=repr(e))
            raise
        rspan.end()
        return res, st, rep

    def _search_shard(self, shard: int, query: Query, span=NULL_SPAN,
                      hedge_after_s: Optional[float] = None,
                      trace_id: Optional[int] = None,
                      scoring_opts: Optional[QueryOptions] = None
                      ) -> Tuple[SearchResult, SearchStats, float, int, int]:
        """Pool-thread body: primary replica first, then the next in
        replica order — *sequentially* on failure (the fail-over path),
        and additionally *concurrently* after ``hedge_after_s`` of
        silence when hedging is armed (the straggler path; replicas are
        byte-identical, so first-result-wins is still bit-identical). A
        failed attempt contributes nothing to the merge (its candidates
        are discarded whole), so retried shards can never duplicate
        documents.

        A replica is health-marked down only when a *sibling* replica
        then succeeds on the same query — that localizes the fault to
        the replica. A hedge that merely *outruns* a slow primary marks
        nothing: slow is not failed. When every replica fails, the
        error almost certainly travels with the query (bad shape,
        poisoned input), so no marks are recorded and the next query
        gets every replica back: one malformed request must never brick
        the cluster — the raised ``ClusterSearchError`` carries the
        shard id, per-replica error summaries, and the trace id.

        ``span`` is this shard's child of the cluster trace; each
        replica attempt nests one level deeper, so fail-overs and
        hedges show up as sibling replica spans (failed ones attr'd
        with their error). Returns (result, stats, wall_ms,
        hedges_fired, hedge_won)."""
        t0 = time.perf_counter()
        reps = [r for r in range(self.store.replicas)
                if not self._down[shard][r]]
        try:
            if not reps:
                raise ClusterSearchError(
                    f"shard {shard}: no replica in rotation",
                    shard=shard, trace_id=trace_id)
            errs: Dict[int, BaseException] = {}
            fired = won = 0
            if hedge_after_s is not None and len(reps) > 1:
                def make(rep: int):
                    def attempt():
                        try:
                            return self._attempt(shard, rep, query, span,
                                                 scoring_opts)
                        except BaseException as e:
                            errs[rep] = e
                            raise
                    return attempt

                try:
                    out = run_hedged(
                        [make(r) for r in reps], self._hedge_executor(),
                        hedge_after_s=hedge_after_s,
                        on_hedge=lambda i: log.debug(
                            "shard %d: hedging to replica %d", shard,
                            reps[i]))
                except ClusterSearchError:
                    raise
                except BaseException as e:
                    raise ClusterSearchError(
                        f"shard {shard}: all {len(reps)} in-rotation "
                        f"replicas failed",
                        shard=shard, trace_id=trace_id,
                        replica_errors={r: repr(x)
                                        for r, x in errs.items()}) from e
                res, st, rep = out.result
                fired, won = out.hedges_fired, int(out.hedge_won)
            else:
                res = None
                for rep in reps:
                    try:
                        res, st, _ = self._attempt(shard, rep, query, span,
                                                   scoring_opts)
                        break
                    except Exception as e:
                        errs[rep] = e
                        log.warning(
                            "shard %d replica %d failed (%s); failing over",
                            shard, rep, e)
                if res is None:
                    raise ClusterSearchError(
                        f"shard {shard}: all {len(reps)} in-rotation "
                        f"replicas failed",
                        shard=shard, trace_id=trace_id,
                        replica_errors={r: repr(x) for r, x in errs.items()}
                    ) from (errs[reps[-1]] if reps[-1] in errs else None)
            # the winner proves the query is serveable: errored siblings
            # (fail-overs in either path) leave rotation
            for r in errs:
                if r != rep:
                    self.mark_down(shard, r)
            wall_ms = (time.perf_counter() - t0) * 1e3
            span.end(replica=rep, wall_ms=round(wall_ms, 3),
                     **({"hedges": fired} if fired else {}))
            return res, st, wall_ms, fired, won
        except BaseException as e:
            span.end(error=repr(e))
            raise

    def search_typed(self, query: Query,
                     options: Optional[QueryOptions] = None, *,
                     _span=None) -> SearchResult:
        """Typed scatter/gather: ``Query`` rows ``[L, Qn]`` (pad < 0) ->
        global ``[L, k]`` top-k over every shard. Shards run
        concurrently; the merge folds in shard order, so results are
        deterministic regardless of which shard finishes first.

        ``options`` is the scheduling contract (DESIGN.md §7.3):
        ``deadline_ms`` + ``allow_partial=True`` cap the gather wait —
        shards that haven't answered at the budget are dropped from the
        merge and listed in ``last_stats.shards_missing`` (and a failed
        shard becomes a missing shard instead of an error);
        ``hedging`` overrides the router's straggler policy. Per-query
        ``k`` truncation and ``SearchResponse`` wrapping belong to the
        public ``search`` shim — this method always returns the raw
        merged ``SearchResult`` (what the coalescing service demuxes)."""
        self._reconcile_generation()
        opts = options if options is not None else QueryOptions()
        q_rows = query.rows()
        t_start = time.perf_counter()
        deadline = (t_start + opts.deadline_ms / 1e3
                    if opts.deadline_ms is not None else None)
        n = self.store.n_shards
        trace = self.obs.tracer.start("query", surface="cluster",
                                      L=int(q_rows[0].shape[0]), shards=n)
        root = trace.root if trace is not None else NULL_SPAN
        trace_id = trace.trace_id if trace is not None else None
        reg = self.obs.registry
        h_shard = reg.histogram("cluster_shard_ms")
        # resolve the straggler policy: per-query override beats the
        # router default; hedging needs a second replica to fire at
        policy = self.hedge_policy
        if opts.hedging is False:
            policy = None
        elif opts.hedging is True and policy is None:
            policy = HedgePolicy()
        hedge_after_s = (policy.hedge_after_ms(reg) / 1e3
                         if policy is not None and self.store.replicas > 1
                         else None)
        # scoring-tier knobs travel to every shard session; None when
        # the caller set none of them, so the default flow is untouched
        scoring_opts = None
        if (opts.mode is not None or opts.recall_target is not None
                or opts.candidates is not None):
            scoring_opts = QueryOptions(mode=opts.mode,
                                        recall_target=opts.recall_target,
                                        candidates=opts.candidates)
        stats = ClusterStats([None] * n)
        walls: List[Optional[float]] = [None] * n
        missing: List[int] = []
        try:
            shard_spans = [root.child("shard", shard=s) for s in range(n)]
            futs = [self._pool.submit(self._search_shard, s, query,
                                      shard_spans[s], hedge_after_s,
                                      trace_id, scoring_opts)
                    for s in range(n)]
            # the gather span covers waiting out the stragglers plus the
            # shard-order fold — the scatter itself lives in the shard
            # children above
            gspan = root.child("gather")
            partial_ok = opts.allow_partial and deadline is not None
            if partial_ok:
                # one bounded wait for the whole scatter; anything not
                # done at the budget is abandoned (it keeps running on
                # the pool — the per-replica locks serialize it against
                # the next query — but contributes nothing here)
                wait(futs, timeout=max(0.0, deadline - time.perf_counter()))
            best: Optional[SearchResult] = None
            err: Optional[BaseException] = None
            for s, fut in enumerate(futs):
                if partial_ok and not fut.done():
                    missing.append(s)
                    shard_spans[s].end(abandoned=True)
                    continue
                try:
                    # without partial consent this blocks for the shard:
                    # the legacy full-gather contract
                    res, st, wall_ms, fired, won = fut.result()
                except BaseException as e:
                    if opts.allow_partial:
                        # degraded, not failed: SpANNS-style flagged
                        # partial answer — the caller consented
                        missing.append(s)
                        continue
                    err = err or e
                    continue
                stats.hedges += fired
                stats.hedge_wins += won
                walls[s] = wall_ms
                h_shard.observe(wall_ms)
                # per-shard series feed the per-shard latency SLOs
                # (§8.4) and make a straggling shard visible in /metrics
                # without joining against the trace attrs
                reg.histogram("cluster_shard_ms", shard=str(s)).observe(
                    wall_ms)
                stats.per_shard[s] = st
                best = res if best is None else _merge_results(
                    best, res, self.cfg.top_k)
            done = [s for s, w in enumerate(walls) if w is not None]
            if done:
                straggler = max(done, key=lambda s: walls[s])
                reg.histogram("cluster_straggler_ms").observe(
                    walls[straggler])
                root.set(straggler_shard=straggler,
                         straggler_ms=round(walls[straggler], 3))
            gspan.end(shards_merged=len(done),
                      **({"shards_missing": missing} if missing else {}))
        finally:
            if trace is not None:
                trace.finish()
        stats.failovers = self.failovers
        stats.partial = bool(missing)
        stats.shards_missing = tuple(missing)
        if missing:
            reg.counter("cluster_partial_total").inc()
            log.warning("cluster gather partial: shards %s missed the "
                        "%.1fms budget", missing, opts.deadline_ms or 0.0)
        if stats.hedges:
            reg.counter("cluster_hedges_total").inc(stats.hedges)
        if stats.hedge_wins:
            reg.counter("cluster_hedge_wins_total").inc(stats.hedge_wins)
        self.last_stats = stats
        if err is not None:
            # the cluster availability-SLO bad-event stream (§8.4);
            # queries_total for the surface counts in publish_search_stats
            reg.counter("query_errors_total", surface="cluster").inc()
            reg.counter("queries_total", surface="cluster").inc()
            raise err
        if best is None:
            # every shard missed the budget: a well-formed no-result
            # answer ([L, k] sentinel rows), flagged partial above —
            # never a hang, never a malformed shape
            L, k = q_rows[0].shape[0], self.cfg.top_k
            best = SearchResult(np.full((L, k), -1, np.int64),
                                np.full((L, k), -np.inf, np.float32))
        self.obs.note_query(
            "cluster", (time.perf_counter() - t_start) * 1e3,
            shards=n, segments_scored=stats.segments_scored,
            cache_hits=stats.cache_hits)
        self.obs.publish_search_stats(stats, surface="cluster")
        return best

    def search(self, query, q_vals=None, *,
               options: Optional[QueryOptions] = None):
        """Public search surface. Typed form — ``search(Query(ids,
        vals), options=QueryOptions(...))`` — returns a
        ``SearchResponse`` carrying this query's scheduling stats;
        positional ``search(q_ids, q_vals)`` arrays remain as a
        deprecation shim returning the bare ``SearchResult``
        (repro/serve/api.py)."""
        try:
            q, options = coerce_request(query, q_vals, options,
                                        surface="ShardRouter.search")
        except ValueError as e:
            # a malformed query is still a ClusterSearchError at this
            # surface (the pre-redesign contract): it fails before any
            # shard work, so replica health is never marked
            raise ClusterSearchError(f"malformed query: {e}") from e
        res = self.search_typed(q, options=options)
        if options is None:
            return res
        st = self.last_stats
        return SearchResponse(truncate_k(res, options.k), QueryStats(
            partial=st.partial, hedged=bool(st.hedge_wins),
            shards_missing=st.shards_missing,
            deadline_ms=options.deadline_ms, tenant=options.tenant))

    # -- introspection -------------------------------------------------
    @property
    def last_trace(self):
        """Most recent sampled cluster QueryTrace (None unless the
        shared ``obs`` samples traces)."""
        return self.obs.tracer.last_trace

    @property
    def cache_stats(self) -> Optional[CacheStats]:
        """Locked snapshot of the cluster-shared slab cache's lifetime
        counters, or None when the cache is disabled. Shard sessions
        mutate the counters concurrently under the cache lock, so the
        lock-free live object could pair mid-flight hits/misses."""
        return (self.slab_cache.stats_snapshot()
                if self.slab_cache is not None else None)

    @property
    def memo_stats(self):
        """Lifetime counters of the cluster-shared recurrent-query memo
        cache (None when the memo is off)."""
        return (self._memo.stats_snapshot()
                if self._memo is not None else None)

    def compile_counts(self) -> List[List[int]]:
        """Engine traces per *opened* (shard, replica) session — the
        per-shard L-bucket bound (DESIGN.md §7.2) applies to each."""
        with self._lock:
            return [[s.engine.compile_stats["n_traces"]
                     for s in row if s is not None]
                    for row in self._sessions]

    def close(self):
        self._pool.shutdown(wait=True)
        with self._lock:
            hedge_pool, self._hedge_pool = self._hedge_pool, None
        if hedge_pool is not None:
            hedge_pool.shutdown(wait=True)
        with self._lock:
            for row in self._sessions:
                for sess in row:
                    if sess is not None:
                        sess.close()
            self._sessions = [[None] * self.store.replicas
                              for _ in range(self.store.n_shards)]
        self.store.close()
