"""ShardedStore — N FlashStores behind one CLUSTER.json (DESIGN.md §5.1).

The paper's capacity story is multi-slice: one slice handles up to 1 TB
and the system grows by adding slices. Here a corpus is split by a
partition policy into per-shard FlashStore directories, each optionally
replicated, under a single manifest:

    <root>/CLUSTER.json                     commit point (os.replace swap)
    <root>/gen-000/shard-00/rep-0/          a complete FlashStore
    <root>/gen-000/shard-00/rep-1/          byte-wise independent replica
    <root>/gen-000/shard-01/rep-0/          ...

``rebalance`` re-splits into a *new* generation directory and swaps the
manifest afterwards, so a crash mid-rebalance leaves the old generation
intact and at worst an orphan ``gen-NNN`` tree; the next rebalance
garbage-collects every generation directory the live manifest does not
reference (covering crashes on either side of the swap). Every shard keeps its own segment vocab filters
and manifest, so in-storage pruning and the per-shard compile cache are
exactly the single-store behavior.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster import partition as partition_lib
from repro.core.corpus import Corpus
from repro.storage import segment as segment_lib
from repro.storage.store import (FlashStore, StoreStats, _corpus_docs,
                                 load_validated_manifest)

CLUSTER_MANIFEST = "CLUSTER.json"
CLUSTER_MAGIC = "rsps-cluster"
SUPPORTED_VERSIONS = (1,)
_REQUIRED_KEYS = ("version", "generation", "partition", "replicas",
                  "vocab_size", "shards")

log = logging.getLogger(__name__)

Doc = Tuple[int, Sequence[Tuple[int, int]]]


def _gen_dir(gen: int) -> str:
    return f"gen-{gen:03d}"


def _shard_rel(gen: int, shard: int, rep: int) -> str:
    return os.path.join(_gen_dir(gen), f"shard-{shard:02d}", f"rep-{rep}")


def _write_generation(root: str, docs: Sequence[Doc],
                      part: partition_lib.Partitioner, replicas: int,
                      gen: int, *, vocab_size: int, docs_per_segment: int,
                      page_items: int, filter_kind: str) -> List[Dict]:
    """Partition ``docs`` and write every shard/replica FlashStore of one
    generation. Input order is preserved within each shard, so shard
    contents are deterministic. Returns the manifest shard list."""
    # a crashed earlier attempt may have left a partial tree for this
    # generation (it was never committed — the manifest swap comes after
    # this returns); clear it so FlashStore.create doesn't collide
    shutil.rmtree(os.path.join(root, _gen_dir(gen)), ignore_errors=True)
    ids = np.asarray([d for d, _ in docs], np.int64)
    assign = part.shard_of(ids) if ids.size else np.empty(0, np.int64)
    shards = []
    for s in range(part.n_shards):
        sdocs = [docs[i] for i in np.flatnonzero(assign == s)]
        reps = []
        for r in range(replicas):
            rel = _shard_rel(gen, s, r)
            store = FlashStore.create(
                os.path.join(root, rel), vocab_size=vocab_size,
                docs_per_segment=docs_per_segment, page_items=page_items,
                filter_kind=filter_kind)
            if sdocs:
                store.append_docs(sdocs)
            store.close()
            reps.append(rel)
        shards.append({"replicas": reps, "n_docs": len(sdocs)})
    return shards


def _write_manifest(root: str, manifest: Dict):
    tmp = os.path.join(root, CLUSTER_MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(root, CLUSTER_MANIFEST))


def build_sharded_store(root: str, docs: Optional[Sequence[Doc]] = None, *,
                        corpus: Optional[Corpus] = None, n_shards: int,
                        replicas: int = 1, policy: str = "hash",
                        vocab_size: int,
                        docs_per_segment: int = 4096,
                        page_items: int = segment_lib.DEFAULT_PAGE_ITEMS,
                        filter_kind: str = "auto",
                        partitioner: Optional[partition_lib.Partitioner]
                        = None) -> "ShardedStore":
    """Split a corpus into an N-shard, R-replica cluster at ``root``.

    Exactly one of ``docs`` ([(doc_id, [(word, count), ...])]) or
    ``corpus`` must be given. Each replica is written independently
    (identical content); CLUSTER.json lands last, so a partially-built
    directory is never openable."""
    if (docs is None) == (corpus is None):
        raise ValueError("exactly one of docs= or corpus= is required")
    if corpus is not None:
        docs = _corpus_docs(corpus)
    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    os.makedirs(root, exist_ok=True)
    if os.path.exists(os.path.join(root, CLUSTER_MANIFEST)):
        raise FileExistsError(f"cluster already exists at {root}")
    part = partitioner or partition_lib.make_partitioner(
        policy, n_shards, doc_ids=[d for d, _ in docs])
    if part.n_shards != n_shards:
        raise ValueError(f"partitioner covers {part.n_shards} shards, "
                         f"asked for {n_shards}")
    shards = _write_generation(
        root, docs, part, replicas, 0, vocab_size=vocab_size,
        docs_per_segment=docs_per_segment, page_items=page_items,
        filter_kind=filter_kind)
    manifest = {
        "magic": CLUSTER_MAGIC,
        "version": 1,
        "generation": 0,
        "partition": part.spec(),
        "replicas": replicas,
        "vocab_size": vocab_size,
        "docs_per_segment": docs_per_segment,
        "page_items": page_items,
        "filter_kind": filter_kind,
        "shards": shards,
    }
    _write_manifest(root, manifest)
    return ShardedStore(root, manifest)


class ShardedStore:
    def __init__(self, root: str, manifest: Dict):
        self.root = root
        self.manifest = manifest
        self._open_stores: Dict[Tuple[int, int], FlashStore] = {}

    # -- lifecycle -----------------------------------------------------
    @classmethod
    def open(cls, root: str) -> "ShardedStore":
        return cls(root, load_validated_manifest(
            os.path.join(root, CLUSTER_MANIFEST), magic=CLUSTER_MAGIC,
            versions=SUPPORTED_VERSIONS, required=_REQUIRED_KEYS,
            kind="sharded store"))

    def close(self):
        for store in self._open_stores.values():
            store.close()
        self._open_stores.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- properties ----------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.manifest["shards"])

    @property
    def replicas(self) -> int:
        return self.manifest["replicas"]

    @property
    def vocab_size(self) -> int:
        return self.manifest["vocab_size"]

    @property
    def generation(self) -> int:
        return self.manifest["generation"]

    @property
    def partitioner(self) -> partition_lib.Partitioner:
        return partition_lib.from_spec(self.manifest["partition"])

    @property
    def n_docs(self) -> int:
        """Documents per the manifest (replica 0 of every shard)."""
        return sum(s["n_docs"] for s in self.manifest["shards"])

    # -- shard access --------------------------------------------------
    def shard_path(self, shard: int, replica: int = 0) -> str:
        return os.path.join(
            self.root, self.manifest["shards"][shard]["replicas"][replica])

    def store(self, shard: int, replica: int = 0) -> FlashStore:
        key = (shard, replica)
        if key not in self._open_stores:
            self._open_stores[key] = FlashStore.open(
                self.shard_path(shard, replica))
        return self._open_stores[key]

    def stats(self) -> List[StoreStats]:
        """Per-shard StoreStats (replica 0) — the rebalance planner's
        view of where the documents and bytes actually sit."""
        return [self.store(s).stats() for s in range(self.n_shards)]

    def scan_corpus(self, nnz_pad: int, *, strict: bool = True) -> Corpus:
        """Decode the whole cluster (replica 0 of every shard) into one
        in-memory Corpus, in shard order. Tests and load generators; the
        query path streams per shard instead."""
        parts = [self.store(s).scan_corpus(nnz_pad, strict=strict)
                 for s in range(self.n_shards)]
        parts = [c for c in parts if c.n_docs]
        if not parts:
            return Corpus.empty(nnz_pad)
        return Corpus(
            np.concatenate([c.doc_ids for c in parts]),
            np.concatenate([c.ids for c in parts]),
            np.concatenate([c.vals for c in parts]),
            np.concatenate([c.norms for c in parts]))

    # -- rebalance -----------------------------------------------------
    def _gc_stale_generations(self):
        """Remove every ``gen-*`` tree the live manifest does not
        reference — leftovers of a crash on either side of a previous
        rebalance's manifest swap."""
        live = {rel.split(os.sep)[0] for sh in self.manifest["shards"]
                for rel in sh["replicas"]}
        for fn in os.listdir(self.root):
            path = os.path.join(self.root, fn)
            if fn.startswith("gen-") and fn not in live \
                    and os.path.isdir(path):
                log.info("rebalance(%s): removing stale generation %s",
                         self.root, fn)
                shutil.rmtree(path, ignore_errors=True)

    def _iter_doc_ids(self) -> np.ndarray:
        """Every doc id in the cluster (replica 0), read from the raw
        streams' header words — no pair decode, ~8 bytes/doc of RAM."""
        from repro.core import stream_format
        out = []
        for s in range(self.n_shards):
            store = self.store(s)
            for e in store.entries:
                stream = store.segment(e.name).stream()
                hdrs = stream[(stream & stream_format.HEADER_BIT) != 0]
                out.append((hdrs & (stream_format.HEADER_BIT - 1))
                           .astype(np.int64))
                del stream, hdrs      # drop the mmap view before closing
                store.release(e.name)
        return np.concatenate(out) if out else np.empty(0, np.int64)

    def rebalance(self, *, n_shards: Optional[int] = None,
                  policy: Optional[str] = None,
                  replicas: Optional[int] = None,
                  docs_per_segment: Optional[int] = None) -> "ShardedStore":
        """Re-split the corpus into a new generation, streaming one old
        segment at a time: host memory holds at most one decoded segment
        plus one under-filled output chunk per target shard, so
        rebalance works at the beyond-RAM scale the tier exists for.
        The CLUSTER.json swap is the commit point; the old generation is
        deleted only after it, and stale generations from crashed
        attempts are garbage-collected first. Returns ``self``."""
        n_shards = n_shards or self.n_shards
        policy = policy or self.manifest["partition"]["policy"]
        replicas = replicas or self.replicas
        per = docs_per_segment or self.manifest["docs_per_segment"]
        plan = self.stats()
        log.info(
            "rebalance(%s): gen %d [%s] -> %d shards x %d replicas (%s); "
            "docs per shard before: %s", self.root, self.generation,
            self.manifest["partition"]["policy"], n_shards, replicas, policy,
            [st.n_docs for st in plan])
        self._gc_stale_generations()
        # pass 1 (cheap): ids only, to fit range bounds
        part = partition_lib.make_partitioner(
            policy, n_shards, doc_ids=self._iter_doc_ids())
        gen = self.generation + 1
        stores = [[FlashStore.create(
            os.path.join(self.root, _shard_rel(gen, s, r)),
            vocab_size=self.vocab_size, docs_per_segment=per,
            page_items=self.manifest["page_items"],
            filter_kind=self.manifest["filter_kind"])
            for r in range(replicas)] for s in range(n_shards)]
        bufs: List[List[Doc]] = [[] for _ in range(n_shards)]
        counts = [0] * n_shards

        def flush(s: int, final: bool = False):
            # full chunks of ``per`` (plus the tail when final), so the
            # segmentation matches a single append_docs of the shard.
            # Segments only — each store's manifest is written once at
            # the end (the generation is invisible until the CLUSTER.json
            # swap anyway, so per-chunk manifest commits would buy
            # nothing but O(segments^2) rewrite I/O).
            while len(bufs[s]) >= per or (final and bufs[s]):
                chunk = bufs[s][:per]
                del bufs[s][:per]
                for st in stores[s]:
                    st.manifest["segments"].append(
                        st._write_one_segment(chunk))
                counts[s] += len(chunk)

        # pass 2: stream old segments through the partitioner
        for s_old in range(self.n_shards):
            store = self.store(s_old)
            for e in store.entries:
                seg_docs = store.segment(e.name).docs()
                store.release(e.name)
                assign = part.shard_of(
                    np.asarray([d for d, _ in seg_docs], np.int64))
                for s in np.unique(assign):
                    bufs[s].extend(seg_docs[i]
                                   for i in np.flatnonzero(assign == s))
                    flush(int(s))
        shards = []
        for s in range(n_shards):
            flush(s, final=True)
            for st in stores[s]:
                st._write_manifest()
                st.close()
            shards.append({"replicas": [_shard_rel(gen, s, r)
                                        for r in range(replicas)],
                           "n_docs": counts[s]})
        self.close()
        manifest = dict(self.manifest, generation=gen, partition=part.spec(),
                        replicas=replicas, docs_per_segment=per,
                        shards=shards)
        old_gen = _gen_dir(self.generation)
        _write_manifest(self.root, manifest)        # commit point
        self.manifest = manifest
        shutil.rmtree(os.path.join(self.root, old_gen), ignore_errors=True)
        log.info("rebalance(%s): gen %d live; docs per shard after: %s",
                 self.root, gen, [s["n_docs"] for s in shards])
        return self


def rebalance(root: str, **kwargs) -> ShardedStore:
    """Open the cluster at ``root`` and re-split it (see
    ``ShardedStore.rebalance`` for the knobs)."""
    return ShardedStore.open(root).rebalance(**kwargs)
