"""FlashClusterSession — FlashSearchSession's serving surface over an
N-shard cluster (DESIGN.md §5).

Drop-in at the serving layer: ``search`` / ``submit`` / ``service`` have
the single-store session's exact signatures, so `SearchService`,
`repro.launch.search_serve`, and the benchmarks drive a cluster the
same way they drive one FlashStore. One coalesced batch costs one
scatter/gather pass: every shard prunes, prefetches, and scores its own
slice concurrently, and only ``[L, k]`` candidates per shard reach the
merge — the paper's "only documentIDs with high scores are reported",
at cluster scope.
"""
from __future__ import annotations

from typing import Optional, Union

from repro.cluster.router import ClusterStats, HedgePolicy, ShardRouter
from repro.cluster.store import ShardedStore
from repro.configs.paper_search import SearchConfig
from repro.core.engine import SearchResult
from repro.serve.api import Query, QueryOptions
from repro.serve.session_surface import ServingSessionMixin


class FlashClusterSession(ServingSessionMixin):
    def __init__(self, store: Union[str, ShardedStore], cfg: SearchConfig,
                 *, backend: str = "jnp", use_filter: bool = True,
                 prefetch_depth: int = 2,
                 max_workers: Optional[int] = None,
                 cache_bytes: Optional[int] = None,
                 obs=None, hedge_policy: Optional[HedgePolicy] = None,
                 mode: str = "exact", candidates: int = 0,
                 approx_min_docs: Optional[int] = None,
                 memo_entries: int = 0):
        """``cache_bytes`` sizes the cluster-shared device slab cache
        (DESIGN.md §4.2) every shard-replica session draws on
        (None = default budget, 0 = disabled). ``obs`` shares one
        observability bundle (DESIGN.md §8) across the router and every
        shard session; None falls back to the process default.
        ``hedge_policy`` arms replica hedging as the router default
        (DESIGN.md §7.3); per-query ``QueryOptions.hedging``
        overrides. ``mode``/``candidates``/``approx_min_docs`` set the
        approximate-tier defaults every shard session inherits (§15;
        exact by default), ``memo_entries`` sizes the cluster-shared
        recurrent-query memo cache (0 = off); per-query
        ``QueryOptions.mode/recall_target/candidates`` overrides ride
        the scatter to every shard."""
        if isinstance(store, str):
            store = ShardedStore.open(store)
        if store.vocab_size > cfg.vocab_size:
            # same invariant the engine and single-store session enforce
            raise ValueError(
                f"cluster vocab_size {store.vocab_size} exceeds "
                f"cfg.vocab_size {cfg.vocab_size}")
        self.store = store
        self.cfg = cfg
        self.router = ShardRouter(
            store, cfg, backend=backend, use_filter=use_filter,
            prefetch_depth=prefetch_depth, max_workers=max_workers,
            cache_bytes=cache_bytes, obs=obs, hedge_policy=hedge_policy,
            mode=mode, candidates=candidates,
            approx_min_docs=approx_min_docs, memo_entries=memo_entries)
        self._init_serving()

    @property
    def obs(self):
        """The cluster's shared observability bundle (DESIGN.md §8)."""
        return self.router.obs

    # ------------------------------------------------------------------
    def search(self, query, q_vals=None, *,
               options: Optional[QueryOptions] = None):
        """Global top-k over every shard (scatter/gather; see
        ShardRouter.search). Typed form — ``search(Query(ids, vals),
        options=QueryOptions(...))`` — returns a ``SearchResponse``
        with this query's scheduling stats (partial/hedged/missing
        shards); positional ``(q_ids, q_vals)`` arrays remain as a
        deprecation shim returning the bare ``SearchResult``."""
        return self.router.search(query, q_vals, options=options)

    def search_typed(self, query: Query,
                     options: Optional[QueryOptions] = None, *,
                     _span=None) -> SearchResult:
        """The raw typed surface the coalescing service dispatches to
        (no wrapping, no deprecation shim); see ShardRouter.search_typed
        for the deadline/partial/hedging contract."""
        return self.router.search_typed(query, options=options)

    # -- live ingestion (DESIGN.md §6.3) -------------------------------
    def enable_ingest(self, **knobs) -> "FlashClusterSession":
        """Attach a write path to every shard replica (each gets its own
        WAL + memtable + compactor). ``knobs`` are
        ``repro.ingest.IngestConfig`` fields."""
        self.router.enable_ingest(**knobs)
        return self

    def append(self, doc_id: int, pairs) -> int:
        """Append one document to the shard that owns its id (per the
        live partition spec — rebalance-aware) on every replica; it is
        searchable by the next query. Returns the owner shard. Per-shard
        snapshot consistency is the single-store guarantee; a scatter
        batch captures each shard's snapshot independently."""
        return self.router.append(doc_id, pairs)

    def flush_ingest(self) -> int:
        """Seal every shard memtable into delta segments (do this before
        ``ShardedStore.rebalance``, which streams segments)."""
        return self.router.flush_ingest()

    @property
    def last_stats(self) -> ClusterStats:
        return self.router.last_stats

    @property
    def last_trace(self):
        """Most recent sampled cluster QueryTrace (None unless ``obs``
        samples traces)."""
        return self.router.last_trace

    @property
    def slab_cache(self):
        """The cluster-shared device slab cache (None when disabled)."""
        return self.router.slab_cache

    @property
    def cache_stats(self):
        """Lifetime slab-cache counters across every shard session —
        the same surface ``FlashSearchSession.cache_stats`` exposes."""
        return self.router.cache_stats

    @property
    def memo_stats(self):
        """Cluster-shared recurrent-query memo counters (None = off),
        mirroring ``FlashSearchSession.memo_stats``."""
        return self.router.memo_stats

    @property
    def compile_stats(self) -> dict:
        """Aggregated engine traces: total plus the per-shard worst case
        (each shard session carries its own §7.2 L-bucket bound)."""
        counts = self.router.compile_counts()
        flat = [c for row in counts for c in row]
        return {"n_traces": sum(flat),
                "per_shard": [max(row, default=0) for row in counts]}

    def _close_resources(self):
        # service/submit/close lifecycle comes from ServingSessionMixin
        # (the same surface FlashSearchSession exposes, by construction)
        self.router.close()
