"""Doc-id partitioning policies for the cluster tier (DESIGN.md §5.1).

The paper scales capacity by adding flash slices; which slice owns a
document is a pure function of its doc id so the router never needs a
lookup table:

- ``HashPartitioner`` — splitmix64-mixed doc id modulo the shard count.
  Uniform regardless of id distribution; the default for write-heavy or
  unknown corpora.
- ``RangePartitioner`` — contiguous doc-id ranges split at explicit
  bounds. ``fit`` picks equal-count quantile bounds from an observed id
  set, so time- or tenant-ordered ids keep locality (and their segment
  vocab filters stay clustered, preserving per-shard skip-rate).

Both vectorize over arrays, serialize to a JSON ``spec`` embedded in
``CLUSTER.json``, and guarantee every non-negative doc id maps to
exactly one shard in ``[0, n_shards)`` — the invariant the partition
property tests pin.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

# the same splitmix64 avalanche the Bloom filter uses (a STABLE-CONTRACT
# function: hash partition assignments persist under CLUSTER.json):
# sequential doc ids must spread uniformly over shards
from repro.storage.filter import splitmix64 as _mix


def _check_ids(doc_ids) -> np.ndarray:
    ids = np.asarray(doc_ids, np.int64).reshape(-1)
    if ids.size and int(ids.min()) < 0:
        raise ValueError("doc ids must be >= 0 (negative ids are padding)")
    return ids


class Partitioner:
    """Maps doc ids to shard indices. Subclasses are pure functions of
    (spec, doc_id): no per-doc state, so routers and writers agree."""

    kind: str = "?"
    n_shards: int = 0

    def shard_of(self, doc_ids) -> np.ndarray:
        """[n] doc ids (>= 0) -> [n] shard indices in [0, n_shards)."""
        raise NotImplementedError

    def spec(self) -> Dict:
        """JSON-serializable policy description (``from_spec`` inverts)."""
        raise NotImplementedError


class HashPartitioner(Partitioner):
    kind = "hash"

    def __init__(self, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards

    def shard_of(self, doc_ids) -> np.ndarray:
        ids = _check_ids(doc_ids)
        return (_mix(ids.astype(np.uint64))
                % np.uint64(self.n_shards)).astype(np.int64)

    def spec(self) -> Dict:
        return {"policy": "hash", "n_shards": self.n_shards}


class RangePartitioner(Partitioner):
    """Shard s owns ids in ``(bounds[s-1], bounds[s]]`` (the last shard
    is unbounded above). ``len(bounds) == n_shards - 1``; duplicate
    bounds yield empty shards, which the router handles."""

    kind = "range"

    def __init__(self, bounds: Sequence[int]):
        b = np.asarray(list(bounds), np.int64).reshape(-1)
        if b.size and np.any(np.diff(b) < 0):
            raise ValueError("range bounds must be ascending")
        self.bounds = b
        self.n_shards = b.size + 1

    @classmethod
    def fit(cls, doc_ids, n_shards: int) -> "RangePartitioner":
        """Equal-count quantile bounds over the observed id set."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        ids = np.unique(_check_ids(doc_ids))
        if n_shards == 1:
            return cls(np.empty(0, np.int64))
        if ids.size == 0:
            return cls(np.arange(1, n_shards, dtype=np.int64))
        cuts = (np.arange(1, n_shards) * ids.size) // n_shards
        return cls(ids[np.maximum(cuts, 1) - 1])

    def shard_of(self, doc_ids) -> np.ndarray:
        ids = _check_ids(doc_ids)
        return np.searchsorted(self.bounds, ids, side="left").astype(np.int64)

    def spec(self) -> Dict:
        return {"policy": "range", "bounds": self.bounds.tolist()}


def from_spec(spec: Dict) -> Partitioner:
    """Rebuild a partitioner from its ``CLUSTER.json`` spec."""
    policy = spec.get("policy")
    if policy == "hash":
        return HashPartitioner(int(spec["n_shards"]))
    if policy == "range":
        return RangePartitioner(spec["bounds"])
    raise ValueError(f"unknown partition policy {policy!r}")


def make_partitioner(policy: str, n_shards: int,
                     doc_ids=None) -> Partitioner:
    """Policy name -> partitioner. ``range`` fits quantile bounds from
    ``doc_ids`` (required); ``hash`` ignores them."""
    if policy == "hash":
        return HashPartitioner(n_shards)
    if policy == "range":
        if doc_ids is None:
            raise ValueError("range policy needs doc_ids to fit bounds")
        return RangePartitioner.fit(doc_ids, n_shards)
    raise ValueError(f"unknown partition policy {policy!r}")
