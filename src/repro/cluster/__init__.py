"""Sharded multi-slice cluster tier: partitioned FlashStores, replica
failover, and scatter/gather top-k behind one serving surface
(DESIGN.md §5)."""
from repro.cluster.partition import (HashPartitioner, Partitioner,
                                     RangePartitioner, from_spec,
                                     make_partitioner)
from repro.cluster.router import ClusterSearchError, ClusterStats, ShardRouter
from repro.cluster.session import FlashClusterSession
from repro.cluster.store import ShardedStore, build_sharded_store, rebalance

__all__ = [
    "HashPartitioner", "Partitioner", "RangePartitioner", "from_spec",
    "make_partitioner",
    "ClusterSearchError", "ClusterStats", "ShardRouter",
    "FlashClusterSession",
    "ShardedStore", "build_sharded_store", "rebalance",
]
