"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production mesh with 512 placeholder host devices, record
memory_analysis / cost_analysis / HLO for the roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k \
      --mesh single --out results/dryrun
  python -m repro.launch.dryrun --all --mesh multi --out results/dryrun
"""
# The assignment requires these to be the VERY FIRST lines — jax locks the
# device count on first init, and smoke tests/benches must still see 1.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import gzip              # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES, OptimizerConfig, TrainConfig, \
    shape_applicable  # noqa: E402
from repro.configs.registry import ARCH_NAMES, get_config  # noqa: E402
from repro.distributed import sharding as sh_lib  # noqa: E402
from repro.launch import specs as specs_lib  # noqa: E402
from repro.launch.mesh import make_ctx  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serve import step as serve_step  # noqa: E402
from repro.train import optimizer as opt_lib  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402

V5E_HBM_PER_CHIP = 16 * 1024 ** 3


def _named(ctx, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_lowering(arch: str, shape_name: str, multi_pod: bool,
                   int8_opt: bool = False, compress: bool = False,
                   variant: str = "base"):
    from repro.models import perfcfg
    if variant == "cf11":
        perfcfg.set_variant("a2aint8")
    else:
        perfcfg.set_variant(variant)
    cfg = get_config(arch)
    if variant == "cf11":   # tighter expert capacity: cf appears squared
        cfg = dataclasses.replace(cfg, capacity_factor=1.1)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, why
    # >500B-param training requires int8 optimizer states to fit the pod
    # (DESIGN.md §14 / EXPERIMENTS.md §Dry-run)
    if shape.kind == "train" and cfg.param_count() > 5e11:
        int8_opt = True
    ctx = make_ctx(multi_pod=multi_pod)
    params_struct = jax.eval_shape(lambda k: M.init(k, cfg),
                                   jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = sh_lib.build_param_specs(params_struct, cfg, ctx)
    p_shard = _named(ctx, pspecs)
    batch_spec = specs_lib.input_specs(cfg, shape)
    dpspec = P(ctx.dp_axes)

    def batch_shardings(bs):
        out = {}
        for k, v in bs.items():
            out[k] = NamedSharding(
                ctx.mesh, P(ctx.dp_axes, *([None] * (v.ndim - 1))))
        return out

    if shape.kind == "train":
        opt_cfg = OptimizerConfig(int8_states=int8_opt,
                                  grad_compression=compress)
        tc = TrainConfig(model=cfg, opt=opt_cfg, seq_len=shape.seq_len,
                         global_batch=shape.global_batch)
        opt_struct = jax.eval_shape(
            lambda p: opt_lib.init_state(opt_cfg, p), params_struct)
        o_specs = sh_lib.opt_state_specs(opt_struct, pspecs, ctx)
        o_shard = _named(ctx, o_specs)
        step_fn = make_train_step(tc, cfg, ctx, donate=True, jit=False)
        err_struct, err_shard = {}, {}
        if compress and "pod" in ctx.mesh.axis_names:
            err_struct = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                params_struct)
            err_shard = p_shard
        args = (params_struct, opt_struct, batch_spec["batch"], err_struct)
        in_sh = (p_shard, o_shard, batch_shardings(batch_spec["batch"]),
                 err_shard)
        lowered = jax.jit(
            step_fn, in_shardings=in_sh, donate_argnums=(0, 1),
        ).lower(*args)
        return (lowered, cfg, ctx), ""

    if shape.kind == "prefill":
        fn = serve_step.make_prefill(cfg, ctx, jit=False)
        args = (params_struct, batch_spec["batch"])
        in_sh = (p_shard, batch_shardings(batch_spec["batch"]))
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        return (lowered, cfg, ctx), ""

    # decode
    fn = serve_step.make_decode_step(cfg, ctx, donate=True, jit=False)
    cache_struct = batch_spec["cache"]
    c_specs = serve_step.cache_specs(cfg, ctx, shape.global_batch)
    c_shard = _named(ctx, c_specs)
    args = (params_struct, batch_spec["batch"], cache_struct,
            batch_spec["cur_index"])
    b = batch_spec["batch"]
    bsh = {}
    for k, v in b.items():
        spec = P(ctx.dp_axes, *([None] * (v.ndim - 1))) \
            if shape.global_batch % ctx.dp_size == 0 else \
            P(*([None] * v.ndim))
        bsh[k] = NamedSharding(ctx.mesh, spec)
    in_sh = (p_shard, bsh, c_shard, NamedSharding(ctx.mesh, P()))
    lowered = jax.jit(fn, in_shardings=in_sh,
                      donate_argnums=(2,)).lower(*args)
    return (lowered, cfg, ctx), ""


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             int8_opt: bool = False, compress: bool = False,
             variant: str = "base", save_hlo: bool = True):
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{mesh_name}" + \
        (f"__{variant}" if variant != "base" else "")
    os.makedirs(out_dir, exist_ok=True)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "int8_opt": int8_opt, "compress": compress}
    t0 = time.time()
    try:
        built, why = build_lowering(arch, shape_name, multi_pod,
                                    int8_opt=int8_opt, compress=compress,
                                    variant=variant)
        if built is None:
            rec["status"] = "skipped"
            rec["reason"] = why
            _dump(out_dir, tag, rec)
            print(f"[dryrun] {tag}: SKIPPED ({why})")
            return rec
        lowered, cfg, ctx = built
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = _mem_dict(ma)
        ca = compiled.cost_analysis()
        rec["cost_analysis"] = {k: float(v) for k, v in (ca or {}).items()
                                if isinstance(v, (int, float))}
        print(compiled.memory_analysis())
        print({k: v for k, v in rec["cost_analysis"].items()
               if k in ("flops", "bytes accessed")})
        n_chips = ctx.mesh.size
        rec["n_chips"] = n_chips
        rec["params"] = cfg.param_count()
        rec["active_params"] = cfg.active_param_count()
        if save_hlo:
            hlo = compiled.as_text()
            with gzip.open(os.path.join(out_dir, tag + ".hlo.gz"), "wt") as f:
                f.write(hlo)
            rec["hlo_bytes"] = len(hlo)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, don't die
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {tag}: ERROR {rec['error']}")
    _dump(out_dir, tag, rec)
    status = rec["status"]
    print(f"[dryrun] {tag}: {status} "
          f"(lower {rec.get('lower_s', 0):.1f}s, "
          f"compile {rec.get('compile_s', 0):.1f}s)")
    return rec


def _mem_dict(ma):
    out = {}
    for field in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "temp_size_in_bytes", "host_generated_code_size_in_bytes",
                  "host_argument_size_in_bytes", "host_output_size_in_bytes",
                  "host_alias_size_in_bytes", "host_temp_size_in_bytes"):
        if hasattr(ma, field):
            out[field] = int(getattr(ma, field))
    return out


def _dump(out_dir, tag, rec):
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--int8-opt", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    multi = args.mesh == "multi"
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                run_cell(arch, shape, multi, args.out,
                         int8_opt=args.int8_opt, compress=args.compress,
                         save_hlo=not args.no_hlo)
    else:
        run_cell(args.arch, args.shape, multi, args.out,
                 int8_opt=args.int8_opt, compress=args.compress,
                 variant=args.variant, save_hlo=not args.no_hlo)


if __name__ == "__main__":
    main()
