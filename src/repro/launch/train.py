"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --steps 100 --seq-len 128 --batch 8 --ckpt-dir /tmp/run1

On this CPU container it runs the single-device mesh with the exact same
code path as the pod meshes (see dryrun.py for the 256/512-chip lowering).
Restart the command to resume from the latest checkpoint; SIGTERM triggers
a synchronous final checkpoint (preemption hook).
"""
import argparse

import jax

from repro.configs.base import OptimizerConfig, TrainConfig
from repro.configs.registry import ARCH_NAMES, get_config, get_smoke_config
from repro.distributed.meshctx import MeshCtx, single_device_ctx
from repro.train.loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--int8-opt", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tc = TrainConfig(
        model=cfg,
        opt=OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                            total_steps=args.steps,
                            int8_states=args.int8_opt),
        seq_len=args.seq_len, global_batch=args.batch,
        microbatches=args.microbatches,
        checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt_dir)
    ctx = single_device_ctx()   # pod meshes: see launch/mesh.py + dryrun.py
    trainer = Trainer(tc, ctx)
    trainer.install_preemption_hook()
    print(f"[train] {cfg.name}: {cfg.param_count():,} params, "
          f"{args.steps} steps")
    metrics = trainer.run(args.steps)
    print(f"[train] final metrics: {metrics}")


if __name__ == "__main__":
    main()
