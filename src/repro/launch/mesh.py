"""Production mesh builders (assignment MULTI-POD DRY-RUN spec)."""
from __future__ import annotations

import jax

from repro.distributed.meshctx import MeshCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_ctx(*, multi_pod: bool = False) -> MeshCtx:
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = ("pod", "data") if multi_pod else ("data",)
    return MeshCtx(mesh=mesh, dp_axes=dp, fsdp_axis="data", tp_axis="model")
