"""Serving launcher: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --batch 2 --prompt-len 16 --max-new 8
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_NAMES, get_config, get_smoke_config
from repro.distributed.meshctx import single_device_ctx
from repro.models import model as M
from repro.serve.step import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.embeds_input:
        raise SystemExit(f"{args.arch} takes frame embeddings (stub "
                         f"frontend); see examples/rag_serve.py for the "
                         f"embeddings-in path")
    ctx = single_device_ctx()
    params = M.init(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32))
    t0 = time.time()
    out = generate(params, cfg, ctx, prompt, max_new=args.max_new,
                   max_len=args.prompt_len + args.max_new,
                   temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"[serve] {cfg.name}: generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    for b in range(args.batch):
        print(f"  seq {b}: {np.asarray(out)[b].tolist()}")


if __name__ == "__main__":
    main()
