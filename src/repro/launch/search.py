"""Search-service launcher: build/load a corpus, serve queries.

    PYTHONPATH=src python -m repro.launch.search --n-docs 100000 \
        --queries 8 --top-k 10
"""
import argparse
import time

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.serve import Query


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=100_000)
    ap.add_argument("--vocab", type=int, default=141_000)
    ap.add_argument("--avg-nnz", type=int, default=60)
    ap.add_argument("--nnz-pad", type=int, default=64)
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--backend", choices=["jnp", "pallas", "pallas_packed"],
                    default="jnp")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = SearchConfig(name="service", vocab_size=args.vocab,
                       avg_nnz_per_doc=args.avg_nnz, nnz_pad=args.nnz_pad,
                       top_k=args.top_k)
    print(f"[search] synthesizing {args.n_docs} docs "
          f"(vocab {args.vocab}, ~{args.avg_nnz} nnz/doc)...")
    corpus = corpus_lib.synthesize(args.n_docs, args.vocab, args.avg_nnz,
                                   args.nnz_pad, seed=args.seed)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                              backend=args.backend)
    rng = np.random.default_rng(args.seed)
    idxs = rng.integers(0, args.n_docs, args.queries)
    qs = [corpus_lib.make_query(corpus, int(i), cfg.max_query_nnz)
          for i in idxs]
    qi = np.stack([q[0] for q in qs])
    qv = np.stack([q[1] for q in qs])

    batch = Query(qi, qv)
    eng.search(batch)             # warm up / compile
    t0 = time.time()
    res = eng.search(batch)
    dt = time.time() - t0
    print(f"[search] {args.queries} queries x {args.n_docs} docs in "
          f"{dt*1e3:.1f} ms ({args.n_docs*args.queries/dt:.3e} "
          f"doc-query pairs/s on CPU)")
    for l, i in enumerate(idxs):
        hit = "OK" if res.doc_ids[l, 0] == i else "MISS"
        print(f"  q{l} (doc {i}): top1 = doc {res.doc_ids[l, 0]} "
              f"cos {res.scores[l, 0]:.4f} [{hit}]")


if __name__ == "__main__":
    main()
