"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation (assignment step 2)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.meshctx import MeshCtx
from repro.models import model as M


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    s = {}
    if cfg.embeds_input:
        s["embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                           jnp.bfloat16)
        s["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    else:
        s["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.family == "vlm":
        s["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    return s


def cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct tree matching model.init_cache (no allocation)."""
    return jax.eval_shape(lambda: M.init_cache(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Returns (kind, specs dict) for the step function to lower."""
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape.global_batch, shape.seq_len)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape.global_batch, shape.seq_len)}
    # decode: one new token against a seq_len cache
    step = batch_specs(cfg, shape.global_batch, 1)
    return {
        "batch": step,
        "cache": cache_struct(cfg, shape.global_batch, shape.seq_len),
        "cur_index": jax.ShapeDtypeStruct((), jnp.int32),
    }
