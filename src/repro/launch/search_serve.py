"""Concurrent-serving launcher: closed-loop load generator against the
micro-batching SearchService (DESIGN.md §7).

N client threads each submit one query at a time and wait for its
result (closed loop), so offered load scales with concurrency the way
a fleet of blocking callers does. Reports per-query p50/p99 latency,
aggregate QPS, batch occupancy and the engine's compile-cache traces.

    PYTHONPATH=src python -m repro.launch.search_serve --n-docs 20000 \\
        --clients 16 --requests 32 --max-batch 8 --max-delay-ms 2

    # one-query-at-a-time baseline for the coalescing speedup:
    PYTHONPATH=src python -m repro.launch.search_serve --serial \\
        --n-docs 20000 --clients 16 --requests 32

Add ``--store PATH`` to serve an existing FlashStore through a
FlashSearchSession, or ``--cluster PATH`` to serve a sharded store
(DESIGN.md §5) through a FlashClusterSession, instead of a synthesized
resident corpus. With either, ``--ingest N`` additionally runs a
closed-loop writer thread that appends N fresh documents through the
live-ingestion tier (WAL -> memtable -> delta segments, DESIGN.md §6)
*while* the query clients run — the serving-under-writes scenario —
and reports appends/sec plus seal/compaction counts.

Storage-backed targets serve hot segments from the device slab cache
(DESIGN.md §4.2); ``--cache-mb`` sizes its byte budget (0 disables)
and the post-run summary reports the hit rate.

Observability (DESIGN.md §8): every target serves under one ``Obs``
bundle, and the post-run summary is the same block for all of them —
query/stage latency percentiles, cache state, compile traces, slow
queries. ``--metrics-out PATH`` dumps the registry in Prometheus text
format (plus ``PATH.traces.json`` when tracing); ``--trace-sample N``
samples every Nth query into a QueryTrace and prints the last one.

The *live* plane (DESIGN.md §8.4–§8.5): ``--telemetry-port PORT``
serves ``/metrics`` (Prometheus text with rolling-window gauges),
``/healthz`` (replica + ingest liveness), ``/slo`` (burn states for the
stock latency/availability objectives; tune with ``--slo-ms`` /
``--slo-target``) and ``/debug/traces`` on 127.0.0.1 while the load
runs. ``--profile-dir DIR`` arms ``/debug/profile`` (jax.profiler
capture); ``--device-fence`` splits ``stage_ms{score}`` into dispatch
vs device time.
"""
import argparse
import threading
import time

import numpy as np

from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.obs import Obs
from repro.obs.export import (render_summary, render_trace, write_metrics,
                              write_traces)
from repro.serve import (DeadlineExceeded, HedgePolicy, OverloadError, Query,
                         QueryOptions, SearchService)


def run_clients(n_clients, n_requests, do_query):
    """Closed loop: each thread issues its requests back-to-back.
    Returns (per-query latencies sec, wall time sec)."""
    lats = [[] for _ in range(n_clients)]
    errors = []

    def client(tid):
        rng = np.random.default_rng(1000 + tid)
        try:
            for _ in range(n_requests):
                t0 = time.perf_counter()
                do_query(rng)
                lats[tid].append(time.perf_counter() - t0)
        except Exception as e:           # surface, don't hang the join
            errors.append(e)

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return np.concatenate([np.asarray(l) for l in lats]), wall


def report(tag, lats, wall):
    n = lats.size
    print(f"[{tag}] {n} queries in {wall:.2f}s -> {n / wall:.1f} QPS | "
          f"latency p50 {np.percentile(lats, 50) * 1e3:.1f} ms  "
          f"p99 {np.percentile(lats, 99) * 1e3:.1f} ms  "
          f"mean {lats.mean() * 1e3:.1f} ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=20_000)
    ap.add_argument("--vocab", type=int, default=50_000)
    ap.add_argument("--avg-nnz", type=int, default=60)
    ap.add_argument("--nnz-pad", type=int, default=64)
    ap.add_argument("--query-nnz", type=int, default=48)
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--backend", choices=["jnp", "pallas", "pallas_packed"],
                    default="jnp")
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--requests", type=int, default=32,
                    help="requests per client (closed loop)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    ap.add_argument("--serial", action="store_true",
                    help="bypass the coalescer: engine.search per query "
                         "under a lock (the one-at-a-time baseline)")
    # scheduling plane (DESIGN.md §7.3): deadlines, admission, hedging
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-query latency budget: the EDF batcher "
                         "flushes early to meet it and drops expired "
                         "requests (DeadlineExceeded) before scoring")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="admission bound on queued+scoring requests; "
                         "beyond it submits shed with OverloadError")
    ap.add_argument("--tenant-qps", type=float, default=None,
                    help="per-tenant token-bucket quota (tokens/s); "
                         "over-quota submits shed with OverloadError")
    ap.add_argument("--allow-partial", action="store_true",
                    help="consent to best-effort gathers: a cluster "
                         "query that hits --deadline-ms returns the "
                         "merged top-k of the responsive shards, "
                         "flagged partial")
    ap.add_argument("--hedge-percentile", type=float, default=None,
                    metavar="P",
                    help="arm replica hedging on the cluster: fire the "
                         "next replica once a shard attempt outlives "
                         "the rolling-window P-quantile of shard "
                         "latency (e.g. 0.95; needs --cluster with "
                         "replicas >= 2)")
    # approximate tier (DESIGN.md §15): candidate generation + re-rank
    ap.add_argument("--mode", choices=["exact", "approx", "auto"],
                    default="exact",
                    help="scoring tier for --store/--cluster: exact "
                         "scans every surviving slab (default), approx "
                         "takes the posting-candidate + exact-re-rank "
                         "path, auto picks by corpus size")
    ap.add_argument("--recall-target", type=float, default=None,
                    metavar="R",
                    help="approx-tier recall@k goal in (0, 1]; sizes "
                         "the candidate pool per query when "
                         "--candidates is not given")
    ap.add_argument("--candidates", type=int, default=None, metavar="C",
                    help="explicit per-segment top-C candidate pool "
                         "for the approx tier (wins over "
                         "--recall-target)")
    ap.add_argument("--memo", type=int, default=0, metavar="N",
                    help="recurrent-query memo cache: keep the last N "
                         "results keyed by normalized query fingerprint "
                         "(0 = off; invalidated on any store mutation)")
    tgt = ap.add_mutually_exclusive_group()
    tgt.add_argument("--store", help="serve this FlashStore path through a "
                                     "FlashSearchSession")
    tgt.add_argument("--cluster", help="serve this sharded-store path "
                                       "through a FlashClusterSession")
    ap.add_argument("--ingest", type=int, default=0, metavar="N",
                    help="append N synthesized documents through the "
                         "live write path while the clients run "
                         "(requires --store or --cluster)")
    ap.add_argument("--seal-docs", type=int, default=256,
                    help="memtable seal threshold for --ingest")
    ap.add_argument("--cache-mb", type=float, default=None,
                    help="device slab cache budget in MB for --store/"
                         "--cluster (default: the storage tier's "
                         "default budget; 0 disables the cache)")
    ap.add_argument("--metrics-out", metavar="PATH",
                    help="write the metrics registry in Prometheus text "
                         "format here after the run (and the retained "
                         "trace trees to PATH.traces.json when "
                         "--trace-sample is on)")
    ap.add_argument("--trace-sample", type=int, default=0, metavar="N",
                    help="sample every Nth query into a QueryTrace "
                         "(0 = tracing off, the default)")
    ap.add_argument("--slow-ms", type=float, default=250.0,
                    help="slow-query log threshold for the summary")
    ap.add_argument("--telemetry-port", type=int, default=None,
                    metavar="PORT",
                    help="serve the live telemetry plane (/metrics, "
                         "/healthz, /slo, /debug/traces — DESIGN.md "
                         "§8.5) on 127.0.0.1:PORT for the run's "
                         "duration (0 picks a free port)")
    ap.add_argument("--slo-ms", type=float, default=250.0,
                    help="latency-SLO threshold for the telemetry "
                         "plane's stock objectives")
    ap.add_argument("--slo-target", type=float, default=0.99,
                    help="latency-SLO good fraction target")
    ap.add_argument("--profile-dir", metavar="DIR",
                    help="arm /debug/profile: GET it to capture a "
                         "jax.profiler trace into DIR (needs "
                         "--telemetry-port)")
    ap.add_argument("--device-fence", action="store_true",
                    help="fence the score dispatch (block_until_ready) "
                         "so stage_ms splits score into dispatch vs "
                         "device time — measurement mode, adds sync")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.ingest and not (args.store or args.cluster):
        ap.error("--ingest needs --store or --cluster (the resident "
                 "engine has no write path)")
    if (args.mode != "exact" or args.memo) \
            and not (args.store or args.cluster):
        ap.error("--mode/--memo need --store or --cluster (the resident "
                 "engine has no posting tier)")

    cfg = SearchConfig(name="serve", vocab_size=args.vocab,
                       avg_nnz_per_doc=args.avg_nnz, nnz_pad=args.nnz_pad,
                       top_k=args.top_k)
    cache_bytes = None if args.cache_mb is None \
        else int(args.cache_mb * 1e6)
    # one Obs bundle for the whole process: every target publishes into
    # the same registry, so the post-run summary is target-agnostic
    obs = Obs(trace_sample=args.trace_sample, slow_ms=args.slow_ms,
              device_fence=args.device_fence)
    if args.store:
        from repro.storage import FlashSearchSession, FlashStore
        store = FlashStore.open(args.store)
        searcher = FlashSearchSession(store, cfg, backend=args.backend,
                                      cache_bytes=cache_bytes, obs=obs,
                                      mode=args.mode,
                                      memo_entries=args.memo)
        corpus = store.scan_corpus(cfg.nnz_pad, strict=False)
        print(f"[serve] store {args.store}: {store.n_docs} docs / "
              f"{store.n_segments} segments")
    elif args.cluster:
        from repro.cluster import FlashClusterSession, ShardedStore
        cstore = ShardedStore.open(args.cluster)
        hedge = (HedgePolicy(percentile=args.hedge_percentile)
                 if args.hedge_percentile is not None else None)
        searcher = FlashClusterSession(cstore, cfg, backend=args.backend,
                                       cache_bytes=cache_bytes, obs=obs,
                                       hedge_policy=hedge, mode=args.mode,
                                       memo_entries=args.memo)
        corpus = cstore.scan_corpus(cfg.nnz_pad, strict=False)
        print(f"[serve] cluster {args.cluster}: {cstore.n_shards} shards x "
              f"{cstore.replicas} replicas, {cstore.n_docs} docs")
    else:
        print(f"[serve] synthesizing {args.n_docs} docs "
              f"(vocab {args.vocab}, ~{args.avg_nnz} nnz/doc)...")
        corpus = corpus_lib.synthesize(args.n_docs, args.vocab, args.avg_nnz,
                                       args.nnz_pad, seed=args.seed)
        searcher = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                                       backend=args.backend, obs=obs)

    # live telemetry plane (DESIGN.md §8.5): HTTP thread on the shared
    # Obs bundle, up for the whole run so an operator (or the cluster
    # stress test) can scrape mid-load
    telemetry = None
    slo_monitor = None
    if args.telemetry_port is not None:
        from repro.obs.server import TelemetryServer, register_searcher_health
        from repro.obs.slo import SLOMonitor, default_slos
        surface = ("cluster" if args.cluster
                   else "store" if args.store else "serve")
        slo_monitor = SLOMonitor(obs, default_slos(
            surface, latency_ms=args.slo_ms,
            latency_target=args.slo_target))
        telemetry = TelemetryServer(obs, port=args.telemetry_port,
                                    slo_monitor=slo_monitor,
                                    profile_dir=args.profile_dir)
        register_searcher_health(telemetry, searcher)
        print(f"[serve] telemetry: {telemetry.url('/metrics')}  "
              f"{telemetry.url('/healthz')}  {telemetry.url('/slo')}")

    def draw_query(rng):
        qi, qv = corpus_lib.make_query(corpus, int(rng.integers(corpus.n_docs)),
                                       args.query_nnz)
        return qi, qv

    writer_state = {"done": 0, "wall": 0.0}
    writer_thread = None
    if args.ingest:
        searcher.enable_ingest(seal_docs=args.seal_docs)
        # sample from the *store's* vocab, not the CLI default — the
        # session allows store.vocab_size < cfg.vocab_size, and appends
        # reject word ids beyond the store's range
        vocab = searcher.store.vocab_size
        next_id = int(corpus.doc_ids.max()) + 1 if corpus.n_docs else 0

        def writer():
            # closed loop: one append at a time, back-to-back, racing
            # the query clients — every search snapshots mid-stream
            rng = np.random.default_rng(args.seed + 7)
            nnz = min(args.avg_nnz, vocab)
            t0 = time.perf_counter()
            try:
                for i in range(args.ingest):
                    pairs = [(int(w), int(rng.integers(1, 30))) for w in
                             rng.choice(vocab, nnz, replace=False)]
                    searcher.append(next_id + i, pairs)
                    writer_state["done"] = i + 1
            except Exception as e:           # surfaced after join, like
                writer_state["error"] = e    # the query clients' errors
            finally:
                writer_state["wall"] = time.perf_counter() - t0

        writer_thread = threading.Thread(target=writer, name="ingest-writer")

    def warm_buckets(max_l):
        """Compile every L-bucket program up front so the measured window
        is steady-state (one trace per power-of-two bucket)."""
        rng = np.random.default_rng(args.seed)
        L = 1
        while L <= max_l:
            qs = [draw_query(rng) for _ in range(L)]
            searcher.search(Query(np.stack([q[0] for q in qs]),
                                  np.stack([q[1] for q in qs])))
            L *= 2

    # the per-query scheduling contract (None = legacy FIFO/unbounded);
    # --recall-target/--candidates ride per query so the session default
    # mode can stay exact while clients opt into the approx tier
    q_opts = None
    if (args.deadline_ms is not None or args.allow_partial
            or args.hedge_percentile is not None
            or args.recall_target is not None
            or args.candidates is not None):
        q_opts = QueryOptions(deadline_ms=args.deadline_ms,
                              allow_partial=args.allow_partial,
                              recall_target=args.recall_target,
                              candidates=args.candidates)
    sched = {"shed": 0, "expired": 0}
    sched_lock = threading.Lock()

    if args.serial:
        lock = threading.Lock()          # engines serve one call at a time

        def do_query(rng):
            qi, qv = draw_query(rng)
            with lock:
                searcher.search(Query(qi[None], qv[None]))

        warm_buckets(1)
        if writer_thread is not None:
            writer_thread.start()
        lats, wall = run_clients(args.clients, args.requests, do_query)
        report("serial", lats, wall)
    else:
        svc = SearchService(searcher, max_batch=args.max_batch,
                            max_delay_ms=args.max_delay_ms,
                            max_pending=args.max_pending,
                            tenant_qps=args.tenant_qps)

        def do_query(rng):
            qi, qv = draw_query(rng)
            try:
                svc.submit(Query(qi, qv), options=q_opts).result()
            except OverloadError:        # shed at the door — counted,
                with sched_lock:         # not fatal: backpressure is
                    sched["shed"] += 1   # the feature under test
            except DeadlineExceeded:
                with sched_lock:
                    sched["expired"] += 1

        warm_buckets(args.max_batch)
        if writer_thread is not None:
            writer_thread.start()
        lats, wall = run_clients(args.clients, args.requests, do_query)
        report(f"coalesced x{args.max_batch}", lats, wall)
        st = svc.stats
        print(f"  batches {st.n_batches}  mean occupancy "
              f"{st.mean_occupancy:.2f}  flushes {st.flushes}")
        if svc.admission is not None or q_opts is not None:
            n_total = args.clients * args.requests
            print(f"  scheduling: {sched['shed']} shed "
                  f"({100 * sched['shed'] / max(n_total, 1):.1f}%) "
                  f"{st.flushes.get('deadline', 0)} deadline flushes, "
                  f"{st.n_expired} expired; "
                  f"by reason {svc.shed_counts()}")
        svc.close()
    if writer_thread is not None:
        writer_thread.join()                 # let a slow writer finish
        if "error" in writer_state:
            raise writer_state["error"]
        done, w_wall = writer_state["done"], writer_state["wall"]
        print(f"  ingest: {done} docs appended in {w_wall:.2f}s "
              f"-> {done / max(w_wall, 1e-9):.0f} appends/s under load")
        pipes = [searcher.ingest] if args.store \
            else searcher.router.ingest_pipelines()
        seals = sum(p.stats.seals for p in pipes)
        folds = sum(p.stats.compactions for p in pipes)
        print(f"  ingest: {seals} seal(s), {folds} background fold(s); "
              f"memtable tail {sum(len(p.memtable) for p in pipes)} docs")
        qi, qv = corpus_lib.make_query(corpus, 0, args.query_nnz)
        searcher.search(Query(qi[None], qv[None]))  # post-run sanity pass
        st = searcher.last_stats
        print(f"  post-ingest store: {st.docs_scored} docs scored "
              f"(snapshot incl. memtable)")
    # unified post-run block (DESIGN.md §8.3): one summary whichever
    # target served — resident engine, store session, or cluster
    print(render_summary(searcher, obs, slo_monitor=slo_monitor))
    if args.cluster:
        down = sum(not ok for row in searcher.router.health() for ok in row)
        print(f"router lifetime: {searcher.router.failovers} replicas "
              f"failed over, {down} out of rotation")
    if args.memo:
        ms = searcher.memo_stats
        total = ms.hits + ms.misses
        print(f"memo cache: {ms.hits}/{total} hits "
              f"({100 * ms.hits / max(total, 1):.1f}%), "
              f"{ms.entries} entries, {ms.evictions} evicted")
    if args.trace_sample:
        print("last sampled trace:")
        print(render_trace(getattr(searcher, "last_trace", None)
                           or obs.tracer.last_trace))
    if args.metrics_out:
        write_metrics(obs, args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
        if args.trace_sample:
            n = write_traces(obs, args.metrics_out + ".traces.json")
            print(f"traces  -> {args.metrics_out}.traces.json ({n} trace(s))")
    if telemetry is not None:
        telemetry.close()
    if args.store or args.cluster:
        searcher.close()


if __name__ == "__main__":
    main()
