"""In-house AdamW with distributed-memory options.

- fp32 m/v states by default;
- ``int8_states``: block-quantized (per-128-block absmax int8) first/second
  moments — the optimizer-memory trick that makes kimi-k2-scale training
  fit the pod (EXPERIMENTS.md §Dry-run memory table);
- cosine LR schedule with warmup, decoupled weight decay, global-norm clip.

States mirror the param tree so the checkpointer and the elastic resharder
treat them uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig

Array = jax.Array
BLOCK = 128


# ---------------------------------------------------------------------------
# block-wise int8 quantization (for optimizer states / gradient compression)
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    q: Array        # int8 payload, [n_blocks, BLOCK]
    scale: Array    # fp32 per-block absmax / 127, [n_blocks]
    shape: Tuple[int, ...] = ()   # static (aux data)

    def tree_flatten(self):
        return (self.q, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(q=children[0], scale=children[1], shape=aux)

    @property
    def dtype(self):
        return jnp.float32


def _block_of(shape) -> int:
    """Quantization blocks run along the LAST axis so the int8 payload has
    the *same shape/sharding as the param* — no resharding or gathers in
    the update step (the flat-blocked variant replicated kimi-1T moments:
    4 TB/device temp measured; this layout: none)."""
    last = shape[-1] if shape else 1
    return BLOCK if last % BLOCK == 0 else last


def quantize_block(x: Array) -> QTensor:
    shape = x.shape
    if not shape:
        return QTensor(q=jnp.zeros((), jnp.int8),
                       scale=jnp.abs(x).astype(jnp.float32)[None] / 127.0,
                       shape=shape)
    b = _block_of(shape)
    xb = x.astype(jnp.float32).reshape(shape[:-1] + (shape[-1] // b, b))
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q.reshape(shape), scale=scale[..., 0], shape=shape)


def _quantum_floor(t: QTensor) -> Array:
    """Elementwise half-quantum of a blocked QTensor (error bound of the
    stored value), broadcast back to the tensor shape."""
    if not t.shape:
        return t.scale[0] * 0.5
    b = _block_of(t.shape)
    s = jnp.repeat(t.scale, b, axis=-1).reshape(t.shape)
    return s * 0.5


def dequantize_block(t: QTensor) -> Array:
    if not t.shape:
        return (t.q.astype(jnp.float32) * t.scale[0])
    b = _block_of(t.shape)
    qb = t.q.astype(jnp.float32).reshape(
        t.shape[:-1] + (t.shape[-1] // b, b))
    return (qb * t.scale[..., None]).reshape(t.shape)


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------
def lr_schedule(cfg: OptimizerConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def init_state(cfg: OptimizerConfig, params):
    def zeros_like_state(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.int8_states:
            return quantize_block(z)
        return z
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_state, params),
        "v": jax.tree.map(zeros_like_state, params),
    }


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def _decayable(path) -> bool:
    """No weight decay on norms / biases / scalars."""
    name = str(path[-1]) if path else ""
    return not any(s in name for s in ("norm", "ln", "bias", "b_", "mu_",
                                       "w0", "u", "scale", "A_log", "D",
                                       "dt_bias"))


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * clip
        if cfg.int8_states:
            # m stored int8 directly; v stored in sqrt domain (halves the
            # dynamic range) with a quantum-floored denominator so v-entries
            # that quantize to 0 can't explode the update
            m = dequantize_block(m)
            u = dequantize_block(v)
            v = jnp.square(u)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        if cfg.int8_states:
            uq = quantize_block(jnp.sqrt(v))
            u_deq = dequantize_block(uq)
            floor = _quantum_floor(uq)
            denom = u_deq / jnp.sqrt(c2) + floor + cfg.eps
            delta = mh / denom
            m_out, v_out = quantize_block(m), uq
        else:
            delta = mh / (jnp.sqrt(v / c2) + cfg.eps)
            m_out, v_out = m, v
        if cfg.weight_decay and _decayable(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m_out, v_out

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    g_leaves = jax.tree.leaves(grads)
    m_leaves = jax.tree.leaves(state["m"],
                               is_leaf=lambda x: isinstance(x, QTensor))
    v_leaves = jax.tree.leaves(state["v"],
                               is_leaf=lambda x: isinstance(x, QTensor))
    out = [upd(path, p, g, m, v) for (path, p), g, m, v in
           zip(flat, g_leaves, m_leaves, v_leaves)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, \
        {"lr": lr, "grad_norm": gnorm}
