"""Fault-tolerant training loop.

- auto-restore from the latest atomic checkpoint (restart == preemption
  recovery);
- async checkpointing every N steps;
- deterministic counter-based data (any step regenerates identically);
- preemption hook (SIGTERM -> synchronous final checkpoint);
- elastic: restoring onto a different mesh reshards via the checkpoint
  manager (host .npy is the full logical array).
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.data.pipeline import PrefetchingLoader, SyntheticLMData, shard_batch
from repro.distributed import sharding as sh_lib
from repro.distributed.compression import init_error_state
from repro.distributed.meshctx import MeshCtx
from repro.models import model as M
from repro.train import optimizer as opt_lib
from repro.train.step import make_train_step


class Trainer:
    def __init__(self, tc: TrainConfig, ctx: MeshCtx,
                 log_fn: Callable[[str], None] = print):
        self.tc = tc
        self.cfg = tc.model
        self.ctx = ctx
        self.log = log_fn
        self.ckpt = CheckpointManager(tc.checkpoint_dir,
                                      keep=tc.keep_checkpoints)
        self.step_fn = make_train_step(tc, self.cfg, ctx)
        self._preempted = False

        key = jax.random.PRNGKey(tc.seed)
        self.params, self.param_shardings = sh_lib.sharded_init(
            key, self.cfg, ctx, lambda k: M.init(k, self.cfg))
        pspecs = sh_lib.build_param_specs(self.params, self.cfg, ctx)
        self.opt_state = jax.jit(
            lambda p: opt_lib.init_state(tc.opt, p),
        )(self.params)
        o_specs = sh_lib.opt_state_specs(self.opt_state, pspecs, ctx)
        self.opt_shardings = jax.tree.map(
            lambda s: NamedSharding(ctx.mesh, s), o_specs,
            is_leaf=lambda x: isinstance(x, P))
        self.err = {} if not tc.opt.grad_compression else \
            init_error_state(self.params)
        self.start_step = 0

        latest = self.ckpt.latest_step()
        if latest is not None:
            self._restore(latest)

        self.data = SyntheticLMData(self.cfg, tc.global_batch, tc.seq_len,
                                    seed=tc.seed)
        self.loader = PrefetchingLoader(self.data, ctx)
        self.loader.seek(self.start_step)

    # ------------------------------------------------------------------
    def _restore(self, step: int):
        state = {"params": self.params, "opt": self.opt_state}
        shardings = {"params": self.param_shardings,
                     "opt": self.opt_shardings}
        restored, extra = self.ckpt.restore(step, state, shardings)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.start_step = int(extra.get("next_step", step))
        self.log(f"[trainer] restored step {step} "
                 f"(resume at {self.start_step}) on mesh "
                 f"{dict(self.ctx.mesh.shape)}")

    def _save(self, step: int, sync: bool = False):
        state = {"params": self.params, "opt": self.opt_state}
        extra = {"next_step": step + 1}
        if sync:
            self.ckpt.save(step, state, extra)
        else:
            self.ckpt.save_async(step, state, extra)

    def install_preemption_hook(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    # ------------------------------------------------------------------
    def run(self, n_steps: int) -> Dict[str, float]:
        metrics = {}
        t0 = time.time()
        for step in range(self.start_step, self.start_step + n_steps):
            batch = self.loader.next(step)
            self.params, self.opt_state, self.err, metrics = self.step_fn(
                self.params, self.opt_state, batch, self.err)
            if step % 10 == 0 or step == self.start_step + n_steps - 1:
                loss = float(metrics["loss"])
                self.log(f"[trainer] step {step} loss {loss:.4f} "
                         f"lr {float(metrics['lr']):.2e} "
                         f"gnorm {float(metrics['grad_norm']):.3f} "
                         f"({(time.time()-t0):.1f}s)")
            if self._preempted:
                self.log(f"[trainer] preempted at step {step}: checkpointing")
                self._save(step, sync=True)
                return {k: float(v) for k, v in metrics.items()}
            if (step + 1) % self.tc.checkpoint_every == 0:
                self._save(step)
        self.ckpt.wait()
        return {k: float(v) for k, v in metrics.items()}
