"""Train step factory: grad (with microbatch accumulation + remat) ->
optional compressed pod-reduction -> AdamW update. Donates params and
optimizer state.

Two pod-axis modes:
  - "spmd" (default): the batch is sharded over (pod, data); XLA's SPMD
    partitioner inserts the cross-pod gradient all-reduce (fp32).
  - "compressed": gradients are computed per-pod under a shard_map over
    {'pod'} and reduced with the int8 + error-feedback collective
    (distributed/compression.py) — the wire-bytes win shows up directly in
    the dry-run collective-bytes roofline term.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from repro.distributed.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.distributed import compression
from repro.distributed.meshctx import MeshCtx
from repro.models import model as M
from repro.train import optimizer as opt_lib


def _grads_fn(tc: TrainConfig, cfg: ModelConfig, ctx: MeshCtx):
    def compute(params, batch):
        if tc.microbatches <= 1:
            (loss, (ce, aux)), grads = jax.value_and_grad(
                M.loss_fn, has_aux=True)(params, cfg, ctx, batch,
                                         remat=tc.remat)
            return grads, {"loss": loss, "ce": ce, "aux": aux}

        def mb(carry, mbatch):
            gacc, lacc = carry
            (loss, _), g = jax.value_and_grad(M.loss_fn, has_aux=True)(
                params, cfg, ctx, mbatch, remat=tc.remat)
            gacc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gacc, g)
            return (gacc, lacc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        split = jax.tree.map(
            lambda x: x.reshape((tc.microbatches,
                                 x.shape[0] // tc.microbatches) + x.shape[1:]),
            batch)
        (gacc, loss), _ = jax.lax.scan(mb, (g0, jnp.float32(0)), split)
        n = tc.microbatches
        grads = jax.tree.map(lambda g: g / n, gacc)
        return grads, {"loss": loss / n, "ce": loss / n,
                       "aux": jnp.float32(0)}
    return compute


def make_train_step(tc: TrainConfig, cfg: ModelConfig, ctx: MeshCtx,
                    param_shardings=None, donate=True, jit=True):
    compute = _grads_fn(tc, cfg, ctx)
    use_compress = tc.opt.grad_compression and "pod" in ctx.mesh.axis_names

    # inside the pod-manual region, the model must not mention 'pod' in
    # sharding constraints (mixed Manual/Auto specs are rejected)
    import dataclasses as _dc
    inner_ctx = _dc.replace(
        ctx, dp_axes=tuple(a for a in ctx.dp_axes if a != "pod"))
    compute_inner = _grads_fn(tc, cfg, inner_ctx)

    def train_step(params, opt_state, batch, err):
        if use_compress:
            def per_pod(p, b):
                return compute_inner(p, b)
            f = shard_map(
                per_pod, mesh=ctx.mesh,
                in_specs=(P(), P("pod")), out_specs=(P(), P()),
                axis_names={"pod"}, check_vma=False)
            grads, metrics = f(params, batch)
            reduce = compression.make_pod_grad_reducer(ctx, params, True)
            grads, err = reduce(grads, err)
            metrics = jax.tree.map(lambda x: x, metrics)
        else:
            grads, metrics = compute(params, batch)
        params, opt_state, om = opt_lib.apply_updates(
            tc.opt, params, grads, opt_state)
        metrics.update(om)
        return params, opt_state, err, metrics

    if not jit:
        return train_step
    donate_args = (0, 1, 3) if donate else ()
    return jax.jit(train_step, donate_argnums=donate_args)
