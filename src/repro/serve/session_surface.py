"""Shared serving surface for storage-backed search sessions
(DESIGN.md §7.3).

FlashSearchSession (one store) and FlashClusterSession (N shards)
promise the same ``service`` / ``submit`` / ``close`` surface; this
mixin is that surface, so the two cannot drift. Host classes implement
``search(q_ids [L, Qn], q_vals [L, Qn]) -> SearchResult`` and
``_close_resources()`` and call ``_init_serving()`` from ``__init__``.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future


class ServingSessionMixin:
    def _init_serving(self):
        self._service = None
        self._service_lock = threading.Lock()
        self._closed = False
        self._telemetry = None

    def start_telemetry(self, *, port: int = 0, host: str = "127.0.0.1",
                        slo_monitor=None, profile_dir=None):
        """Start the live telemetry plane for this session (DESIGN.md
        §8.5): an HTTP thread serving /metrics, /healthz, /slo, and
        /debug/traces off the session's ``Obs`` bundle, with the
        session's health surfaces (router replicas, ingest liveness)
        registered. One server per session; a second call returns the
        running one. Closed with the session."""
        with self._service_lock:
            if self._closed:
                raise RuntimeError(f"{type(self).__name__} is closed")
            if self._telemetry is None:
                from repro.obs.server import start_telemetry
                self._telemetry = start_telemetry(
                    self, port=port, host=host, slo_monitor=slo_monitor,
                    profile_dir=profile_dir)
            return self._telemetry

    @property
    def telemetry(self):
        """The running TelemetryServer, or None."""
        return self._telemetry

    def service(self, *, max_batch: int = 8, max_delay_ms: float = 2.0,
                admission=None, max_pending=None, tenant_qps=None,
                tenant_burst=None):
        """The session's lazily-created SearchService (DESIGN.md §7):
        one micro-batching scheduler whose flushed batches run
        ``self.search`` — each coalesced batch costs one pass over the
        backing store(s) instead of one per client. The knobs apply on
        first call; later calls return the same service. The admission
        knobs (DESIGN.md §7.3) bound the pending queue and meter
        tenants; all-None keeps the legacy admit-everything door."""
        with self._service_lock:
            if self._closed:
                raise RuntimeError(f"{type(self).__name__} is closed")
            if self._service is None:
                from repro.serve.search_service import SearchService
                self._service = SearchService(
                    self, max_batch=max_batch, max_delay_ms=max_delay_ms,
                    admission=admission, max_pending=max_pending,
                    tenant_qps=tenant_qps, tenant_burst=tenant_burst)
            return self._service

    def submit(self, query, q_vals=None, *, options=None) -> Future:
        """Non-blocking single-query search: route one query through
        the session's coalescing service and return its Future. Also the
        thread-safe entry point — the scheduler serializes scoring, so
        non-thread-safe session internals are never raced.

        Typed form ``submit(Query(...), options=QueryOptions(...))``
        resolves to a ``SearchResponse``; positional ``(q_ids, q_vals)``
        arrays remain as a deprecation shim resolving to the bare
        ``SearchResult`` row (see repro/serve/api.py)."""
        return self.service().submit(query, q_vals, options=options)

    def close(self):
        """Idempotent: only the first close tears down the session's
        resources (store/pipeline/router); later calls are no-ops, so a
        router teardown racing a user close cannot double-free."""
        with self._service_lock:
            first = not self._closed
            self._closed = True
            if self._service is not None:
                self._service.close()
                self._service = None
            telemetry, self._telemetry = self._telemetry, None
        if telemetry is not None:
            telemetry.close()
        if first:
            self._close_resources()

    def _close_resources(self):
        raise NotImplementedError

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
