"""Serving steps: prefill + decode with donated caches, plus a sampler.

``decode_32k`` / ``long_500k`` lower ``serve_step`` (one token against a
seq_len cache) per the assignment. Long-context decode shards the KV cache
sequence dim over ``data`` (flash-decoding partial-softmax combine, handled
by the SPMD partitioner) and KV heads over ``model`` when divisible.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.meshctx import MeshCtx
from repro.models import model as M


def cache_specs(cfg: ModelConfig, ctx: MeshCtx, batch_size: int):
    """PartitionSpecs for the decode cache tree.

    KV heads shard over ``model`` when divisible; otherwise the cache
    *sequence* shards over ``model`` instead (flash-decoding: the softmax
    partial max/sum reduce over the sharded seq dim becomes a psum, exact
    numerics) — llama-vision decode_32k drops from 87 GB to ~5.5 GB of
    cache per chip this way (EXPERIMENTS.md §Roofline notes)."""
    tp, dp = ctx.tp_axis, ctx.dp_axes
    ts = ctx.mesh.shape[tp]
    kv_tp = tp if cfg.n_kv_heads % ts == 0 else None
    batch_shardable = batch_size % ctx.dp_size == 0 and batch_size >= ctx.dp_size

    def kv_spec(ndim, seq_axis, batch_axis, head_axis):
        spec = [None] * ndim
        if batch_shardable:
            spec[batch_axis] = dp
        else:
            spec[seq_axis] = ctx.fsdp_axis  # SP: shard the sequence instead
        spec[head_axis] = kv_tp
        if kv_tp is None:                   # seq over model instead of heads
            spec[seq_axis] = tp if spec[seq_axis] is None \
                else (spec[seq_axis], tp)
        return P(*spec)

    if cfg.family == "ssm":
        return {
            "wkv": P(None, dp if batch_shardable else None, tp, None, None),
            "tm_x": P(None, dp if batch_shardable else None, None),
            "cm_x": P(None, dp if batch_shardable else None, None),
        }
    if cfg.family == "hybrid":
        return {
            "mamba": {
                "h": P(None, dp if batch_shardable else None, tp, None, None),
                "conv": P(None, dp if batch_shardable else None, None, None),
            },
            "k": kv_spec(5, 2, 1, 3), "v": kv_spec(5, 2, 1, 3),
        }
    spec = {"k": kv_spec(5, 2, 1, 3), "v": kv_spec(5, 2, 1, 3)}
    if cfg.family == "vlm":
        spec = {"k": kv_spec(6, 3, 2, 4), "v": kv_spec(6, 3, 2, 4),
                "img_k": kv_spec(5, 2, 1, 3), "img_v": kv_spec(5, 2, 1, 3)}
    return spec


def make_prefill(cfg: ModelConfig, ctx: MeshCtx, jit=True):
    def prefill(params, batch):
        logits, _, cache = M.apply_prefill(params, cfg, ctx, batch)
        return logits[:, -1:], cache
    return jax.jit(prefill) if jit else prefill


def make_decode_step(cfg: ModelConfig, ctx: MeshCtx, donate=True, jit=True):
    def decode(params, step_batch, cache, cur_index):
        logits, _, cache = M.apply_decode(params, cfg, ctx, step_batch,
                                          cache, cur_index)
        return logits, cache
    if not jit:
        return decode
    return jax.jit(decode, donate_argnums=(2,) if donate else ())


def sample(logits: jax.Array, key, temperature: float = 0.0) -> jax.Array:
    """logits: [B, 1, V] -> token ids [B, 1]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits[:, 0] / temperature, axis=-1)[:, None].astype(jnp.int32)


def generate(params, cfg: ModelConfig, ctx: MeshCtx, prompt: jax.Array,
             max_new: int, max_len: int, temperature: float = 0.0,
             seed: int = 0):
    """Greedy/temperature generation loop for the examples. prompt: [B, S]."""
    B, S = prompt.shape
    prefill = make_prefill(cfg, ctx)
    decode = make_decode_step(cfg, ctx)
    logits, cache = prefill(params, {"tokens": prompt})
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        full = M.init_cache(cfg, B, max_len)
        cache = jax.tree.map(
            lambda dst, src: jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * src.ndim)
            if dst.shape != src.shape else src, full, cache)
    key = jax.random.PRNGKey(seed)
    toks = [sample(logits, key, temperature)]
    out_len = S
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode(params, {"tokens": toks[-1]}, cache,
                               jnp.int32(out_len))
        out_len += 1
        toks.append(sample(logits, sub, temperature))
    return jnp.concatenate(toks, axis=1)
