"""SearchService — concurrent query serving with micro-batch coalescing
(DESIGN.md §7).

Many clients each hold one sparse query; the paper's engine wants one
L-column merged batch per corpus pass. The service bridges the two:

    client threads ── submit(q_ids, q_vals) -> Future ──┐
                                                        ▼
                                           MicroBatcher (§7.1)
                                   flush on max_batch L or max_delay_ms
                                                        ▼
                            searcher.search([L, Qn] stacked batch)
                      (PatternSearchEngine or FlashSearchSession)
                                                        ▼
                              demux row l -> client l's Future

Results are bit-identical to calling ``searcher.search`` serially per
query: stacking pads rows with the -1 sentinel that the merge path
strips, scoring is column-independent, and the engine's L-bucketing
(core/engine.py) makes every coalesced shape hit a cached program. One
scheduler thread performs all scoring, so non-thread-safe searchers
(e.g. FlashSearchSession.last_stats) are safe behind ``submit``.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import List

import numpy as np

from repro.core.engine import SearchResult
from repro.serve.batcher import BatcherStats, MicroBatcher


@dataclasses.dataclass
class _Request:
    q_ids: np.ndarray     # [Qn] int32, pad < 0
    q_vals: np.ndarray    # [Qn] float32
    future: Future


class SearchService:
    def __init__(self, searcher, *, max_batch: int = 8,
                 max_delay_ms: float = 2.0):
        """``searcher`` is anything with ``.search(q_ids [L, Qn],
        q_vals [L, Qn]) -> SearchResult`` — the resident engine or a
        flash session. ``max_batch`` is the engine's L; keep it at the
        L-bucket granularity (a power of two times the model-axis size)
        so full batches need no pad columns."""
        self.searcher = searcher
        # share the searcher's observability bundle (every tier carries
        # one, DESIGN.md §8) so queue-wait/occupancy histograms land in
        # the same registry as the scoring stages
        self.obs = getattr(searcher, "obs", None)
        self._batcher = MicroBatcher(
            self._run_batch, max_batch=max_batch, max_delay_ms=max_delay_ms,
            name="search-service", obs=self.obs)

    # ------------------------------------------------------------------
    def submit(self, q_ids: np.ndarray, q_vals: np.ndarray) -> Future:
        """Non-blocking: enqueue one query (1-D ``[Qn]`` ids/vals, pad
        < 0) and return a Future resolving to its ``SearchResult`` row
        (1-D ``[k]`` doc_ids / scores)."""
        q_ids = np.array(q_ids, np.int32, copy=True).reshape(-1)
        q_vals = np.array(q_vals, np.float32, copy=True).reshape(-1)
        if q_ids.shape != q_vals.shape:
            raise ValueError(
                f"q_ids {q_ids.shape} and q_vals {q_vals.shape} differ")
        fut: Future = Future()
        self._batcher.submit(_Request(q_ids, q_vals, fut))
        return fut

    def search(self, q_ids: np.ndarray, q_vals: np.ndarray) -> SearchResult:
        """Blocking convenience wrapper: one query through the coalescer
        (it may share its batch with concurrent submitters)."""
        return self.submit(q_ids, q_vals).result()

    @property
    def stats(self) -> BatcherStats:
        return self._batcher.stats

    @property
    def cache_stats(self):
        """The backing searcher's slab-cache lifetime counters
        (DESIGN.md §4.2) — None for the resident engine, which keeps
        its whole corpus device-resident and has no storage tier."""
        return getattr(self.searcher, "cache_stats", None)

    @property
    def last_trace(self):
        """The backing searcher's most recent sampled QueryTrace (the
        batch's trace, annotated with its clients' queue waits)."""
        return getattr(self.searcher, "last_trace", None)

    def close(self):
        self._batcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    def _run_batch(self, reqs: List[_Request]) -> None:
        """Scheduler-thread body: stack -> score -> demux. Runs entirely
        on the batcher thread, so the searcher sees serialized calls."""
        # claim every future first: a client that cancelled while queued
        # is dropped here, and claiming makes later cancel() a no-op so
        # the demux set_result below can never race an InvalidStateError
        # (which would otherwise fail the whole batch's clients)
        reqs = [r for r in reqs if r.future.set_running_or_notify_cancel()]
        if not reqs:
            return
        # per-request serving accounting (queue wait + scoring wall):
        # the serve-surface query_ms series feeds the latency SLO the
        # same way the session tiers feed store/cluster (DESIGN.md
        # §8.4). Guarded so Obs.disabled() reads no clock.
        timed = self.obs is not None and getattr(self.obs, "enabled", False)
        t0 = time.perf_counter() if timed else 0.0
        try:
            Qn = max(max(r.q_ids.size for r in reqs), 1)
            qi = np.full((len(reqs), Qn), -1, np.int32)
            qv = np.zeros((len(reqs), Qn), np.float32)
            for l, r in enumerate(reqs):
                qi[l, :r.q_ids.size] = r.q_ids
                qv[l, :r.q_vals.size] = r.q_vals
            before = getattr(self.searcher, "last_trace", None)
            res = self.searcher.search(qi, qv)
            # if the tracer sampled THIS batch's query, stitch the serve
            # stage in: the clients' queue waits become root attrs
            after = getattr(self.searcher, "last_trace", None)
            waits = self._batcher.last_queue_waits_ms
            if after is not None and after is not before and waits:
                after.root.set(
                    batch_size=len(reqs),
                    queue_wait_ms_max=round(max(waits), 3),
                    queue_wait_ms_mean=round(sum(waits) / len(waits), 3))
        except BaseException as e:
            if timed:
                reg = self.obs.registry
                # the whole batch's clients see the failure: each is one
                # bad event on the serve availability SLO
                reg.counter("queries_total", surface="serve").inc(len(reqs))
                reg.counter("query_errors_total",
                            surface="serve").inc(len(reqs))
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
            return
        if timed:
            wall_ms = (time.perf_counter() - t0) * 1e3
            reg = self.obs.registry
            h = reg.histogram("query_ms", surface="serve")
            aligned = waits if len(waits) == len(reqs) else None
            for l in range(len(reqs)):
                h.observe(wall_ms + (aligned[l] if aligned else 0.0))
            reg.counter("queries_total", surface="serve").inc(len(reqs))
        for l, r in enumerate(reqs):
            r.future.set_result(SearchResult(
                doc_ids=np.array(res.doc_ids[l]),
                scores=np.array(res.scores[l])))
