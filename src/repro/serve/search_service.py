"""SearchService — concurrent query serving with micro-batch coalescing
(DESIGN.md §7).

Many clients each hold one sparse query; the paper's engine wants one
L-column merged batch per corpus pass. The service bridges the two:

    client threads ── submit(Query, options=...) -> Future ──┐
                         (admission: quota + bounded queue)  ▼
                                           MicroBatcher (§7.1, §7.3)
                  flush on max_batch L, max_delay_ms, or EDF deadline
                                                             ▼
                            searcher.search([L, Qn] stacked batch)
                      (PatternSearchEngine or FlashSearchSession)
                                                             ▼
                              demux row l -> client l's Future

Results are bit-identical to calling ``searcher.search`` serially per
query: stacking pads rows with the -1 sentinel that the merge path
strips, scoring is column-independent, and the engine's L-bucketing
(core/engine.py) makes every coalesced shape hit a cached program. One
scheduler thread performs all scoring, so non-thread-safe searchers
(e.g. FlashSearchSession.last_stats) are safe behind ``submit``.

PR 9 adds the scheduling layer (DESIGN.md §7.3): an optional
``AdmissionController`` sheds at the door with ``OverloadError``
before anything queues; ``QueryOptions.deadline_ms`` turns into an
absolute monotonic deadline the EDF batcher flushes early for and
drops past-due requests against (``DeadlineExceeded``); per-request
``QueryOptions`` demux into a ``SearchResponse`` with that request's
``QueryStats``. Submitting plain positional arrays (no options) keeps
the legacy contract bit-for-bit: FIFO keys, no admission, a bare
``SearchResult`` out.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import List, Optional

import numpy as np

from repro.core.engine import SearchResult
from repro.serve.admission import AdmissionController
from repro.serve.api import (Query, QueryOptions, QueryStats, SearchResponse,
                             coerce_request, truncate_k)
from repro.serve.batcher import BatcherStats, MicroBatcher


@dataclasses.dataclass
class _Request:
    q_ids: np.ndarray     # [Qn] int32, pad < 0
    q_vals: np.ndarray    # [Qn] float32
    future: Future
    options: Optional[QueryOptions] = None
    deadline: Optional[float] = None    # absolute time.monotonic instant
    priority: int = 0
    queue_wait_ms: float = 0.0          # written by the batcher at flush


def _batch_options(reqs: List["_Request"], now: float
                   ) -> Optional[QueryOptions]:
    """Fold the batch's per-request options into the one QueryOptions a
    typed searcher (cluster router) runs the whole batch under:

      deadline_ms    the *tightest* remaining budget — the batch is one
                     device pass, so it must fit the most urgent member
      allow_partial  only if every member consented (a partial merge
                     degrades all L rows at once)
      hedging        any False pins it off, else any True pins it on,
                     else None (router default) — an explicit opt-out
                     wins because hedging spends a replica's work

    None when no member carries options: the searcher sees the legacy
    positional call and the whole scheduling layer stays out of the
    data path."""
    opted = [r.options for r in reqs if r.options is not None]
    if not opted:
        return None
    deadline_ms = None
    live = [r.deadline for r in reqs if r.deadline is not None]
    if live:
        deadline_ms = max(0.0, (min(live) - now) * 1e3)
    allow_partial = bool(opted) and all(
        r.options is not None and r.options.allow_partial for r in reqs)
    hedge_votes = {o.hedging for o in opted if o.hedging is not None}
    hedging = (False if False in hedge_votes
               else True if True in hedge_votes else None)
    return QueryOptions(deadline_ms=deadline_ms, allow_partial=allow_partial,
                        hedging=hedging)


class SearchService:
    def __init__(self, searcher, *, max_batch: int = 8,
                 max_delay_ms: float = 2.0,
                 admission: Optional[AdmissionController] = None,
                 max_pending: Optional[int] = None,
                 tenant_qps: Optional[float] = None,
                 tenant_burst: Optional[float] = None):
        """``searcher`` is anything with ``.search(q_ids [L, Qn],
        q_vals [L, Qn]) -> SearchResult`` — the resident engine or a
        flash session (typed surfaces additionally exposing
        ``search_typed`` get the batch's folded QueryOptions).
        ``max_batch`` is the engine's L; keep it at the L-bucket
        granularity (a power of two times the model-axis size) so full
        batches need no pad columns.

        Admission control: pass a prebuilt ``admission`` controller, or
        the ``max_pending``/``tenant_qps``/``tenant_burst`` knobs to
        build one here; all-None means admit everything (legacy)."""
        self.searcher = searcher
        # share the searcher's observability bundle (every tier carries
        # one, DESIGN.md §8) so queue-wait/occupancy histograms land in
        # the same registry as the scoring stages
        self.obs = getattr(searcher, "obs", None)
        reg = self.obs.registry if self.obs is not None else None
        if admission is None and (max_pending is not None
                                  or tenant_qps is not None):
            admission = AdmissionController(
                max_pending=max_pending, tenant_qps=tenant_qps,
                tenant_burst=tenant_burst, registry=reg)
        self.admission = admission
        self._batcher = MicroBatcher(
            self._run_batch, max_batch=max_batch, max_delay_ms=max_delay_ms,
            name="search-service", obs=self.obs)

    # ------------------------------------------------------------------
    def submit(self, query, q_vals=None, *,
               options: Optional[QueryOptions] = None) -> Future:
        """Non-blocking: enqueue one query and return a Future.

        Typed form — ``submit(Query(ids, vals), options=QueryOptions(
        deadline_ms=..., tenant=...))`` — resolves to a
        ``SearchResponse`` (results + that request's QueryStats).
        Positional 1-D arrays still work as a deprecation shim and
        resolve to the bare ``SearchResult`` row (1-D ``[k]``).

        Scheduling errors surface distinctly: admission sheds raise
        ``OverloadError`` *here, synchronously* (the request never
        queued — retry-after semantics belong to the caller); deadline
        expiry fails the *Future* with ``DeadlineExceeded`` (the
        request queued, then aged out)."""
        q, options = coerce_request(query, q_vals, options, surface="submit")
        q_ids, q_vals = q.flat()
        fut: Future = Future()
        deadline = None
        priority = 0
        if options is not None:
            if options.deadline_ms is not None:
                deadline = time.monotonic() + options.deadline_ms / 1e3
            priority = options.priority
        if self.admission is not None:
            release = self.admission.admit(
                options.tenant if options is not None else "default")
            fut.add_done_callback(lambda _f: release())
        req = _Request(q_ids, q_vals, fut, options=options,
                       deadline=deadline, priority=priority)
        try:
            self._batcher.submit(req)
        except RuntimeError:
            fut.cancel()                 # fires the admission release
            raise
        return fut

    def search(self, query, q_vals=None, *,
               options: Optional[QueryOptions] = None):
        """Blocking convenience wrapper: one query through the coalescer
        (it may share its batch with concurrent submitters)."""
        return self.submit(query, q_vals, options=options).result()

    @property
    def stats(self) -> BatcherStats:
        return self._batcher.stats

    @property
    def pending_count(self) -> int:
        return self._batcher.pending_count

    def shed_counts(self):
        """Admission sheds by reason ({} when admission is off)."""
        return self.admission.shed_counts() if self.admission else {}

    @property
    def cache_stats(self):
        """The backing searcher's slab-cache lifetime counters
        (DESIGN.md §4.2) — None for the resident engine, which keeps
        its whole corpus device-resident and has no storage tier."""
        return getattr(self.searcher, "cache_stats", None)

    @property
    def last_trace(self):
        """The backing searcher's most recent sampled QueryTrace (the
        batch's trace, annotated with its clients' queue waits)."""
        return getattr(self.searcher, "last_trace", None)

    def close(self):
        self._batcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    def _score(self, qi: np.ndarray, qv: np.ndarray,
               opts: Optional[QueryOptions]):
        """Dispatch one stacked batch to the searcher. Typed surfaces
        (``search_typed``) get the folded batch options — that's how a
        deadline reaches the cluster gather; plain ``search(qi, qv)``
        searchers (the engine, duck-typed test searchers) see the
        legacy positional call."""
        typed = getattr(self.searcher, "search_typed", None)
        if typed is not None:
            return typed(Query(qi, qv), options=opts)
        return self.searcher.search(qi, qv)

    def _run_batch(self, reqs: List[_Request]) -> None:
        """Scheduler-thread body: stack -> score -> demux. Runs entirely
        on the batcher thread, so the searcher sees serialized calls."""
        # claim every future first: a client that cancelled while queued
        # is dropped here, and claiming makes later cancel() a no-op so
        # the demux set_result below can never race an InvalidStateError
        # (which would otherwise fail the whole batch's clients)
        reqs = [r for r in reqs if r.future.set_running_or_notify_cancel()]
        if not reqs:
            return
        # per-request serving accounting (queue wait + scoring wall):
        # the serve-surface query_ms series feeds the latency SLO the
        # same way the session tiers feed store/cluster (DESIGN.md
        # §8.4). Guarded so Obs.disabled() reads no clock.
        timed = self.obs is not None and getattr(self.obs, "enabled", False)
        t0 = time.perf_counter() if timed else 0.0
        try:
            Qn = max(max(r.q_ids.size for r in reqs), 1)
            qi = np.full((len(reqs), Qn), -1, np.int32)
            qv = np.zeros((len(reqs), Qn), np.float32)
            for l, r in enumerate(reqs):
                qi[l, :r.q_ids.size] = r.q_ids
                qv[l, :r.q_vals.size] = r.q_vals
            before = getattr(self.searcher, "last_trace", None)
            res = self._score(qi, qv, _batch_options(reqs, time.monotonic()))
            # if the tracer sampled THIS batch's query, stitch the serve
            # stage in: the clients' queue waits become root attrs
            after = getattr(self.searcher, "last_trace", None)
            waits = [r.queue_wait_ms for r in reqs]
            if after is not None and after is not before and waits:
                after.root.set(
                    batch_size=len(reqs),
                    queue_wait_ms_max=round(max(waits), 3),
                    queue_wait_ms_mean=round(sum(waits) / len(waits), 3))
        except BaseException as e:
            if timed:
                reg = self.obs.registry
                # the whole batch's clients see the failure: each is one
                # bad event on the serve availability SLO
                reg.counter("queries_total", surface="serve").inc(len(reqs))
                reg.counter("query_errors_total",
                            surface="serve").inc(len(reqs))
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
            return
        if timed:
            wall_ms = (time.perf_counter() - t0) * 1e3
            reg = self.obs.registry
            h = reg.histogram("query_ms", surface="serve")
            for l in range(len(reqs)):
                h.observe(wall_ms + waits[l])
            reg.counter("queries_total", surface="serve").inc(len(reqs))
        # cluster-level scheduling outcomes for this batch (partial
        # merge? hedge won?) ride on the searcher's last_stats; demux
        # mirrors them into each opted-in request's QueryStats
        cl = getattr(self.searcher, "last_stats", None)
        partial = bool(getattr(cl, "partial", False))
        missing = tuple(getattr(cl, "shards_missing", ()) or ())
        hedged = bool(getattr(cl, "hedge_wins", 0))
        for l, r in enumerate(reqs):
            row = SearchResult(doc_ids=np.array(res.doc_ids[l]),
                               scores=np.array(res.scores[l]))
            if r.options is None:
                r.future.set_result(row)
                continue
            row = truncate_k(row, r.options.k)
            r.future.set_result(SearchResponse(row, QueryStats(
                queue_wait_ms=round(r.queue_wait_ms, 3),
                partial=partial, hedged=hedged, shards_missing=missing,
                deadline_ms=r.options.deadline_ms,
                tenant=r.options.tenant)))
