"""Micro-batch coalescing scheduler (DESIGN.md §7.1, §7.3).

The paper's headline amortization is one corpus pass per L-query merged
batch (Table 2); the serving-layer analogue is a scheduler that turns
many concurrent single-query clients into those L-column batches. A
single scheduler thread owns the pending set and flushes it when

  - it reaches ``max_batch`` requests (the engine's L), or
  - the *oldest* pending request has waited ``max_delay_ms``, or
  - the *nearest deadline* in the set would miss if the flush waited
    any longer (deadline minus the EWMA-estimated batch service time)

whichever comes first — bounded batching delay under light load, full
batches under heavy load, early flushes under deadline pressure. The
pending set is EDF-ordered (DESIGN.md §7.3): requests sort by
``(priority, deadline, submission order)`` — lower priority class
first, earliest deadline first within a class, FIFO within a tie — so
a full-batch flush takes the most urgent ``max_batch`` requests, not
the oldest. Requests without deadline or priority keep exactly the
legacy FIFO behavior (their key is ``(0, +inf, seq)``).

A request whose deadline has already passed when its batch forms is
dropped with a typed ``DeadlineExceeded`` *before* any device work —
nobody is waiting for that answer, and scoring it would delay the
requests that can still make their deadlines.

``MicroBatcher`` stays generic: it coalesces opaque request objects —
deadlines/priorities are read through injectable ``deadline_of`` /
``priority_of`` extractors (default: ``request.deadline`` as an
*absolute* ``time.monotonic`` instant, ``request.priority``) — and
hands each flushed batch (a list) to ``run_batch``, which completes the
requests' futures. A ``run_batch`` exception fails only that batch's
requests; the scheduler keeps serving.

Invariants the stress tests pin down (tests/test_serve_stress.py):
every submitted request lands in exactly one batch (or is dropped with
a typed error), batches preserve per-client submission order,
``close()`` drains pending requests, and ``submit`` after close raises
instead of dropping work silently. Flush accounting — reason counters,
``last_queue_waits_ms``, occupancy — is recorded under the batcher
lock in the same critical section that takes ownership of the batch,
so two flushes can never interleave their stats (the PR-9 accounting
fix: previously ``last_queue_waits_ms`` was written outside any lock).
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
import math
import queue
import threading
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.obs import NULL_REGISTRY, Obs
from repro.serve.api import DeadlineExceeded

_SHUTDOWN = object()

# recent batch sizes kept for inspection; bounded so a long-lived
# service doesn't grow a list forever (means come from running totals)
_OCCUPANCY_WINDOW = 4096

# EWMA smoothing for the batch service-time estimate that drives early
# deadline flushes: new = (1-ALPHA)*old + ALPHA*sample. 0.25 tracks a
# shifting service time within ~8 batches without chasing one outlier.
_SERVICE_EWMA_ALPHA = 0.25

# fixed safety margin under the deadline flush: with a cold (zero)
# service estimate the flush would otherwise land exactly ON the
# nearest deadline — and the expiry check would drop the very request
# the early flush was trying to save
_DEADLINE_GUARD_S = 2e-3


def _default_deadline_of(request: Any) -> Optional[float]:
    """Absolute ``time.monotonic`` deadline, or None (no deadline)."""
    return getattr(request, "deadline", None)


def _default_priority_of(request: Any) -> int:
    return getattr(request, "priority", 0) or 0


@dataclasses.dataclass
class BatcherStats:
    n_requests: int = 0
    n_batches: int = 0
    n_expired: int = 0                           # deadline drops
    flushes: Optional[Dict[str, int]] = None     # reason -> count
    occupancy: Optional[Deque[int]] = None       # recent batch sizes

    def __post_init__(self):
        self.flushes = self.flushes or {"full": 0, "timeout": 0,
                                        "deadline": 0, "drain": 0}
        if self.occupancy is None:
            self.occupancy = collections.deque(maxlen=_OCCUPANCY_WINDOW)

    @property
    def mean_occupancy(self) -> float:
        return self.n_requests / self.n_batches if self.n_batches else 0.0


class _Entry:
    """One pending request with its EDF heap key: lower priority class
    first, earlier deadline first within a class (None sorts last),
    submission order as the tiebreak — so legacy no-deadline requests
    coalesce in exactly the old FIFO order."""
    __slots__ = ("key", "seq", "t_sub", "request", "deadline")

    def __init__(self, seq: int, t_sub: float, request: Any,
                 priority: int, deadline: Optional[float]):
        self.key = (priority, deadline if deadline is not None else math.inf,
                    seq)
        self.seq = seq
        self.t_sub = t_sub
        self.request = request
        self.deadline = deadline

    def __lt__(self, other: "_Entry") -> bool:
        return self.key < other.key


class MicroBatcher:
    def __init__(self, run_batch: Callable[[List[Any]], None], *,
                 max_batch: int = 8, max_delay_ms: float = 2.0,
                 name: str = "micro-batcher",
                 obs: Optional[Obs] = None,
                 deadline_of: Callable[[Any], Optional[float]] = None,
                 priority_of: Callable[[Any], int] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0")
        self.max_batch = max_batch
        self.max_delay = max_delay_ms / 1e3
        self._run_batch = run_batch
        self._deadline_of = deadline_of or _default_deadline_of
        self._priority_of = priority_of or _default_priority_of
        self._q: "queue.Queue" = queue.Queue()
        self._seq = itertools.count()
        self._closed = False
        self._lock = threading.Lock()
        self._n_queued = 0               # submitted, not yet flushed/dropped
        self.stats = BatcherStats()
        # EWMA of run_batch wall time (s), the service estimate behind
        # early deadline flushes; starts at 0 (optimistic) and converges
        # within a few batches
        self._service_est_s = 0.0
        # §8 registry handles (resolved once — the scheduler loop only
        # touches pre-bound instruments); NULL when no obs is shared
        reg = obs.registry if obs is not None else NULL_REGISTRY
        self._h_wait = reg.histogram("serve_queue_wait_ms")
        self._h_occ = reg.histogram(
            "serve_batch_occupancy",
            buckets=(1., 2., 4., 8., 16., 32., 64., 128.))
        self._c_flush = {reason: reg.counter("serve_flushes", reason=reason)
                         for reason in ("full", "timeout", "deadline",
                                        "drain")}
        self._c_expired = reg.counter("serve_deadline_dropped_total")
        # queue waits (ms) of the most recent flush, written under the
        # batcher lock in the same critical section that takes the batch
        # — run_batch bodies (e.g. SearchService) may read it to
        # annotate traces
        self.last_queue_waits_ms: List[float] = []
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=name)
        self._thread.start()

    # ------------------------------------------------------------------
    def submit(self, request: Any) -> None:
        """Enqueue one request for an upcoming batch. Thread-safe. The
        request is timestamped here, so the max_delay_ms bound is
        measured from submission — time spent queued behind an
        in-flight batch counts against the delay budget. A request
        whose deadline is already past is failed here with
        ``DeadlineExceeded(where="submit")`` and never enqueued."""
        now = time.monotonic()
        deadline = self._deadline_of(request)
        if deadline is not None and now >= deadline:
            self._expire(request, now, where="submit")
            return
        entry = _Entry(next(self._seq), now, request,
                       int(self._priority_of(request)), deadline)
        with self._lock:
            if self._closed:
                raise RuntimeError("submit() on a closed MicroBatcher")
            self._n_queued += 1
            self._q.put(entry)

    @property
    def pending_count(self) -> int:
        """Requests submitted but not yet handed to ``run_batch`` (nor
        dropped as expired) — the live queue depth."""
        with self._lock:
            return self._n_queued

    def close(self, timeout: Optional[float] = None) -> None:
        """Stop accepting requests, drain what is pending, join the
        scheduler thread (by default without a timeout: returning while
        a batch is still scoring would let the caller tear down
        resources — stores, devices — out from under it). Idempotent."""
        with self._lock:
            if self._closed:
                already = True
            else:
                already = False
                self._closed = True
                self._q.put(_SHUTDOWN)
        if not already:
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    "MicroBatcher scheduler still running after "
                    f"{timeout}s; resources must not be torn down yet")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    def _expire(self, request: Any, now: float, *, where: str) -> None:
        """Drop one expired request: typed error on its future, drop
        counters. Called before any device work is spent on it."""
        deadline = self._deadline_of(request)
        late_ms = (now - deadline) * 1e3 if deadline is not None else 0.0
        with self._lock:
            self.stats.n_expired += 1
        self._c_expired.inc()
        fut = getattr(request, "future", None)
        if fut is not None and fut.set_running_or_notify_cancel():
            fut.set_exception(DeadlineExceeded(
                f"deadline passed {late_ms:.1f}ms ago "
                f"({'at submit' if where == 'submit' else 'while queued'}); "
                f"request dropped before scoring",
                late_ms=late_ms, where=where))

    def _flush(self, heap: List[_Entry], reason: str) -> None:
        """Take the ``max_batch`` most urgent pending entries (EDF
        order), drop the expired ones, run the rest. Flush accounting
        happens under the batcher lock in the same critical section
        that claims the batch, so concurrent readers of
        ``last_queue_waits_ms``/``stats`` can never see two flushes
        interleaved."""
        now = time.monotonic()
        batch: List[_Entry] = []
        while heap and len(batch) < self.max_batch:
            e = heapq.heappop(heap)
            if e.deadline is not None and now >= e.deadline:
                with self._lock:
                    self._n_queued -= 1
                self._expire(e.request, now, where="queue")
                continue
            batch.append(e)
        if not batch:
            return
        # heap pops come out in key order, so equal-key (legacy FIFO)
        # requests keep their exact arrival order within the batch
        waits = [(now - e.t_sub) * 1e3 for e in batch]
        with self._lock:
            self._n_queued -= len(batch)
            self.last_queue_waits_ms = waits
            self.stats.n_batches += 1
            self.stats.n_requests += len(batch)
            self.stats.flushes[reason] += 1
            self.stats.occupancy.append(len(batch))
        for w in waits:
            self._h_wait.observe(w)
        self._h_occ.observe(len(batch))
        self._c_flush[reason].inc()
        requests = []
        for e, w in zip(batch, waits):
            try:
                e.request.queue_wait_ms = w
            except AttributeError:
                pass                     # slot-less/opaque requests
            requests.append(e.request)
        t0 = time.monotonic()
        try:
            self._run_batch(requests)
        except BaseException as e:
            # run_batch is expected to fail its requests' futures itself;
            # this is the backstop for errors it did not attribute
            for r in requests:
                fut = getattr(r, "future", None)
                if fut is not None and not fut.done():
                    fut.set_exception(e)
        wall = time.monotonic() - t0
        self._service_est_s += _SERVICE_EWMA_ALPHA * (wall
                                                      - self._service_est_s)

    def _topup(self, heap: List[_Entry]) -> bool:
        """Non-blocking: absorb whatever is already queued. An overdue
        flush must still coalesce the backlog that accumulated behind
        the previous batch — those requests are here *now*, so batching
        them delays nobody. (The heap may exceed max_batch; the flush
        takes the most urgent max_batch and leaves the rest pending.)
        True if shutdown was hit."""
        while True:
            try:
                entry = self._q.get_nowait()
            except queue.Empty:
                return False
            if entry is _SHUTDOWN:
                return True
            heapq.heappush(heap, entry)

    def _flush_at(self, heap: List[_Entry], oldest_sub: float
                  ) -> Tuple[float, str]:
        """When the pending set must flush and why: the oldest
        request's delay budget, or earlier if the nearest deadline
        would miss given the estimated service time."""
        t_timeout = oldest_sub + self.max_delay
        nearest = min((e.deadline for e in heap if e.deadline is not None),
                      default=None)
        if nearest is not None:
            t_deadline = nearest - self._service_est_s - _DEADLINE_GUARD_S
            if t_deadline < t_timeout:
                return t_deadline, "deadline"
        return t_timeout, "timeout"

    def _loop(self) -> None:
        heap: List[_Entry] = []
        oldest_sub = 0.0
        while True:
            if not heap:
                entry = self._q.get()    # idle: block until work arrives
                if entry is _SHUTDOWN:
                    return
                heapq.heappush(heap, entry)
                # the delay budget started at submit time, not dequeue:
                # a request that already waited behind a long batch
                # flushes promptly instead of waiting a fresh max_delay
                oldest_sub = entry.t_sub
            else:
                flush_at, why = self._flush_at(heap, oldest_sub)
                timeout = flush_at - time.monotonic()
                if timeout <= 0:
                    shutdown = self._topup(heap)
                    self._flush(heap, "full" if len(heap) >= self.max_batch
                                else why)
                    oldest_sub = min((e.t_sub for e in heap),
                                     default=0.0)
                    if shutdown:
                        while heap:      # drain whatever close() raced in
                            self._flush(heap, "drain")
                        return
                    continue
                try:
                    entry = self._q.get(timeout=timeout)
                except queue.Empty:
                    self._flush(heap, why)
                    oldest_sub = min((e.t_sub for e in heap), default=0.0)
                    continue
                if entry is _SHUTDOWN:
                    while heap:
                        self._flush(heap, "drain")
                    return
                heapq.heappush(heap, entry)
                oldest_sub = min(oldest_sub, entry.t_sub)
            shutdown = False
            while len(heap) >= self.max_batch and not shutdown:
                # absorb the rest of the backlog first, so a full flush
                # takes the most urgent max_batch of EVERYTHING queued
                # (EDF), not just the earliest arrivals — and keep
                # flushing while a full batch remains (the leftovers
                # must not wait out a fresh max_delay)
                shutdown = self._topup(heap)
                self._flush(heap, "full")
            oldest_sub = min((e.t_sub for e in heap), default=oldest_sub)
            if shutdown:
                while heap:
                    self._flush(heap, "drain")
                return
