"""Micro-batch coalescing scheduler (DESIGN.md §7.1).

The paper's headline amortization is one corpus pass per L-query merged
batch (Table 2); the serving-layer analogue is a scheduler that turns
many concurrent single-query clients into those L-column batches. A
single scheduler thread owns the pending batch and flushes it when

  - it reaches ``max_batch`` requests (the engine's L), or
  - the *oldest* pending request has waited ``max_delay_ms``

whichever comes first — bounded batching delay under light load, full
batches under heavy load. ``MicroBatcher`` is generic: it coalesces
opaque request objects and hands each flushed batch (a list) to
``run_batch``, which is responsible for completing the requests'
futures. A ``run_batch`` exception fails only that batch's requests;
the scheduler keeps serving.

Invariants the stress tests pin down (tests/test_serve_stress.py):
every submitted request lands in exactly one batch, batches preserve
per-client submission order, ``close()`` drains pending requests, and
``submit`` after close raises instead of dropping work silently.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.obs import NULL_REGISTRY, Obs

_SHUTDOWN = object()

# recent batch sizes kept for inspection; bounded so a long-lived
# service doesn't grow a list forever (means come from running totals)
_OCCUPANCY_WINDOW = 4096


@dataclasses.dataclass
class BatcherStats:
    n_requests: int = 0
    n_batches: int = 0
    flushes: Optional[Dict[str, int]] = None     # reason -> count
    occupancy: Optional[Deque[int]] = None       # recent batch sizes

    def __post_init__(self):
        self.flushes = self.flushes or {"full": 0, "timeout": 0, "drain": 0}
        if self.occupancy is None:
            self.occupancy = collections.deque(maxlen=_OCCUPANCY_WINDOW)

    @property
    def mean_occupancy(self) -> float:
        return self.n_requests / self.n_batches if self.n_batches else 0.0


class MicroBatcher:
    def __init__(self, run_batch: Callable[[List[Any]], None], *,
                 max_batch: int = 8, max_delay_ms: float = 2.0,
                 name: str = "micro-batcher",
                 obs: Optional[Obs] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0")
        self.max_batch = max_batch
        self.max_delay = max_delay_ms / 1e3
        self._run_batch = run_batch
        self._q: "queue.Queue" = queue.Queue()
        self._closed = False
        self._lock = threading.Lock()
        self.stats = BatcherStats()
        # §8 registry handles (resolved once — the scheduler loop only
        # touches pre-bound instruments); NULL when no obs is shared
        reg = obs.registry if obs is not None else NULL_REGISTRY
        self._h_wait = reg.histogram("serve_queue_wait_ms")
        self._h_occ = reg.histogram(
            "serve_batch_occupancy",
            buckets=(1., 2., 4., 8., 16., 32., 64., 128.))
        self._c_flush = {reason: reg.counter("serve_flushes", reason=reason)
                        for reason in ("full", "timeout", "drain")}
        # queue waits (ms) of the most recent flush, written by the
        # scheduler thread right before run_batch — run_batch bodies
        # (e.g. SearchService) may read it to annotate traces
        self.last_queue_waits_ms: List[float] = []
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=name)
        self._thread.start()

    # ------------------------------------------------------------------
    def submit(self, request: Any) -> None:
        """Enqueue one request for the next batch. Thread-safe. The
        request is timestamped here, so the max_delay_ms bound is
        measured from submission — time spent queued behind an
        in-flight batch counts against the delay budget."""
        with self._lock:
            if self._closed:
                raise RuntimeError("submit() on a closed MicroBatcher")
            self._q.put((request, time.monotonic()))

    def close(self, timeout: Optional[float] = None) -> None:
        """Stop accepting requests, drain what is pending, join the
        scheduler thread (by default without a timeout: returning while
        a batch is still scoring would let the caller tear down
        resources — stores, devices — out from under it). Idempotent."""
        with self._lock:
            if self._closed:
                already = True
            else:
                already = False
                self._closed = True
                self._q.put((_SHUTDOWN, 0.0))
        if not already:
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    "MicroBatcher scheduler still running after "
                    f"{timeout}s; resources must not be torn down yet")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    def _flush(self, pending: List[Tuple[Any, float]], reason: str) -> None:
        """``pending`` holds (request, submit monotonic-time) pairs, so
        the flush can attribute each request's full queue wait — from
        submit to the moment its batch starts scoring."""
        now = time.monotonic()
        waits = [(now - t_sub) * 1e3 for _, t_sub in pending]
        self.last_queue_waits_ms = waits
        for w in waits:
            self._h_wait.observe(w)
        self._h_occ.observe(len(pending))
        self._c_flush[reason].inc()
        self.stats.n_batches += 1
        self.stats.n_requests += len(pending)
        self.stats.flushes[reason] += 1
        self.stats.occupancy.append(len(pending))
        requests = [item for item, _ in pending]
        try:
            self._run_batch(requests)
        except BaseException as e:
            # run_batch is expected to fail its requests' futures itself;
            # this is the backstop for errors it did not attribute
            for r in requests:
                fut = getattr(r, "future", None)
                if fut is not None and not fut.done():
                    fut.set_exception(e)

    def _topup(self, pending: List[Tuple[Any, float]]) -> bool:
        """Non-blocking: absorb whatever is already queued, up to
        max_batch. An overdue flush must still coalesce the backlog that
        accumulated behind the previous batch — those requests are here
        *now*, so batching them delays nobody. True if shutdown was hit."""
        while len(pending) < self.max_batch:
            try:
                item, t_sub = self._q.get_nowait()
            except queue.Empty:
                return False
            if item is _SHUTDOWN:
                return True
            pending.append((item, t_sub))
        return False

    def _loop(self) -> None:
        pending: List[Tuple[Any, float]] = []
        deadline = 0.0
        while True:
            if not pending:
                item, t_sub = self._q.get()  # idle: block until work arrives
                if item is _SHUTDOWN:
                    return
                pending.append((item, t_sub))
                # the delay budget started at submit time, not dequeue:
                # a request that already waited behind a long batch
                # flushes promptly instead of waiting a fresh max_delay
                deadline = t_sub + self.max_delay
            else:
                timeout = deadline - time.monotonic()
                if timeout <= 0:
                    shutdown = self._topup(pending)
                    self._flush(pending, "full"
                                if len(pending) >= self.max_batch
                                else "timeout")
                    pending = []
                    if shutdown:
                        return
                    continue
                try:
                    item, t_sub = self._q.get(timeout=timeout)
                except queue.Empty:
                    self._flush(pending, "timeout")
                    pending = []
                    continue
                if item is _SHUTDOWN:
                    self._flush(pending, "drain")
                    return
                pending.append((item, t_sub))
            if len(pending) >= self.max_batch:
                self._flush(pending, "full")
                pending = []
