"""Typed request/response surface for the serving tier (DESIGN.md §7.3).

Every search surface in this tree historically took two positional
arrays — ``search(q_ids, q_vals)`` — which left nowhere to put the
scheduling contract the ROADMAP's tail-latency item needs: deadlines,
priorities, tenants, partial-result consent, hedging. This module is
that contract:

    ``Query``          the sparse pattern itself (ids/vals, 1-D single
                       or 2-D batch), validated once at the boundary
    ``QueryOptions``   how the request may be scheduled: deadline_ms,
                       priority, tenant, k, allow_partial, hedging
    ``QueryStats``     what scheduling did to it: queue wait, partial
                       flag, hedged flag, the shards that missed
    ``SearchResponse`` results + QueryStats; quacks like SearchResult
                       (``.doc_ids`` / ``.scores``) so result-shape
                       consumers never care which they got

plus the typed scheduling errors: ``OverloadError`` (admission shed —
the request never entered the queue) and ``DeadlineExceeded`` (the
request expired before or inside the queue; no device work was spent).

Migration contract: every surface (engine / session / cluster /
service) accepts ``search(Query, options=...)``; the positional
``search(q_ids, q_vals)`` form still works but is a deprecation shim —
``coerce_request`` below emits the ``DeprecationWarning`` exactly once
per call site. Surfaces return a ``SearchResponse`` when the caller
passed a ``QueryOptions`` (they opted into the new contract) and the
bare ``SearchResult`` otherwise, so legacy callers see byte-identical
behavior.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional, Tuple

import numpy as np


class OverloadError(RuntimeError):
    """Admission control shed this request (token-bucket quota or the
    bounded pending queue) — it never entered the scheduler, no device
    work was spent, and the caller should back off. Typed so callers
    can distinguish load shedding from real failures; carries the
    decision context."""

    def __init__(self, msg: str, *, tenant: str = "default",
                 reason: str = "queue_full", depth: int = 0,
                 limit: Optional[int] = None):
        super().__init__(msg)
        self.tenant = tenant
        self.reason = reason        # "queue_full" | "quota"
        self.depth = depth
        self.limit = limit


class DeadlineExceeded(TimeoutError):
    """The request's deadline passed before its batch started scoring
    (at submit, or while queued). The scheduler drops expired requests
    instead of spending device work on answers nobody is waiting for."""

    def __init__(self, msg: str, *, deadline_ms: Optional[float] = None,
                 late_ms: float = 0.0, where: str = "queue"):
        super().__init__(msg)
        self.deadline_ms = deadline_ms
        self.late_ms = late_ms
        self.where = where          # "submit" | "queue"


@dataclasses.dataclass
class Query:
    """One sparse pattern query (1-D ``[Qn]``) or a stacked batch
    (2-D ``[L, Qn]``); ids int32 with pad < 0, vals float32. Arrays are
    copied and validated here so downstream stages can trust them."""
    ids: np.ndarray
    vals: np.ndarray

    def __post_init__(self):
        self.ids = np.array(self.ids, np.int32, copy=True)
        self.vals = np.array(self.vals, np.float32, copy=True)
        if self.ids.shape != self.vals.shape:
            raise ValueError(
                f"query ids {self.ids.shape} and vals {self.vals.shape} "
                f"differ")
        if self.ids.ndim not in (1, 2):
            raise ValueError(
                f"query must be 1-D (single) or 2-D (batch), got "
                f"{self.ids.ndim}-D")

    @property
    def is_single(self) -> bool:
        return self.ids.ndim == 1

    @property
    def n_rows(self) -> int:
        return 1 if self.is_single else int(self.ids.shape[0])

    def rows(self) -> Tuple[np.ndarray, np.ndarray]:
        """The 2-D ``[L, Qn]`` view every scoring surface consumes (a
        single query becomes its own one-row batch)."""
        if self.is_single:
            return self.ids[None], self.vals[None]
        return self.ids, self.vals

    def flat(self) -> Tuple[np.ndarray, np.ndarray]:
        """The 1-D view the coalescing service consumes; a ``[1, Qn]``
        batch flattens, a taller batch is rejected (one Future resolves
        one query row)."""
        if self.is_single:
            return self.ids, self.vals
        if self.ids.shape[0] == 1:
            return self.ids[0], self.vals[0]
        raise ValueError(
            f"submit() takes one query per Future; got a batch of "
            f"{self.ids.shape[0]} rows (call search() for batches)")


@dataclasses.dataclass(frozen=True)
class QueryOptions:
    """How a request may be scheduled (DESIGN.md §7.3). All knobs
    default to the legacy FIFO/unbounded behavior, so
    ``QueryOptions()`` schedules exactly like no options at all.

    deadline_ms   latency budget from submission; the batcher flushes
                  early rather than miss it and drops the request with
                  ``DeadlineExceeded`` once it expires; the cluster
                  gather stops waiting on stragglers at the budget
                  (None = no deadline)
    priority      scheduling class; *lower runs first* (0 default).
                  Within a class, earliest deadline first, then
                  submission order — no-deadline requests sort after
                  deadlined ones of the same class
    tenant        admission-control accounting key (per-tenant
                  token-bucket quotas; DESIGN.md §7.3)
    k             per-query top-k override, truncating the configured
                  ``cfg.top_k`` rows (must be <= it)
    allow_partial consent to a best-effort gather: a deadline-bound
                  scatter may return merged top-k from the shards that
                  responded, flagged ``partial=True`` with the missing
                  shard list in stats. Without consent the gather
                  blocks for every shard (legacy behavior)
    hedging       None = the router's configured policy; True forces
                  straggler hedging on (default policy if the router
                  has none), False disables it for this request
    mode          scoring tier override (DESIGN.md §15): "exact" scans
                  every surviving slab, "approx" takes the per-segment
                  posting-candidate + exact-re-rank path, "auto" picks
                  by corpus size. None = the session's configured
                  default (which itself defaults to exact, so legacy
                  callers can never drift into the approximate tier)
    recall_target approx-tier recall@k goal in (0, 1]; mapped to a
                  candidate-pool multiplier when ``candidates`` is not
                  given explicitly (closer to 1.0 = wider pool)
    candidates    explicit per-segment top-C candidate-pool size for
                  the approx tier (wins over recall_target)
    """
    deadline_ms: Optional[float] = None
    priority: int = 0
    tenant: str = "default"
    k: Optional[int] = None
    allow_partial: bool = False
    hedging: Optional[bool] = None
    mode: Optional[str] = None
    recall_target: Optional[float] = None
    candidates: Optional[int] = None

    def __post_init__(self):
        if self.k is not None and self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if not self.tenant:
            raise ValueError("tenant must be a non-empty string")
        if self.priority != int(self.priority):
            raise ValueError(f"priority must be an int, got {self.priority}")
        if self.mode is not None and self.mode not in (
                "exact", "approx", "auto"):
            raise ValueError(
                f"mode must be 'exact', 'approx' or 'auto', got "
                f"{self.mode!r}")
        if self.recall_target is not None and not (
                0.0 < self.recall_target <= 1.0):
            raise ValueError(
                f"recall_target must be in (0, 1], got {self.recall_target}")
        if self.candidates is not None and self.candidates < 1:
            raise ValueError(
                f"candidates must be >= 1, got {self.candidates}")


@dataclasses.dataclass
class QueryStats:
    """What scheduling did to one request (per-query, rides on the
    ``SearchResponse``)."""
    queue_wait_ms: float = 0.0       # submit -> batch start
    partial: bool = False            # gather returned without every shard
    hedged: bool = False             # a hedge attempt won this query
    shards_missing: Tuple[int, ...] = ()   # shards absent from the merge
    deadline_ms: Optional[float] = None    # the budget the request ran under
    tenant: str = "default"


@dataclasses.dataclass
class SearchResponse:
    """Results plus the per-query scheduling stats. Quacks like
    ``SearchResult`` (``.doc_ids`` / ``.scores``) so result consumers
    are agnostic to which they received."""
    results: Any                     # SearchResult (or row thereof)
    stats: QueryStats

    @property
    def doc_ids(self):
        return self.results.doc_ids

    @property
    def scores(self):
        return self.results.scores


def coerce_request(query, q_vals=None, options: Optional[QueryOptions] = None,
                   *, surface: str = "search"
                   ) -> Tuple[Query, Optional[QueryOptions]]:
    """Boundary normalizer every public search surface shares: a typed
    ``Query`` passes through; the positional ``(q_ids, q_vals)`` array
    form still works but emits a ``DeprecationWarning`` (the shim the
    migration keeps until callers move — exercised explicitly once in
    tests/test_api_query.py)."""
    if isinstance(query, Query):
        if q_vals is not None:
            raise TypeError(
                f"{surface}: pass either Query or (q_ids, q_vals), not both")
        return query, options
    if q_vals is None:
        raise TypeError(
            f"{surface}: positional form needs both q_ids and q_vals "
            f"(or pass a repro.serve.api.Query)")
    warnings.warn(
        f"{surface}(q_ids, q_vals) positional arrays are deprecated; "
        f"pass repro.serve.api.Query(ids, vals) (and QueryOptions for "
        f"deadlines/priorities/partial-gather consent)",
        DeprecationWarning, stacklevel=3)
    return Query(query, q_vals), options


def truncate_k(result, k: Optional[int]):
    """Per-query top-k override: keep the first ``k`` of the engine's
    ``top_k`` columns (rows are score-descending, so the prefix IS the
    top-k). No-op when k is None or not smaller."""
    if k is None:
        return result
    ids, scores = result.doc_ids, result.scores
    if ids.shape[-1] <= k:
        return result
    return type(result)(ids[..., :k], scores[..., :k])
