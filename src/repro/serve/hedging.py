"""Replica hedging: fire a straggling shard query at the next replica
and take whichever answers first (DESIGN.md §7.3).

A scatter/gather is as slow as its slowest shard, and shard latency in
this tree has a long tail (cold slab cache, compactor stalls, a busy
device). Hedging converts that tail into a second chance: when a
replica attempt has run longer than the *straggler threshold*, the same
query is launched at the next in-rotation replica and the first result
wins. Replicas are byte-wise independent copies of the same shard
(cluster/store.py), so either answer is correct and bit-identical —
hedging changes *when* the result arrives, never *what* it is.

The threshold is seeded from live telemetry, closing the PR-8 loop:
``HedgePolicy.hedge_after_ms`` reads the rolling-window twin of the
router's ``cluster_shard_ms`` histogram and takes a configurable
percentile of the *recent* shard latency distribution (default p95 —
"slower than 19 of 20 recent shard calls ⇒ probably stuck, not slow").
With no window yet populated (cold start, windows disabled) it falls
back to a fixed ``fallback_ms``.

The mechanics live in ``run_hedged``: a primary attempt plus a timer
that launches the hedge only if the primary is still running at the
threshold. First completion wins; the loser is cancelled best-effort
(Python can't interrupt a running scoring call, so a started loser
runs to completion on its executor and is discarded — callers that
care about session reuse must make attempts self-serializing, which
the router's per-replica locks do). A hedge *winning* is recorded
distinctly from a hedge merely *firing*; neither marks the slow
replica down — slow is not failed, and health marking stays the
fail-over path's job.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import FIRST_COMPLETED, Future, wait
from typing import Callable, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """When to fire the second attempt.

    percentile    straggler threshold as a quantile of the recent
                  (rolling-window) ``cluster_shard_ms`` distribution
    min_ms        floor under the percentile — never hedge faster than
                  this, so a uniformly-fast window can't make every
                  query fire two attempts
    fallback_ms   threshold when no window data exists yet (cold start,
                  or the registry has windows disabled)
    """
    percentile: float = 0.95
    min_ms: float = 1.0
    fallback_ms: float = 50.0

    def __post_init__(self):
        if not 0.0 < self.percentile < 1.0:
            raise ValueError(
                f"percentile must be in (0, 1), got {self.percentile}")
        if self.min_ms < 0 or self.fallback_ms <= 0:
            raise ValueError("min_ms must be >= 0 and fallback_ms > 0")

    def hedge_after_ms(self, registry) -> float:
        """Current straggler threshold, seeded from the rolling-window
        shard-latency histogram when it has data."""
        win = registry.windowed("cluster_shard_ms") \
            if registry is not None else None
        if win is not None:
            p = win.percentile(self.percentile)
            if p > 0.0:
                return max(self.min_ms, p)
        return max(self.min_ms, self.fallback_ms)


@dataclasses.dataclass
class HedgeOutcome:
    """What one hedged call did. ``winner_index`` indexes ``fns``;
    ``hedge_won`` is True only when a timer-fired attempt (index >= 1)
    delivered the result — a hedge that fired but lost is visible as
    ``hedges_fired > 0, hedge_won=False``."""
    winner_index: int
    result: object
    hedges_fired: int = 0
    hedge_won: bool = False
    errors: List[Optional[BaseException]] = dataclasses.field(
        default_factory=list)


class SpawnExecutor:
    """Executor-shaped launcher that gives every attempt its own daemon
    thread. Hedge attempts must never queue behind other attempts: on a
    bounded pool an abandoned loser still sleeping inside a straggler
    holds a worker, and the next query's hedge then waits *for the very
    straggler it was meant to outrun* — under sustained traffic the
    timer fires but the winning attempt can't start inside the deadline
    budget. One thread per submit keeps the timer honest; the live
    thread count is bounded by in-flight attempts (losers exit when
    their per-replica-serialized call returns)."""

    def __init__(self):
        self._threads: set = set()
        self._lock = threading.Lock()

    def submit(self, fn: Callable[[], object]) -> Future:
        fut: Future = Future()

        def run():
            try:
                if not fut.set_running_or_notify_cancel():
                    return
                try:
                    fut.set_result(fn())
                except BaseException as e:
                    fut.set_exception(e)
            finally:
                with self._lock:
                    self._threads.discard(threading.current_thread())

        t = threading.Thread(target=run, daemon=True, name="hedge-attempt")
        with self._lock:
            self._threads.add(t)
        t.start()
        return fut

    def shutdown(self, wait: bool = True) -> None:
        """Join every in-flight attempt (abandoned losers included) so
        callers can close replica sessions without a late attempt
        touching a closed session."""
        if not wait:
            return
        while True:
            with self._lock:
                t = next(iter(self._threads), None)
            if t is None:
                return
            t.join()


def run_hedged(fns: Sequence[Callable[[], object]], executor, *,
               hedge_after_s: float,
               on_hedge: Optional[Callable[[int], None]] = None
               ) -> HedgeOutcome:
    """Run ``fns[0]`` on ``executor``; if it hasn't completed after
    ``hedge_after_s``, launch ``fns[1]`` (then ``fns[2]`` after another
    interval, ...) and return the first *successful* completion.

    Called from a router pool thread with attempts running on a
    *separate* executor — launching hedges back onto the caller's own
    pool would self-deadlock when every worker is blocked here waiting,
    and any *bounded* pool starves under sustained straggling (see
    ``SpawnExecutor``). An attempt that raises doesn't win: its error is
    recorded
    and the wait continues (launching the next attempt immediately if
    none is in flight — an error is a stronger hedge signal than a
    straggler). Only when every attempt has failed does the primary's
    error re-raise; per-attempt errors ride on the outcome for the
    caller's structured error context.

    Losing attempts are cancelled best-effort; a loser already running
    is discarded on completion (see module docstring for the session-
    serialization contract this implies).
    """
    if not fns:
        raise ValueError("run_hedged needs at least one attempt")
    errors: List[Optional[BaseException]] = [None] * len(fns)
    futs: List[Future] = [executor.submit(fns[0])]
    pending = {futs[0]}
    launched = 1
    hedges_fired = 0
    while True:
        # wait only on in-flight attempts (a completed-failed future
        # would make a whole-list FIRST_COMPLETED return immediately
        # and busy-spin); no timeout once every replica is launched
        timeout = hedge_after_s if launched < len(fns) else None
        done, pending = wait(pending, timeout=timeout,
                             return_when=FIRST_COMPLETED)
        for f in done:
            idx = futs.index(f)
            err = f.exception() if not f.cancelled() else None
            if err is None and not f.cancelled():
                for other in futs:
                    if other is not f:
                        other.cancel()
                return HedgeOutcome(
                    winner_index=idx, result=f.result(),
                    hedges_fired=hedges_fired, hedge_won=idx >= 1,
                    errors=errors)
            errors[idx] = err
        if launched < len(fns) and (not done or not pending):
            # timer expired with attempts still running, or everything
            # in flight just failed (an error is a stronger hedge
            # signal than a straggler): fire the next replica
            if on_hedge is not None:
                on_hedge(launched)
            nxt = executor.submit(fns[launched])
            futs.append(nxt)
            pending.add(nxt)
            launched += 1
            hedges_fired += 1
        elif not pending:
            # every attempt launched and failed
            raise next(e for e in errors if e is not None)


class CancelFlag:
    """Cooperative cancellation token for losing hedge attempts: the
    winner's thread sets it, a loser checks it at its next safe point
    (before touching its replica session) and bails without device
    work. Cheap, race-free (Event), and purely advisory."""

    def __init__(self):
        self._ev = threading.Event()

    def set(self) -> None:
        self._ev.set()

    def __bool__(self) -> bool:
        return self._ev.is_set()
