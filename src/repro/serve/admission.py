"""Admission control: per-tenant token-bucket quotas + a bounded
pending queue that sheds instead of hanging (DESIGN.md §7.3).

The paper's pitch is *bounded, predictable* throughput; an unbounded
FIFO queue makes every latency percentile a function of the backlog,
so overload must be refused at the door, not absorbed. The controller
makes two checks under one lock, both O(1):

  1. **pending bound** — at most ``max_pending`` admitted requests may
     be outstanding (queued or scoring). Beyond it, ``admit`` raises a
     typed :class:`~repro.serve.api.OverloadError` (``reason=
     "queue_full"``) — the caller gets an immediate, attributable shed,
     never a hang, and the batcher's EDF queue stays short enough that
     deadlines remain meetable.
  2. **tenant quota** — a token bucket per tenant (``rate`` tokens/s,
     ``burst`` capacity, lazily refilled from the injected clock — the
     same monotonic clock the rolling-window instruments use, so quota
     refill and window rotation age together in tests). A dry bucket
     sheds with ``reason="quota"`` so one hot tenant cannot starve the
     rest (the skewed/repetitive workloads of PAPERS.md "Leveraging
     Recurrent Patterns" are exactly the risk).

Shed decisions feed the shared registry: ``serve_shed_total{reason,
tenant}`` counters and the live ``serve_queue_depth`` gauge, so the
PR-8 telemetry plane sees overload as a first-class signal.

``admit`` returns a zero-arg ``release`` callable; the service attaches
it as the Future's done-callback, so every admitted request — served,
failed, expired, or cancelled — gives its slot back exactly once.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional, Tuple

from repro.obs import NULL_REGISTRY
from repro.serve.api import OverloadError


class TokenBucket:
    """Classic token bucket, lock-free (callers serialize): ``rate``
    tokens/s refill up to ``burst``; ``try_take`` refills lazily from
    the injected clock read, so an idle bucket costs nothing."""

    def __init__(self, rate: float, burst: Optional[float] = None):
        if rate <= 0:
            raise ValueError(f"rate must be > 0 tokens/s, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst) if burst is not None else max(1.0, rate)
        if self.burst < 1.0:
            raise ValueError(f"burst must be >= 1 token, got {self.burst}")
        self.tokens = self.burst
        self._last: Optional[float] = None

    def try_take(self, now: float) -> bool:
        if self._last is not None:
            self.tokens = min(self.burst,
                              self.tokens + (now - self._last) * self.rate)
        self._last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


class AdmissionController:
    """Thread-safe front door for a SearchService (DESIGN.md §7.3).

    ``max_pending`` bounds admitted-but-unfinished requests (None =
    unbounded); ``tenant_qps``/``tenant_burst`` set the default
    per-tenant quota applied to any tenant not named in ``quotas``
    (None = unmetered); ``quotas`` maps tenant -> (qps, burst) for
    explicit overrides. With every knob at None the controller admits
    everything — constructing one is never a behavior change by itself.
    """

    def __init__(self, *, max_pending: Optional[int] = None,
                 tenant_qps: Optional[float] = None,
                 tenant_burst: Optional[float] = None,
                 quotas: Optional[Dict[str, Tuple[float, float]]] = None,
                 registry=None, clock=time.monotonic):
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_pending = max_pending
        self._default_quota = (tenant_qps, tenant_burst)
        self._quota_spec = dict(quotas or {})
        self._buckets: Dict[str, Optional[TokenBucket]] = {}
        self._clock = clock
        self._lock = threading.Lock()
        self._depth = 0
        # local shed tally (the registry may be NULL — its counters
        # no-op — but shed_counts() must still report truthfully)
        self._sheds = {"queue_full": 0, "quota": 0}
        reg = registry if registry is not None else NULL_REGISTRY
        self._g_depth = reg.gauge("serve_queue_depth")
        self._c_shed = {
            reason: reg.counter("serve_shed_total", reason=reason)
            for reason in ("queue_full", "quota")}
        self._c_admit = reg.counter("serve_admitted_total")

    # ------------------------------------------------------------------
    def _bucket(self, tenant: str) -> Optional[TokenBucket]:
        """Lazily built per-tenant bucket; caller holds the lock. None =
        this tenant is unmetered (no default and no explicit quota)."""
        if tenant not in self._buckets:
            if tenant in self._quota_spec:
                qps, burst = self._quota_spec[tenant]
                self._buckets[tenant] = TokenBucket(qps, burst)
            elif self._default_quota[0] is not None:
                self._buckets[tenant] = TokenBucket(*self._default_quota)
            else:
                self._buckets[tenant] = None
        return self._buckets[tenant]

    @property
    def depth(self) -> int:
        with self._lock:
            return self._depth

    def admit(self, tenant: str = "default") -> Callable[[], None]:
        """Admit one request or shed it with a typed ``OverloadError``
        (synchronously — shedding never blocks, never hangs). Returns
        the release callable the caller must invoke exactly once when
        the request leaves the system (attach it as the Future's
        done-callback so served/failed/expired/cancelled all count)."""
        now = self._clock()
        with self._lock:
            if (self.max_pending is not None
                    and self._depth >= self.max_pending):
                self._sheds["queue_full"] += 1
                self._c_shed["queue_full"].inc()
                raise OverloadError(
                    f"pending queue full ({self._depth}/"
                    f"{self.max_pending}); request shed",
                    tenant=tenant, reason="queue_full",
                    depth=self._depth, limit=self.max_pending)
            bucket = self._bucket(tenant)
            if bucket is not None and not bucket.try_take(now):
                self._sheds["quota"] += 1
                self._c_shed["quota"].inc()
                raise OverloadError(
                    f"tenant {tenant!r} over quota "
                    f"({bucket.rate:g}/s, burst {bucket.burst:g}); "
                    f"request shed",
                    tenant=tenant, reason="quota",
                    depth=self._depth, limit=self.max_pending)
            self._depth += 1
            depth = self._depth
        self._c_admit.inc()
        self._g_depth.set(depth)
        released = threading.Event()     # exactly-once guard

        def release() -> None:
            if released.is_set():
                return
            released.set()
            with self._lock:
                self._depth -= 1
                depth = self._depth
            self._g_depth.set(depth)

        return release

    def shed_counts(self) -> Dict[str, int]:
        """Lifetime sheds by reason (a local tally, so it is truthful
        with or without a live registry)."""
        with self._lock:
            return dict(self._sheds)
