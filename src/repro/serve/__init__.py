"""Serving layer: LM decode steps (step.py) and the sparse-search
micro-batching service (DESIGN.md §7)."""
from repro.serve.batcher import BatcherStats, MicroBatcher
from repro.serve.search_service import SearchService

__all__ = ["BatcherStats", "MicroBatcher", "SearchService"]
