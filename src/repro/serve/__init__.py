"""Serving layer: LM decode steps (step.py), the sparse-search
micro-batching service (DESIGN.md §7), and the scheduling plane —
typed Query/QueryOptions API, admission control, EDF deadline
batching, replica hedging (DESIGN.md §7.3)."""
from repro.serve.admission import AdmissionController, TokenBucket
from repro.serve.api import (DeadlineExceeded, OverloadError, Query,
                             QueryOptions, QueryStats, SearchResponse)
from repro.serve.batcher import BatcherStats, MicroBatcher
from repro.serve.hedging import (HedgeOutcome, HedgePolicy, SpawnExecutor,
                                 run_hedged)
from repro.serve.search_service import SearchService

__all__ = [
    "AdmissionController", "BatcherStats", "DeadlineExceeded",
    "HedgeOutcome", "HedgePolicy", "MicroBatcher", "OverloadError",
    "Query", "QueryOptions", "QueryStats", "SearchResponse",
    "SearchService", "SpawnExecutor", "TokenBucket", "run_hedged",
]
