"""Mamba-2 (SSD) block [arXiv:2405.21060] — used by the Zamba2 hybrid.

State-space recurrence per head (scalar decay a_t per head):
    h_t = a_t h_{t-1} + (dt_t x_t) B_t^T,    y_t = C_t h_t + D x_t
Chunked SSD form: within a chunk the decay couples only (t, s) scalars per
head, so the intra-chunk term is a pure matmul (MXU-friendly); the O(hd*N)
state crosses chunks via lax.scan. Decode is the O(1) recurrence.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Array = jax.Array
CONV_K = 4  # depthwise causal conv kernel size


def layer_init(key, cfg: ModelConfig, n: int):
    d = cfg.d_model
    d_in = cfg.d_inner
    N = cfg.ssm_state
    nh = d_in // cfg.ssm_headdim
    conv_dim = d_in + 2 * N
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((n, d), jnp.float32),
        "in_proj": L.stacked_dense_init(ks[0], n, d, 2 * d_in + 2 * N + nh,
                                        dtype),
        "conv_w": (jax.random.normal(ks[1], (n, CONV_K, conv_dim), jnp.float32)
                   * (1.0 / math.sqrt(CONV_K))).astype(jnp.float32),
        "A_log": jnp.zeros((n, nh), jnp.float32),     # a = exp(-exp(A_log)*dt)
        "D": jnp.ones((n, nh), jnp.float32),
        "dt_bias": jnp.zeros((n, nh), jnp.float32),
        "gate_norm": jnp.ones((n, d_in), jnp.float32),
        "out_proj": L.stacked_dense_init(ks[2], n, d_in, d, dtype,
                                         scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def init_state(cfg: ModelConfig, n: int, batch_size: int, dtype=jnp.float32):
    d_in = cfg.d_inner
    N = cfg.ssm_state
    nh = d_in // cfg.ssm_headdim
    conv_dim = d_in + 2 * N
    return {
        "h": jnp.zeros((n, batch_size, nh, cfg.ssm_headdim, N), jnp.float32),
        "conv": jnp.zeros((n, batch_size, CONV_K - 1, conv_dim), dtype),
    }


def _causal_conv(x, w, conv_state, single: bool):
    """Depthwise causal conv. x: [B,T,C]; w: [K,C]; conv_state: [B,K-1,C]."""
    ctx = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    new_state = ctx[:, -(CONV_K - 1):, :]
    if single:
        out = jnp.einsum("bkc,kc->bc", ctx, w.astype(x.dtype))[:, None, :]
    else:
        T = x.shape[1]
        # gather K shifted views: out_t = sum_k w_k * ctx[t + k]
        views = jnp.stack([ctx[:, i:i + T, :] for i in range(CONV_K)], axis=2)
        out = jnp.einsum("btkc,kc->btc", views, w.astype(x.dtype))
    return jax.nn.silu(out), new_state


def _ssd_chunked(x, dt, B_, C_, a_log, h0, chunk: int):
    """x: [B,T,nh,hd]; dt: [B,T,nh]; B_,C_: [B,T,N]; a_log: [B,T,nh] (log a);
    h0: [B,nh,hd,N]. Returns (y [B,T,nh,hd], h)."""
    Bb, T, nh, hd = x.shape
    N = B_.shape[-1]
    Cn = min(chunk, T)
    assert T % Cn == 0
    n = T // Cn

    def resh(t):
        return jnp.moveaxis(t.reshape((Bb, n, Cn) + t.shape[2:]), 1, 0)

    xs, dts, Bs, Cs, als = resh(x), resh(dt), resh(B_), resh(C_), resh(a_log)

    def body(h, inp):
        xc, dtc, Bc, Cc, alc = inp
        xc = xc.astype(jnp.float32)
        dtc = dtc.astype(jnp.float32)
        Bc = Bc.astype(jnp.float32)
        Cc = Cc.astype(jnp.float32)
        cum = jnp.cumsum(alc, axis=1)                   # [B,C,nh] inclusive
        # intra: score[t,s] = (C_t . B_s) exp(cum_t - cum_s) dt_s, s<=t
        G = jnp.einsum("btn,bsn->bts", Cc, Bc)          # [B,C,C]
        Dm = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # [B,t,s,nh]
        tri = jnp.tril(jnp.ones((Cn, Cn), bool))
        Dm = jnp.where(tri[None, :, :, None], Dm, 0.0)
        scores = G[:, :, :, None] * Dm * dtc[:, None, :, :]
        y = jnp.einsum("btsh,bshe->bthe", scores, xc)
        # inter: y_t += exp(cum_t) C_t . h_in
        decay_in = jnp.exp(cum)                          # [B,C,nh]
        y = y + jnp.einsum("btn,bhen,bth->bthe", Cc, h, decay_in)
        # state update: h = exp(cum_last) h + sum_s exp(cum_last-cum_s) dt_s x_s B_s^T
        cum_last = cum[:, -1:, :]
        w_s = jnp.exp(cum_last - cum) * dtc              # [B,C,nh]
        h = jnp.exp(cum_last[:, 0])[:, :, None, None] * h + jnp.einsum(
            "bsh,bshe,bsn->bhen", w_s, xc, Bc)
        return h, y

    # remat per chunk (the [B,C,C,nh] decay tensor must not be saved per
    # chunk by the scan's AD)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h, ys = jax.lax.scan(body, h0.astype(jnp.float32),
                         (xs, dts, Bs, Cs, als))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, T, nh, hd)
    return y.astype(x.dtype), h


def _ssd_step(x, dt, B_, C_, a_log, h):
    """Single-token recurrence. x: [B,nh,hd]; dt,a_log: [B,nh]; B_,C_: [B,N]."""
    xf = x.astype(jnp.float32)
    a = jnp.exp(a_log.astype(jnp.float32))               # [B,nh]
    h = a[:, :, None, None] * h + jnp.einsum(
        "bh,bhe,bn->bhen", dt.astype(jnp.float32), xf, B_.astype(jnp.float32))
    y = jnp.einsum("bn,bhen->bhe", C_.astype(jnp.float32), h)
    return y.astype(x.dtype), h


def block_apply(pb, x, cfg: ModelConfig, state, *, chunk=64, single=False):
    """One Mamba2 block. x: [B,T,d]; state: {'h','conv'} for this layer."""
    B, T, d = x.shape
    d_in = cfg.d_inner
    N = cfg.ssm_state
    hd = cfg.ssm_headdim
    nh = d_in // hd

    resid = x
    xn = L.rms_norm(x, pb["norm"], cfg.norm_eps)
    proj = xn @ pb["in_proj"]
    z, xbc_dt = proj[..., :d_in], proj[..., d_in:]
    xbc, dt_raw = xbc_dt[..., :d_in + 2 * N], xbc_dt[..., d_in + 2 * N:]
    xbc, conv_state = _causal_conv(xbc, pb["conv_w"], state["conv"], single)
    xs = xbc[..., :d_in].reshape(B, T, nh, hd)
    B_ = xbc[..., d_in:d_in + N]
    C_ = xbc[..., d_in + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         pb["dt_bias"][None, None, :])    # [B,T,nh]
    a_log = -jnp.exp(pb["A_log"])[None, None, :] * dt     # log a_t  [B,T,nh]

    if single:
        y, h = _ssd_step(xs[:, 0], dt[:, 0], B_[:, 0], C_[:, 0],
                         a_log[:, 0], state["h"])
        y = y[:, None]
    else:
        y, h = _ssd_chunked(xs, dt, B_, C_, a_log, state["h"], chunk)
    y = y + xs * pb["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, T, d_in)
    y = L.rms_norm(y * jax.nn.silu(z), pb["gate_norm"], cfg.norm_eps)
    out = y @ pb["out_proj"]
    return resid + out, {"h": h, "conv": conv_state}
