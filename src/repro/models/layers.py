"""Shared neural-net layers: norms, RoPE, flash-style attention, FFNs.

Everything is a pure function over nested-dict params. Layer stacks are
stored with a leading layer axis and consumed via ``jax.lax.scan`` so HLO
size stays O(1) in depth (critical for the 512-device dry-run compiles).

The training/prefill attention is a blockwise streaming-softmax
implementation (flash attention expressed in jnp + lax.scan): memory is
O(block_q * block_kv) instead of O(S^2), XLA sees real FLOPs (needed for
cost_analysis-based rooflines — a Pallas custom call would hide them), and
it partitions cleanly under the production mesh.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -3.0, 3.0, (d_in, d_out), jnp.float32)
            * std).astype(dtype)


def stacked_dense_init(key, n: int, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -3.0, 3.0, (n, d_in, d_out), jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# norm
# ---------------------------------------------------------------------------
def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, H, hd]; positions: [S] or [B, S] (int32)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]   # [S, half]
        ang = ang[None, :, None, :]                                     # [1,S,1,half]
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs          # [B,S,half]
        ang = ang[:, :, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise (flash-style) attention — train / prefill
#
# custom_vjp so the backward is O(block) memory: the naive scan's AD would
# save per-kv-block residuals (measured: 65 GB/device temp for qwen2
# train_4k; 2.9 GB with this — EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------
def _attn_mask(qpos, kp, causal, window):
    mask = jnp.ones((qpos.shape[0], qpos.shape[1], kp.shape[0]), bool)
    dq = qpos[:, :, None]
    dk = kp[None, None, :]
    if causal:
        mask &= dq >= dk
    w = jnp.asarray(window, jnp.int32)  # traced per-layer scalar; 0 = global
    mask &= jnp.where(w > 0, (dq - dk) < w, True)
    return mask


def _flash_fwd(q, k, v, qpos, kpos, causal, window, softcap, scale):
    """q: [B,nq,bq,KV,G,hd]; k,v: [nk,B,bk,KV,hd]. Returns out, lse."""
    B, nq, bq, KV, G, hd = q.shape

    m0 = jnp.full((B, nq, bq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, bq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, nq, bq, KV, G, hd), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, kp = inp
        s = jnp.einsum("bnqkgd,bskd->bnqkgs", q, kb,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        mask = _attn_mask(qpos, kp, causal, window)
        s = jnp.where(mask[None, :, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bnqkgs,bskd->bnqkgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k, v, kpos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash(q, k, v, qpos, kpos, causal, window_static, softcap, scale):
    # window_static: python int >= 0, or -1 meaning "traced" (then window
    # rides in qpos aux — see blockwise_attention)
    out, _ = _flash_fwd(q, k, v, qpos[0], kpos, causal, qpos[1], softcap,
                        scale)
    return out


def _flash_f(q, k, v, qpos, kpos, causal, window_static, softcap, scale):
    out, lse = _flash_fwd(q, k, v, qpos[0], kpos, causal, qpos[1], softcap,
                          scale)
    return out, (q, k, v, qpos, kpos, out, lse)


def _flash_b(causal, window_static, softcap, scale, res, dout):
    q, k, v, qpos_w, kpos, out, lse = res
    qpos, window = qpos_w
    B, nq, bq, KV, G, hd = q.shape
    dout = dout.astype(jnp.float32)
    delta = (dout * out.astype(jnp.float32)).sum(-1)       # [B,nq,bq,KV,G]

    def body(dq_acc, inp):
        kb, vb, kp = inp
        sraw = jnp.einsum("bnqkgd,bskd->bnqkgs", q, kb,
                          preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            t = jnp.tanh(sraw / softcap)
            s = softcap * t
            dcap = 1.0 - jnp.square(t)                     # ds_raw/ds
        else:
            s = sraw
            dcap = None
        mask = _attn_mask(qpos, kp, causal, window)
        s = jnp.where(mask[None, :, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                    # [B,nq,bq,KV,G,s]
        dp = jnp.einsum("bnqkgd,bskd->bnqkgs", dout, vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * scale
        if dcap is not None:
            ds = ds * dcap
        dv = jnp.einsum("bnqkgs,bnqkgd->bskd", p, dout)
        dk = jnp.einsum("bnqkgs,bnqkgd->bskd", ds, q.astype(jnp.float32))
        dq_acc = dq_acc + jnp.einsum("bnqkgs,bskd->bnqkgd", ds,
                                     kb.astype(jnp.float32))
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros(q.shape, jnp.float32)
    dq, (dk, dv) = jax.lax.scan(body, dq0, (k, v, kpos))
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            (jnp.zeros_like(qpos), jnp.zeros_like(window)),
            jnp.zeros_like(kpos))


_flash.defvjp(_flash_f, _flash_b)


def blockwise_attention(
    q: Array, k: Array, v: Array, *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 256,
    block_kv: int = 512,
    q_offset: int = 0,
) -> Array:
    """Streaming-softmax attention with O(block) backward memory.

    q: [B, Sq, H, hd]; k, v: [B, Sk, KV, hd]; returns [B, Sq, H, hd].
    GQA handled by grouping H into (KV, G). Window > 0 restricts attention
    to the last ``window`` positions (sliding-window / gemma3 local layers;
    may be a traced per-layer scalar).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_kv, Sk)
    if Sq % bq:
        bq = math.gcd(bq, Sq)   # e.g. cross-attn over 1600 image tokens
    if Sk % bk:
        bk = math.gcd(bk, Sk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(hd)

    qh = q.reshape(B, nq, bq, KV, G, hd)
    kh = jnp.moveaxis(k.reshape(B, nk, bk, KV, hd), 1, 0)    # [nk,B,bk,KV,hd]
    vh = jnp.moveaxis(v.reshape(B, nk, bk, KV, hd), 1, 0)
    qpos = q_offset + jnp.arange(Sq, dtype=jnp.int32).reshape(nq, bq)
    kpos = jnp.arange(Sk, dtype=jnp.int32).reshape(nk, bk)
    w = jnp.asarray(window, jnp.int32)

    out = _flash(qh, kh, vh, (qpos, w), kpos, causal, 0, softcap, scale)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# banded attention — static sliding window, O(S*w) instead of O(S^2)
# (§Perf cell B: gemma3 local layers compute 5/6 of the stack; masking the
# full S^2 wastes S/w = 32x at prefill_32k)
# ---------------------------------------------------------------------------
def banded_attention(q: Array, k: Array, v: Array, *, window: int,
                     block: int = 512, softcap: float = 0.0) -> Array:
    """Causal sliding-window attention computing only the kv blocks inside
    the window band. window must be a python int > 0."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    assert Sq == Sk, "banded path is for self-attention prefill/train"
    G = H // KV
    b = min(block, Sq)
    if Sq % b:
        b = math.gcd(b, Sq)
    n = Sq // b
    nb = -(-window // b) + 1          # kv blocks per band
    nb = min(nb, n)
    scale = 1.0 / math.sqrt(hd)

    qh = q.reshape(B, n, b, KV, G, hd)
    kb = k.reshape(B, n, b, KV, hd)
    vb = v.reshape(B, n, b, KV, hd)
    # band indices: for q block i -> kv blocks [i-nb+1 .. i]
    off = jnp.arange(nb, dtype=jnp.int32) - (nb - 1)
    idx = jnp.arange(n, dtype=jnp.int32)[:, None] + off[None, :]  # [n, nb]
    valid_blk = idx >= 0
    idx_c = jnp.clip(idx, 0, n - 1)
    bk = jnp.take(kb, idx_c, axis=1)   # [B, n, nb, b, KV, hd]
    bv = jnp.take(vb, idx_c, axis=1)

    qpos = jnp.arange(Sq, dtype=jnp.int32).reshape(n, b)
    kpos = jnp.take(qpos, idx_c, axis=0)                  # [n, nb, b]
    kpos = jnp.where(valid_blk[:, :, None], kpos, -1)

    s = jnp.einsum("bnqkgd,bntskd->bnkgqts", qh, bk,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    # s: [B, n, KV, G, q=b, t=nb, s=b] -> flatten band, mask, softmax
    sf = s.reshape(B, n, KV, G, b, nb * b)
    mask = _banded_mask(qpos, kpos, window)               # [n, b, nb*b]
    sf = jnp.where(mask[None, :, None, None], sf, NEG_INF)
    p = jax.nn.softmax(sf, axis=-1)
    out = jnp.einsum("bnkgqe,bnekd->bnqkgd", p.astype(v.dtype),
                     bv.reshape(B, n, nb * b, KV, hd),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _banded_mask(qpos, kpos, window):
    """[n, b(q), nb, b(s)] -> mask reshaped to [n, b, nb*b] laid out as
    s's (q, t*s) trailing dims."""
    dq = qpos[:, :, None, None]
    dk = kpos[:, None, :, :]
    m = (dk >= 0) & (dq >= dk) & ((dq - dk) < window)     # [n, b, nb, b]
    n, b = qpos.shape
    return m.reshape(n, b, -1)


# ---------------------------------------------------------------------------
# decode attention — one query token against a (possibly huge) cache
# ---------------------------------------------------------------------------
def decode_attention(
    q: Array, k_cache: Array, v_cache: Array, cur_index: Array, *,
    window: int = 0, softcap: float = 0.0,
) -> Array:
    """q: [B, 1, H, hd]; caches: [B, S, KV, hd]; cur_index: scalar int32
    (position of the query token; cache entries at positions <= cur_index
    are valid). Works with the cache sequence dim sharded over the mesh
    (flash-decoding: XLA partitions the max/sum reductions with psum).
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qh = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S, dtype=jnp.int32)
    mask = pos <= cur_index
    w = jnp.asarray(window, jnp.int32)
    mask &= jnp.where(w > 0, (cur_index - pos) < w, True)
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", (p / l).astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (projection + rope + core), shared by all transformer archs
# ---------------------------------------------------------------------------
def attn_init(key, cfg, n: int, cross: bool = False):
    """Stacked attention params for ``n`` layers."""
    ks = jax.random.split(key, 6)
    dtype = jnp.dtype(cfg.dtype)
    p = {
        "wq": stacked_dense_init(ks[0], n, cfg.d_model, cfg.q_dim, dtype),
        "wk": stacked_dense_init(ks[1], n, cfg.d_model, cfg.kv_dim, dtype),
        "wv": stacked_dense_init(ks[2], n, cfg.d_model, cfg.kv_dim, dtype),
        "wo": stacked_dense_init(ks[3], n, cfg.q_dim, cfg.d_model, dtype,
                                 scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((n, cfg.q_dim), jnp.float32)
        p["bk"] = jnp.zeros((n, cfg.kv_dim), jnp.float32)
        p["bv"] = jnp.zeros((n, cfg.kv_dim), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((n, cfg.head_dim), jnp.float32)
        p["k_norm"] = jnp.ones((n, cfg.head_dim), jnp.float32)
    return p


def attn_qkv(p, x: Array, cfg, kv_x: Optional[Array] = None):
    """Project to q/k/v heads. kv_x: cross-attention source (image embeds)."""
    src = x if kv_x is None else kv_x
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    q = q.reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------
def ffn_init(key, cfg, n: int, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    down_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    if cfg.ffn_kind == "gelu":
        return {
            "w_up": stacked_dense_init(ks[0], n, cfg.d_model, d_ff, dtype),
            "w_down": stacked_dense_init(ks[1], n, d_ff, cfg.d_model, dtype,
                                         scale=down_scale),
        }
    return {
        "w_gate": stacked_dense_init(ks[0], n, cfg.d_model, d_ff, dtype),
        "w_up": stacked_dense_init(ks[1], n, cfg.d_model, d_ff, dtype),
        "w_down": stacked_dense_init(ks[2], n, d_ff, cfg.d_model, dtype,
                                     scale=down_scale),
    }


def ffn_apply(p, x: Array) -> Array:
    if "w_gate" in p:
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# embedding / unembedding with vocab sharding-friendly loss
# ---------------------------------------------------------------------------
def embed_init(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    p = {"table": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model), jnp.float32)
                   * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dtype)
    return p


def embed_apply(p, tokens: Array) -> Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed_apply(p, x: Array) -> Array:
    w = p["table"].T if "head" not in p else p["head"]
    return x @ w


def softmax_cross_entropy(logits: Array, labels: Array, mask: Array) -> Array:
    """logits: [B, S, V] with V sharded over 'model'; labels: [B, S].

    Written so the SPMD partitioner never gathers the vocab dim: max/sum
    reductions partition into partial-reduce + psum, and the label
    log-probability is a one-hot contraction (fuses into the reduce loop)
    instead of a gather on the sharded axis.
    """
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m                                     # bf16, sharded
    sumexp = jnp.exp(shifted.astype(jnp.float32)).sum(axis=-1)
    lse = jnp.log(sumexp) + m[..., 0].astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = (shifted.astype(jnp.float32) * onehot).sum(axis=-1) + \
        m[..., 0].astype(jnp.float32)
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
