"""Mixture-of-Experts block with expert parallelism (EP).

Dispatch is MegaBlocks-style adapted to TPU/SPMD (DESIGN.md §10):

  router top-k -> sort assignments by destination expert shard -> capacity
  slice -> all_to_all along the ``model`` (EP) axis -> per-expert matmul via
  a lax.scan over local experts with capacity-sized blocks -> all_to_all
  back -> weighted combine.

Run inside ``shard_map`` so the all_to_all is explicit; tokens are sharded
over (dp axes x model axis) during dispatch (sequence dim over ``model`` —
a sequence-parallel region), expert weights are sharded E over ``model``
(EP) and d over ``data`` (FSDP, gathered per layer with an explicit
all_gather).

The same hierarchical top-k + sort-dispatch machinery the paper uses for
result reporting (§III.B "documentIDs with high scores are reported") backs
the routing here — see repro.core.topk.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from repro.distributed.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.meshctx import MeshCtx
from repro.models.layers import stacked_dense_init

Array = jax.Array


@jax.custom_vjp
def _same_dtype_grad(x):
    """Identity whose cotangent is cast back to x's dtype — stops the
    router einsum's fp32 VJP (preferred_element_type propagates into the
    transpose rule) from promoting the residual-stream backward chain to
    fp32 (measured: 2x collective bytes on kimi train_4k)."""
    return x


def _sdg_fwd(x):
    return x, jnp.zeros((), x.dtype)


def _sdg_bwd(res, g):
    return (g.astype(res.dtype),)


_same_dtype_grad.defvjp(_sdg_fwd, _sdg_bwd)


def moe_init(key, cfg, n: int):
    """Stacked MoE params for n layers."""
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    down_std = 1.0 / math.sqrt(ff)

    def experts(k, d_in, d_out, s):
        return (jax.random.truncated_normal(
            k, -3.0, 3.0, (n, E, d_in, d_out), jnp.float32) * s).astype(dtype)

    p = {
        "router": (jax.random.truncated_normal(
            ks[0], -3.0, 3.0, (n, d, E), jnp.float32) * std),  # fp32 router
        "w_gate": experts(ks[1], d, ff, std),
        "w_up": experts(ks[2], d, ff, std),
        "w_down": experts(ks[3], ff, d, down_std),
    }
    if cfg.n_shared_experts > 0:
        ff_sh = cfg.n_shared_experts * ff
        p["shared"] = {
            "w_gate": stacked_dense_init(ks[4], n, d, ff_sh, dtype),
            "w_up": stacked_dense_init(jax.random.fold_in(ks[4], 1), n, d, ff_sh, dtype),
            "w_down": stacked_dense_init(jax.random.fold_in(ks[4], 2), n, ff_sh, d, dtype),
        }
    return p


def _expert_ffn_scan(x_sorted: Array, starts: Array, counts: Array,
                     w_gate: Array, w_up: Array, w_down: Array,
                     cap: int) -> Array:
    """Per-expert SwiGLU over capacity-sized dynamic slices of the sorted
    token buffer. x_sorted: [N, d]; w_*: [E_loc, ...]. Returns [N, d]."""
    N, d = x_sorted.shape
    E_loc = w_gate.shape[0]
    out0 = jnp.zeros((N, d), x_sorted.dtype)

    def body(out, inp):
        wg, wu, wd, start, count = inp
        s = jnp.clip(start, 0, max(N - cap, 0))
        rows = jax.lax.dynamic_slice_in_dim(x_sorted, s, cap, axis=0)
        idx = s + jnp.arange(cap, dtype=jnp.int32)
        valid = (idx >= start) & (idx < start + count)
        h = (jax.nn.silu(rows @ wg) * (rows @ wu)) @ wd
        h = jnp.where(valid[:, None], h, 0)
        out = out.at[idx].add(h, mode="drop")
        return out, None

    out, _ = jax.lax.scan(body, out0, (w_gate, w_up, w_down, starts, counts))
    return out


def _a2a_maybe_int8(x: Array, tp_axis: str) -> Array:
    """Dispatch all_to_all, optionally int8-quantized per token row
    (a2a_int8 flag): 2x wire bytes vs bf16, DeepSeek-V3 fp8-dispatch style
    and the paper's bandwidth-efficient-encoding insight on ICI. Error
    feedback is unnecessary: quantization is per-row absmax and the value
    is consumed once."""
    from repro.models import perfcfg
    if not perfcfg.flag("a2a_int8"):
        return jax.lax.all_to_all(x, tp_axis, split_axis=0, concat_axis=0,
                                  tiled=False)
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    q = jax.lax.all_to_all(q, tp_axis, split_axis=0, concat_axis=0,
                           tiled=False)
    s = jax.lax.all_to_all(scale, tp_axis, split_axis=0, concat_axis=0,
                           tiled=False)
    return (q.astype(jnp.float32) * s).astype(x.dtype)


def _dispatch_local(x: Array, router: Array, w_gate: Array, w_up: Array,
                    w_down: Array, *, cfg, tp_axis: str, M: int) -> Tuple[Array, Array]:
    """Per-device body under shard_map. x: [T_loc, d] local tokens;
    w_*: [E_loc, ...] local expert shards (d already FSDP-gathered)."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // M

    from repro.models import perfcfg
    if perfcfg.flag("router_bf16_matmul"):
        # bf16 matmul, fp32 accumulation: keeps the x-cotangent bf16 (an
        # fp32 cast here promotes the whole residual stream's backward
        # collectives to fp32 — measured 2x collective bytes, kimi train)
        logits = jnp.einsum("td,de->te", _same_dtype_grad(x),
                            router.astype(x.dtype),
                            preferred_element_type=jnp.float32)
    else:
        logits = (x.astype(jnp.float32) @ router)                 # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_id = jax.lax.top_k(probs, k)                   # [T, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balancing loss (global over all shards) -------------------
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_id.reshape(-1)].add(
        1.0 / (T * k))
    me = jax.lax.pmean(me, axis_name=tp_axis)
    ce = jax.lax.pmean(ce, axis_name=tp_axis)
    aux = E * jnp.sum(me * ce)

    # --- send-side sort by destination shard --------------------------------
    cap_send = int(math.ceil(T * k / M * cfg.capacity_factor))
    flat_eid = expert_id.reshape(-1)                              # [T*k]
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_gw = gate_w.reshape(-1)
    dest = flat_eid // E_loc
    order = jnp.argsort(dest, stable=True)
    s_dest, s_eid, s_tok, s_gw = dest[order], flat_eid[order], flat_tok[order], flat_gw[order]
    counts = jnp.zeros((M,), jnp.int32).at[s_dest].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[s_dest]
    keep = pos < cap_send
    slot = jnp.where(keep, s_dest * cap_send + pos, M * cap_send)  # drop slot

    send_x = jnp.zeros((M * cap_send, d), x.dtype).at[slot].set(
        x[s_tok], mode="drop")
    send_le = jnp.full((M * cap_send,), E_loc, jnp.int32).at[slot].set(
        s_eid % E_loc, mode="drop")                                # local expert id
    # bookkeeping to combine on the way back (stays on source device)
    slot_tok = jnp.full((M * cap_send,), -1, jnp.int32).at[slot].set(
        s_tok, mode="drop")
    slot_gw = jnp.zeros((M * cap_send,), jnp.float32).at[slot].set(
        s_gw, mode="drop")

    # --- all_to_all to expert shards ----------------------------------------
    recv_x = _a2a_maybe_int8(send_x.reshape(M, cap_send, d), tp_axis)
    recv_le = jax.lax.all_to_all(send_le.reshape(M, cap_send), tp_axis,
                                 split_axis=0, concat_axis=0, tiled=False)
    N = M * cap_send
    recv_x = recv_x.reshape(N, d)
    recv_le = recv_le.reshape(N)

    # --- local expert compute (sorted, capacity-sliced scan) -----------------
    order2 = jnp.argsort(recv_le, stable=True)
    xs = recv_x[order2]
    le_sorted = recv_le[order2]
    counts2 = jnp.zeros((E_loc + 1,), jnp.int32).at[le_sorted].add(1)
    counts2 = counts2[:E_loc]
    starts2 = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts2)[:-1]])
    cap_exp = int(math.ceil(N / max(E_loc, 1) * cfg.capacity_factor))
    cap_exp = min(cap_exp, N)
    ys = _expert_ffn_scan(xs, starts2, counts2, w_gate, w_up, w_down, cap_exp)
    out_recv = jnp.zeros((N, d), x.dtype).at[order2].set(ys)

    # --- all_to_all back + weighted combine ----------------------------------
    back = _a2a_maybe_int8(out_recv.reshape(M, cap_send, d), tp_axis)
    back = back.reshape(N, d)
    contrib = back * slot_gw[:, None].astype(back.dtype)
    y = jnp.zeros((T, d), x.dtype).at[slot_tok].add(contrib, mode="drop")
    return y, aux


def moe_apply(p_layer, x: Array, cfg, ctx: MeshCtx) -> Tuple[Array, Array]:
    """x: [B, S, d] -> (y [B, S, d], aux scalar). p_layer holds this layer's
    slices: router [d,E], w_gate/w_up [E,d,ff], w_down [E,ff,d]."""
    B, S, d = x.shape
    M = ctx.tp_size
    seq_shard = S % M == 0 and S >= M
    xs_spec = P(ctx.dp_axes, ctx.tp_axis if seq_shard else None, None)
    wg_spec = P(ctx.tp_axis, ctx.fsdp_axis, None)
    wd_spec = P(ctx.tp_axis, None, ctx.fsdp_axis)

    @functools.partial(
        shard_map, mesh=ctx.mesh,
        in_specs=(xs_spec, P(None, None), wg_spec, wg_spec, wd_spec),
        out_specs=(xs_spec, P()),
        check_vma=False)
    def run(xb, router, wg, wu, wd):
        # FSDP gather of the expert weights for this layer (explicit)
        wg = jax.lax.all_gather(wg, ctx.fsdp_axis, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, ctx.fsdp_axis, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, ctx.fsdp_axis, axis=2, tiled=True)
        Bl, Sl = xb.shape[0], xb.shape[1]
        y, aux = _dispatch_local(
            xb.reshape(Bl * Sl, d), router, wg, wu, wd,
            cfg=cfg, tp_axis=ctx.tp_axis, M=M)
        aux = jax.lax.pmean(aux, ctx.fsdp_axis)
        for ax in ctx.dp_axes:
            if ax != ctx.fsdp_axis:
                aux = jax.lax.pmean(aux, ax)
        return y.reshape(Bl, Sl, d), aux

    y, aux = run(x, p_layer["router"], p_layer["w_gate"], p_layer["w_up"],
                 p_layer["w_down"])

    if "shared" in p_layer:
        sh = p_layer["shared"]
        y = y + (jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])) @ sh["w_down"]
    return y, aux
