"""Rematerialization policy knob (a §Perf hillclimb axis).

  minimal — nothing_saveable: full per-layer remat; activations are just
            the scan carries (L x [B,S,d]). Memory-lean default; backward
            recomputes the layer.
  dots    — dots_with_no_batch_dims_saveable: saves projection outputs
            (d_ff-sized) — ~30x more activation memory at qwen2 scale
            (measured: 82.8 GB vs 2.9 GB temp per device, train_4k), in
            exchange for no matmul recompute.
  none    — no remat (only for tiny smoke configs).
"""
import jax

_POLICY = "minimal"


def set_policy(name: str):
    global _POLICY
    assert name in ("minimal", "dots", "none")
    _POLICY = name


def policy_name() -> str:
    return _POLICY


def wrap(fn):
    """Apply the active remat policy to a scan body."""
    if _POLICY == "none":
        return fn
    if _POLICY == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.nothing_saveable)
