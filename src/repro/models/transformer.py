"""Decoder-only transformer covering the dense / MoE / VLM / audio archs.

One parameterized implementation:
  - GQA attention with RoPE, optional qkv-bias (qwen2), qk-norm (qwen3,
    gemma3), sliding-window local:global mix (gemma3);
  - SwiGLU / GELU FFN or MoE block (kimi-k2, qwen3-moe) with EP dispatch;
  - cross-attention "superblocks" for the VLM (llama-3.2-vision): 4 self
    layers + 1 cross-attn layer per superblock, scanned over 20 superblocks;
  - audio backbone (musicgen): embeddings-in (stub EnCodec frontend).

Layer stacks are scanned; per-layer heterogeneity (gemma3 window pattern)
rides along as scan xs so the HLO stays O(1) in depth.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.meshctx import MeshCtx
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import perfcfg
from repro.models import rematcfg

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _block_init(key, cfg: ModelConfig, n: int, kind: str):
    """kind: dense | moe | cross."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((n, cfg.d_model), jnp.float32),
        "attn": L.attn_init(k1, cfg, n, cross=(kind == "cross")),
        "ln2": jnp.ones((n, cfg.d_model), jnp.float32),
    }
    if kind == "moe":
        p["moe"] = moe_lib.moe_init(k2, cfg, n)
    else:
        d_ff = cfg.d_ff
        if kind == "dense_lead" and cfg.d_ff_dense:
            d_ff = cfg.d_ff_dense
        p["mlp"] = L.ffn_init(k3, cfg, n, d_ff=d_ff)
    return p


def init(key, cfg: ModelConfig):
    keys = jax.random.split(key, 8)
    p = {"embed": L.embed_init(keys[0], cfg),
         "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.family == "vlm":
        n_sb = cfg.n_layers // cfg.cross_attn_every
        p["self_blocks"] = _block_init(
            keys[1], cfg, n_sb * cfg.cross_attn_every, "dense")
        p["cross_blocks"] = _block_init(keys[2], cfg, n_sb, "cross")
    elif cfg.n_experts > 0:
        nd = cfg.first_k_dense
        if nd:
            cfg_lead = cfg
            p["dense_blocks"] = _block_init(keys[1], cfg_lead, nd, "dense_lead")
        p["moe_blocks"] = _block_init(keys[2], cfg, cfg.n_layers - nd, "moe")
    else:
        p["blocks"] = _block_init(keys[1], cfg, cfg.n_layers, "dense")
    return p


def window_schedule(cfg: ModelConfig, n: int) -> Array:
    """Per-layer sliding window (0 = global). gemma3: 5 local : 1 global."""
    if cfg.local_global_ratio > 0 and cfg.sliding_window > 0:
        per = cfg.local_global_ratio + 1
        w = [cfg.sliding_window if (i % per) != (per - 1) else 0
             for i in range(n)]
    elif cfg.sliding_window > 0:
        w = [cfg.sliding_window] * n
    else:
        w = [0] * n
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------
def _self_attn(pb, x, cfg, *, positions, window, mode, cache=None,
               cur_index=None, ctx=None, static_window=0):
    """pb: block params {'ln1', 'attn', ...}. Returns (attn_out, kv).

    static_window > 0 (python int) + banded_local flag -> O(S*w) banded
    attention. seq_shard_attn flag + unshardable heads -> attention compute
    sharded over the sequence on the model axis (q seq-sharded, kv full).
    """
    ap = pb["attn"]
    q, k, v = L.attn_qkv(ap, L_norm(x, pb["ln1"], cfg), cfg)
    q = L.rope(q, positions, cfg.rope_theta)
    k_rot = L.rope(k, positions, cfg.rope_theta)
    if mode in ("train", "prefill"):
        S = q.shape[1]
        if (ctx is not None and perfcfg.flag("seq_shard_attn")
                and cfg.n_heads % ctx.tp_size != 0
                and S % ctx.tp_size == 0 and S >= 1024):
            q = jax.lax.with_sharding_constraint(
                q, ctx.sharding(ctx.dp_axes, ctx.tp_axis, None, None))
            k_rot = jax.lax.with_sharding_constraint(
                k_rot, ctx.sharding(ctx.dp_axes, None, None, None))
            v = jax.lax.with_sharding_constraint(
                v, ctx.sharding(ctx.dp_axes, None, None, None))
        if static_window > 0 and perfcfg.flag("banded_local"):
            out = L.banded_attention(q, k_rot, v, window=static_window,
                                     softcap=cfg.attn_logit_softcap)
        else:
            out = L.blockwise_attention(
                q, k_rot, v, causal=True, window=window,
                softcap=cfg.attn_logit_softcap)
        new_kv = (k_rot, v)
    else:  # decode: cache = (k_cache, v_cache) [B, S, KV, hd]
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_rot.astype(k_cache.dtype), cur_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cur_index, axis=1)
        out = L.decode_attention(q, k_cache, v_cache, cur_index,
                                 window=window,
                                 softcap=cfg.attn_logit_softcap)
        new_kv = (k_cache, v_cache)
    B, S = x.shape[:2]
    return out.reshape(B, S, cfg.q_dim) @ ap["wo"], new_kv


def L_norm(x, scale, cfg):
    return L.rms_norm(x, scale, cfg.norm_eps)


def _cross_attn(pb, x, img_kv, cfg):
    """Cross-attention onto precomputed image K/V. img_kv: (k, v)
    [B, n_img, KV, hd]. Non-causal."""
    q = (L_norm(x, pb["ln1"], cfg) @ pb["attn"]["wq"])
    B, S = x.shape[:2]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    if "q_norm" in pb["attn"]:
        q = L.rms_norm(q, pb["attn"]["q_norm"], cfg.norm_eps)
    k, v = img_kv
    out = L.blockwise_attention(q, k, v, causal=False, window=0,
                                block_q=min(256, S),
                                block_kv=min(512, k.shape[1]))
    x = x + out.reshape(B, S, cfg.q_dim) @ pb["attn"]["wo"]
    x = x + L.ffn_apply(pb["mlp"], L_norm(x, pb["ln2"], cfg))
    return x


def _image_kv(pb_cross, image_embeds, cfg):
    """Precompute cross-attn K/V from image embeddings for all cross blocks.
    image_embeds: [B, n_img, d]; returns stacked (k, v) [n_cross, B, n_img, KV, hd]."""
    def one(p):
        B, n_img = image_embeds.shape[:2]
        k = (image_embeds @ p["wk"]).reshape(B, n_img, cfg.n_kv_heads, cfg.head_dim)
        v = (image_embeds @ p["wv"]).reshape(B, n_img, cfg.n_kv_heads, cfg.head_dim)
        if "k_norm" in p:
            k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
        return k, v
    return jax.vmap(one)(pb_cross["attn"])


def _mlp_or_moe(pb, x, cfg, ctx):
    if "moe" in pb:
        y, aux = moe_lib.moe_apply(pb["moe"], L_norm(x, pb["ln2"], cfg), cfg, ctx)
        return x + y, aux
    return x + L.ffn_apply(pb["mlp"], L_norm(x, pb["ln2"], cfg)), jnp.float32(0)


def _dense_stack(blocks, x, cfg, ctx, *, positions, windows, mode,
                 caches=None, cur_index=None, remat=True, moe=False):
    """Scan a stacked block group. caches: (k,v) stacks [n,B,S,KV,hd] for
    decode. Returns (x, aux_sum, new_caches or kv stacks)."""
    dp_spec = P(ctx.dp_axes, None, None)

    def resid_spec(x):
        # sp_residual: residual stream stays sequence-sharded on the model
        # axis between blocks (Megatron-SP) — halves the per-layer
        # reshard collectives around the MoE shard_map region
        if perfcfg.flag("sp_residual") and x.shape[1] % ctx.tp_size == 0 \
                and x.shape[1] >= ctx.tp_size:
            return ctx.sharding(ctx.dp_axes, ctx.tp_axis, None)
        return ctx.sharding(ctx.dp_axes, None, None)

    def body(carry, inp):
        x, aux = carry
        if mode == "decode":
            pb, w, kc, vc = inp
            attn_out, (kc, vc) = _self_attn(
                pb, x, cfg, positions=positions, window=w, mode=mode,
                cache=(kc, vc), cur_index=cur_index, ctx=ctx)
            ys = (kc, vc)
        else:
            pb, w = inp
            attn_out, (k, v) = _self_attn(
                pb, x, cfg, positions=positions, window=w, mode=mode,
                ctx=ctx)
            ys = (k, v) if mode == "prefill" else None
        x = x + attn_out
        x = jax.lax.with_sharding_constraint(x, resid_spec(x))
        x, aux_l = _mlp_or_moe(pb, x, cfg, ctx)
        x = jax.lax.with_sharding_constraint(x, resid_spec(x))
        return (x, aux + aux_l), ys

    if remat:
        body = rematcfg.wrap(body)

    xs = (blocks, windows)
    if mode == "decode":
        xs = (blocks, windows, caches[0], caches[1])
    (x, aux), ys = jax.lax.scan(body, (x, jnp.float32(0)), xs)
    return x, aux, ys


def _static_window_stack(blocks, x, cfg, ctx, *, positions, mode, remat):
    """gemma3 5:1 local:global as a superblock scan: per-position windows
    are PYTHON ints, so local positions use banded attention (O(S*w))
    and only the global position pays O(S^2). Layer order is preserved
    (layer i is local iff i % (ratio+1) != ratio — same as
    window_schedule)."""
    per = cfg.local_global_ratio + 1
    n_sb = cfg.n_layers // per
    rem = cfg.n_layers - n_sb * per
    win_of = [cfg.sliding_window if j != per - 1 else 0 for j in range(per)]

    def group(t):
        return t[:n_sb * per].reshape((n_sb, per) + t.shape[1:])
    main = jax.tree.map(group, blocks)

    def one_layer(pb, x, w):
        attn_out, kv = _self_attn(pb, x, cfg, positions=positions,
                                  window=w, mode=mode, ctx=ctx,
                                  static_window=w)
        x = x + attn_out
        x = x + L.ffn_apply(pb["mlp"], L_norm(x, pb["ln2"], cfg))
        x = jax.lax.with_sharding_constraint(
            x, ctx.sharding(ctx.dp_axes, None, None))
        return x, kv

    def sb_body(carry, pb_group):
        x, = carry
        ks, vs = [], []
        for j in range(per):
            pb = jax.tree.map(lambda t: t[j], pb_group)
            x, (k, v) = one_layer(pb, x, win_of[j])
            if mode == "prefill":
                ks.append(k); vs.append(v)
        ys = (jnp.stack(ks), jnp.stack(vs)) if ks else None
        return (x,), ys

    if remat:
        sb_body = rematcfg.wrap(sb_body)
    (x,), ys = jax.lax.scan(sb_body, (x,), main)
    rem_ks, rem_vs = [], []
    for i in range(n_sb * per, cfg.n_layers):
        pb = jax.tree.map(lambda t: t[i], blocks)
        x, (k, v) = one_layer(pb, x, win_of[i % per])
        if mode == "prefill":
            rem_ks.append(k); rem_vs.append(v)

    kv = None
    if mode == "prefill":
        k_all = ys[0].reshape((-1,) + ys[0].shape[2:])
        v_all = ys[1].reshape((-1,) + ys[1].shape[2:])
        if rem_ks:
            k_all = jnp.concatenate([k_all, jnp.stack(rem_ks)], axis=0)
            v_all = jnp.concatenate([v_all, jnp.stack(rem_vs)], axis=0)
        kv = (k_all, v_all)
    return x, kv


# ---------------------------------------------------------------------------
# forward (train) / prefill / decode
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, ctx: MeshCtx, batch, *, mode="train",
            remat=True, caches=None, cur_index=None):
    """batch: dict with 'tokens' [B,S] (or 'embeds' [B,S,d] for audio stub)
    and optional 'image_embeds' [B,n_img,d] (vlm). Returns
    (logits, aux, caches_out)."""
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = L.embed_apply(params["embed"], batch["tokens"])
    B, S = x.shape[:2]
    if mode == "decode":
        positions = jnp.full((B, 1), cur_index, jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
    x = jax.lax.with_sharding_constraint(
        x, ctx.sharding(ctx.dp_axes, None, None))

    aux = jnp.float32(0)
    kv_out = None
    if cfg.family == "vlm":
        n_sb = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every
        img_kv = (caches["img_k"], caches["img_v"]) if mode == "decode" else \
            _image_kv(params["cross_blocks"], batch["image_embeds"], cfg)

        def reshape_group(t):
            return t.reshape((n_sb, per) + t.shape[1:])
        self_groups = jax.tree.map(reshape_group, params["self_blocks"])
        windows = jnp.zeros((n_sb, per), jnp.int32)

        def sb_body(carry, inp):
            x, aux = carry
            if mode == "decode":
                sg, cb, w, kc, vc, ik, iv = inp
            else:
                sg, cb, w, ik, iv = inp
            ys_k, ys_v = [], []
            for i in range(per):
                pb = jax.tree.map(lambda t: t[i], sg)
                if mode == "decode":
                    attn_out, (nk, nv) = _self_attn(
                        pb, x, cfg, positions=positions, window=w[i],
                        mode=mode, cache=(kc[i], vc[i]), cur_index=cur_index)
                    ys_k.append(nk); ys_v.append(nv)
                else:
                    attn_out, (nk, nv) = _self_attn(
                        pb, x, cfg, positions=positions, window=w[i],
                        mode=mode)
                    if mode == "prefill":
                        ys_k.append(nk); ys_v.append(nv)
                x = x + attn_out
                x = x + L.ffn_apply(pb["mlp"], L_norm(x, pb["ln2"], cfg))
            x = _cross_attn(cb, x, (ik, iv), cfg)
            ys = (jnp.stack(ys_k), jnp.stack(ys_v)) if ys_k else None
            return (x, aux), ys

        if remat:
            sb_body = rematcfg.wrap(sb_body)
        ik, iv = img_kv
        xs = (self_groups, params["cross_blocks"], windows, ik, iv)
        if mode == "decode":
            xs = (self_groups, params["cross_blocks"], windows,
                  caches["k"], caches["v"], ik, iv)
        (x, aux), ys = jax.lax.scan(sb_body, (x, aux), xs)
        if mode in ("prefill", "decode"):
            kv_out = {"k": ys[0], "v": ys[1], "img_k": ik, "img_v": iv}
    elif cfg.n_experts > 0:
        nd = cfg.first_k_dense
        ks, vs = [], []
        if nd and "dense_blocks" in params:
            wd = jnp.zeros((nd,), jnp.int32)
            c = None if mode != "decode" else (caches["k"][:nd], caches["v"][:nd])
            x, aux_d, ys = _dense_stack(
                params["dense_blocks"], x, cfg, ctx, positions=positions,
                windows=wd, mode=mode, caches=c, cur_index=cur_index,
                remat=remat)
            aux += aux_d
            if ys is not None:
                ks.append(ys[0]); vs.append(ys[1])
        nm = cfg.n_layers - nd
        wm = jnp.zeros((nm,), jnp.int32)
        c = None if mode != "decode" else (caches["k"][nd:], caches["v"][nd:])
        x, aux_m, ys = _dense_stack(
            params["moe_blocks"], x, cfg, ctx, positions=positions,
            windows=wm, mode=mode, caches=c, cur_index=cur_index, remat=remat,
            moe=True)
        aux += aux_m
        if ys is not None:
            ks.append(ys[0]); vs.append(ys[1])
        if ks:
            kv_out = {"k": jnp.concatenate(ks, 0) if len(ks) > 1 else ks[0],
                      "v": jnp.concatenate(vs, 0) if len(vs) > 1 else vs[0]}
    elif (cfg.local_global_ratio > 0 and cfg.sliding_window > 0
          and mode != "decode" and perfcfg.flag("banded_local")):
        # gemma3 + banded_local: superblock scan with STATIC per-position
        # windows so local layers run the O(S*w) banded kernel
        x, ys = _static_window_stack(params["blocks"], x, cfg, ctx,
                                     positions=positions, mode=mode,
                                     remat=remat)
        if ys is not None:
            kv_out = {"k": ys[0], "v": ys[1]}
    else:
        windows = window_schedule(cfg, cfg.n_layers)
        c = None if mode != "decode" else (caches["k"], caches["v"])
        x, aux, ys = _dense_stack(
            params["blocks"], x, cfg, ctx, positions=positions,
            windows=windows, mode=mode, caches=c, cur_index=cur_index,
            remat=remat)
        if ys is not None:
            kv_out = {"k": ys[0], "v": ys[1]}

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)
    logits = jax.lax.with_sharding_constraint(
        logits, ctx.sharding(ctx.dp_axes, None, ctx.tp_axis))
    return logits, aux, kv_out


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=None):
    """Decode KV caches. For gemma3-style local layers the window cache is
    still allocated at max_len (optimization: ring buffers — see
    EXPERIMENTS.md §Perf)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n = cfg.n_layers
    shape = (n, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if cfg.family == "vlm":
        n_sb = cfg.n_layers // cfg.cross_attn_every
        kv = (n_sb, cfg.cross_attn_every, batch_size, max_len,
              cfg.n_kv_heads, cfg.head_dim)
        cache = {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
                 "img_k": jnp.zeros((n_sb, batch_size, cfg.n_image_tokens,
                                     cfg.n_kv_heads, cfg.head_dim), dtype),
                 "img_v": jnp.zeros((n_sb, batch_size, cfg.n_image_tokens,
                                     cfg.n_kv_heads, cfg.head_dim), dtype)}
    return cache
