"""RWKV-6 "Finch" — attention-free LM with data-dependent decay
[arXiv:2404.05892].

Time-mix uses the WKV recurrence
    o_t = r_t^T (diag(u) k_t v_t^T + S_t),   S_{t+1} = diag(w_t) S_t + k_t v_t^T
with per-channel data-dependent decay w_t = exp(-exp(w0 + tanh(x W_A) W_B)).
Training/prefill run a *chunked* form: within a chunk the pairwise decay
tensor D[t,s,d] = exp(cum_{t-1} - cum_s) is materialized (numerically safe —
no exp(+large)), across chunks an O(hd^2) state is carried by lax.scan.
Decode is the O(1)-state recurrence — the reason this arch runs long_500k.

Simplifications vs the released model (DESIGN.md §14): static token-shift
lerp coefficients (the ddlerp LoRA is kept only for the decay, which is the
paper's headline mechanism); per-head RMS norm in place of GroupNorm.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import rematcfg

Array = jax.Array
LORA_DIM = 64


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _layer_init(key, cfg: ModelConfig, n: int):
    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_size
    H = d // hd
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 12)

    def mat(k, i, o, scale=1.0):
        return L.stacked_dense_init(k, n, i, o, dtype, scale)

    tm = {
        "mu_r": jnp.full((n, d), 0.5, jnp.float32),
        "mu_k": jnp.full((n, d), 0.5, jnp.float32),
        "mu_v": jnp.full((n, d), 0.5, jnp.float32),
        "mu_g": jnp.full((n, d), 0.5, jnp.float32),
        "mu_w": jnp.full((n, d), 0.5, jnp.float32),
        "w0": jnp.full((n, d), -2.0, jnp.float32),   # base decay ~exp(-exp(-2))
        "wA": mat(ks[0], d, LORA_DIM) * 0.1,
        "wB": mat(ks[1], LORA_DIM, d) * 0.1,
        "u": jnp.zeros((n, H, hd), jnp.float32),
        "wr": mat(ks[2], d, d), "wk": mat(ks[3], d, d),
        "wv": mat(ks[4], d, d), "wg": mat(ks[5], d, d),
        "wo": mat(ks[6], d, d, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        "ln_x": jnp.ones((n, d), jnp.float32),
    }
    cm = {
        "mu_r": jnp.full((n, d), 0.5, jnp.float32),
        "mu_k": jnp.full((n, d), 0.5, jnp.float32),
        "wr": mat(ks[7], d, d),
        "wk": mat(ks[8], d, ff),
        "wv": mat(ks[9], ff, d, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }
    return {
        "ln1": jnp.ones((n, d), jnp.float32),
        "ln2": jnp.ones((n, d), jnp.float32),
        "tm": tm, "cm": cm,
    }


def init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "embed": L.embed_init(k1, cfg),
        "blocks": _layer_init(k2, cfg, cfg.n_layers),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# WKV chunked scan
# ---------------------------------------------------------------------------
def _wkv_chunked(r, k, v, lw, u, state, chunk: int):
    """r,k,v: [B,T,H,hd]; lw: [B,T,H,hd] log-decay (<=0); u: [H,hd];
    state: [B,H,hd,hd]. Returns (out [B,T,H,hd], state)."""
    B, T, H, hd = r.shape
    C = min(chunk, T)
    assert T % C == 0
    n = T // C

    def resh(x):  # [B,T,H,hd] -> [n, B, H, C, hd]
        return jnp.moveaxis(x.reshape(B, n, C, H, hd), (1, 3), (0, 2))

    r_, k_, v_, lw_ = resh(r), resh(k), resh(v), resh(lw)

    def body(S, inp):
        rc, kc, vc, lwc = (x.astype(jnp.float32) for x in inp)  # [B,H,C,hd]
        cum = jnp.cumsum(lwc, axis=2)                    # inclusive
        cum_prev = cum - lwc                             # cum_{t-1}
        # intra-chunk pairwise decay D[t,s,d] = exp(cum_prev[t] - cum[s]) s<t
        D = jnp.exp(cum_prev[:, :, :, None, :] - cum[:, :, None, :, :])
        tri = jnp.tril(jnp.ones((C, C), bool), -1)
        D = jnp.where(tri[None, None, :, :, None], D, 0.0)
        A = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rc, kc, D)
        A = A + jnp.einsum("bhtd,bhtd->bht", rc * u[None, :, None, :], kc)[
            ..., None] * jnp.eye(C)[None, None]
        y = jnp.einsum("bhts,bhse->bhte", A, vc)
        # inter-chunk: r'_t = r_t * exp(cum_prev_t) applied to incoming state
        y = y + jnp.einsum("bhtd,bhde->bhte", rc * jnp.exp(cum_prev), S)
        # state update
        cum_last = cum[:, :, -1:, :]
        k_dec = kc * jnp.exp(cum_last - cum)
        S = jnp.exp(cum_last[:, :, 0, :])[..., None] * S + jnp.einsum(
            "bhsd,bhse->bhde", k_dec, vc)
        return S, y

    # remat per chunk: the inner scan's AD would otherwise save the
    # [B,H,C,C,hd] decay tensor for every chunk
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    state, ys = jax.lax.scan(body, state.astype(jnp.float32),
                             (r_, k_, v_, lw_))
    out = jnp.moveaxis(ys, (0, 2), (1, 3)).reshape(B, T, H, hd)
    return out.astype(r.dtype), state


def _wkv_step(r, k, v, lw, u, state):
    """Single decode step. r,k,v,lw: [B,H,hd]; state: [B,H,hd,hd]."""
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    att = state + u[None, :, :, None] * kf[..., None] * vf[..., None, :]
    out = jnp.einsum("bhd,bhde->bhe", rf, att)
    state = jnp.exp(lw.astype(jnp.float32))[..., None] * state + \
        kf[..., None] * vf[..., None, :]
    return out.astype(r.dtype), state


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _shift(x, last):
    """Token shift: previous token's value. last: [B,1,d] carried state."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _time_mix(p, x, cfg, state, chunk=64, single=False):
    B, T, d = x.shape
    hd = cfg.rwkv_head_size
    H = d // hd
    xprev = state["tm_x"][:, None, :] if single else _shift(x, state["tm_x"][:, None, :])

    def lerp(mu):
        return x + (xprev - x) * mu.astype(x.dtype)

    r = lerp(p["mu_r"]) @ p["wr"]
    k = lerp(p["mu_k"]) @ p["wk"]
    v = lerp(p["mu_v"]) @ p["wv"]
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["wg"])
    xw = lerp(p["mu_w"]).astype(jnp.float32)
    lw = -jnp.exp(p["w0"][None, None] +
                  jnp.tanh(xw @ p["wA"].astype(jnp.float32))
                  @ p["wB"].astype(jnp.float32))         # log w_t <= 0

    def heads(t):
        return t.reshape(B, T, H, hd)

    u = p["u"]
    if single:
        o, s_new = _wkv_step(heads(r)[:, 0], heads(k)[:, 0], heads(v)[:, 0],
                             lw.reshape(B, T, H, hd)[:, 0], u, state["wkv"])
        o = o[:, None]
    else:
        o, s_new = _wkv_chunked(heads(r), heads(k), heads(v),
                                lw.reshape(B, T, H, hd), u, state["wkv"],
                                chunk)
    # per-head norm then gate
    o = L.rms_norm(o, jnp.ones((hd,), jnp.float32), cfg.norm_eps)
    o = o.reshape(B, T, d) * p["ln_x"].astype(o.dtype)
    out = (o * g) @ p["wo"]
    new_state = {"wkv": s_new, "tm_x": x[:, -1, :]}
    return out, new_state


def _channel_mix(p, x, state, single=False):
    xprev = state["cm_x"][:, None, :] if single else _shift(x, state["cm_x"][:, None, :])

    def lerp(mu):
        return x + (xprev - x) * mu.astype(x.dtype)

    r = jax.nn.sigmoid(lerp(p["mu_r"]) @ p["wr"])
    k = jnp.square(jax.nn.relu(lerp(p["mu_k"]) @ p["wk"]))
    return r * (k @ p["wv"]), {"cm_x": x[:, -1, :]}


def block_apply(pb, x, cfg, state, *, chunk=64, single=False):
    y, tm_state = _time_mix(pb["tm"], L.rms_norm(x, pb["ln1"], cfg.norm_eps),
                            cfg, state, chunk=chunk, single=single)
    x = x + y
    y, cm_state = _channel_mix(pb["cm"], L.rms_norm(x, pb["ln2"], cfg.norm_eps),
                               state, single=single)
    x = x + y
    return x, {**tm_state, **cm_state}


# ---------------------------------------------------------------------------
# model-level forward
# ---------------------------------------------------------------------------
def init_state(cfg: ModelConfig, batch_size: int, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    H = d // hd
    n = cfg.n_layers
    return {
        "wkv": jnp.zeros((n, batch_size, H, hd, hd), jnp.float32),
        "tm_x": jnp.zeros((n, batch_size, d), dtype),
        "cm_x": jnp.zeros((n, batch_size, d), dtype),
    }


def forward(params, cfg: ModelConfig, ctx, batch, *, mode="train",
            remat=True, caches=None, cur_index=None, chunk=64):
    x = L.embed_apply(params["embed"], batch["tokens"])
    B = x.shape[0]
    state = caches if caches is not None else init_state(cfg, B, x.dtype)
    single = mode == "decode"

    def body(carry, inp):
        x, = carry
        pb, st = inp
        x, st_new = block_apply(pb, x, cfg, st, chunk=chunk, single=single)
        x = jax.lax.with_sharding_constraint(
            x, ctx.sharding(ctx.dp_axes, None, None))
        return (x,), st_new

    if remat:
        body = rematcfg.wrap(body)
    (x,), new_state = jax.lax.scan(body, (x,), (params["blocks"], state))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)
    logits = jax.lax.with_sharding_constraint(
        logits, ctx.sharding(ctx.dp_axes, None, ctx.tp_axis))
    return logits, jnp.float32(0), new_state
