"""Family dispatcher: one API over transformer / rwkv6 / zamba2 backbones.

    params = init(key, cfg)
    logits, aux, _     = apply_train(params, cfg, ctx, batch)
    logits, _, cache   = apply_prefill(params, cfg, ctx, batch)
    logits, _, cache   = apply_decode(params, cfg, ctx, batch, cache, idx)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.meshctx import MeshCtx
from repro.models import hybrid, rwkv6, transformer

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm", "audio")


def init(key, cfg: ModelConfig):
    if cfg.family == "ssm":
        return rwkv6.init(key, cfg)
    if cfg.family == "hybrid":
        return hybrid.init(key, cfg)
    return transformer.init(key, cfg)


def _mod(cfg: ModelConfig):
    if cfg.family == "ssm":
        return rwkv6
    if cfg.family == "hybrid":
        return hybrid
    return transformer


def apply_train(params, cfg, ctx: MeshCtx, batch, remat=True):
    return _mod(cfg).forward(params, cfg, ctx, batch, mode="train",
                             remat=remat)


def apply_prefill(params, cfg, ctx: MeshCtx, batch, remat=True):
    return _mod(cfg).forward(params, cfg, ctx, batch, mode="prefill",
                             remat=remat)


def apply_decode(params, cfg, ctx: MeshCtx, batch, caches, cur_index,
                 remat=False):
    return _mod(cfg).forward(params, cfg, ctx, batch, mode="decode",
                             remat=remat, caches=caches, cur_index=cur_index)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    if cfg.family == "ssm":
        return rwkv6.init_state(cfg, batch_size, jnp.dtype(cfg.dtype))
    if cfg.family == "hybrid":
        return hybrid.init_cache(cfg, batch_size, max_len)
    return transformer.init_cache(cfg, batch_size, max_len)


def loss_fn(params, cfg, ctx, batch, remat=True):
    """Next-token cross-entropy + MoE aux. batch: tokens/embeds + labels?"""
    from repro.models.layers import softmax_cross_entropy
    logits, aux, _ = apply_train(params, cfg, ctx, batch, remat=remat)
    if "labels" in batch:
        labels = batch["labels"]
        mask = jnp.ones(labels.shape, jnp.float32)
        lg = logits
    else:
        tokens = batch["tokens"]
        labels = tokens[:, 1:]
        lg = logits[:, :-1]
        mask = jnp.ones(labels.shape, jnp.float32)
    ce = softmax_cross_entropy(lg, labels, mask)
    return ce + 0.01 * aux, (ce, aux)
