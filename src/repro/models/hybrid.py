"""Zamba2 hybrid [arXiv:2411.15242]: Mamba2 backbone + *shared* attention.

The backbone is ``n_layers`` Mamba2 blocks; a single attention+MLP block
with shared weights is applied after every ``attn_every``-th Mamba layer
(6 application sites for 38 layers / every 6). Mamba segments between the
shared-attention sites are scanned; the shared block is python-unrolled at
its (static) sites. Decode carries O(1) Mamba state + a KV cache per
shared-attention site — the hybrid's long-context story.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import rematcfg
from repro.models import mamba2
from repro.models.transformer import _self_attn

Array = jax.Array


def segments(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """Mamba-layer index ranges between shared-attn sites."""
    out, start = [], 0
    for i in range(cfg.n_layers):
        if (i + 1) % cfg.attn_every == 0:
            out.append((start, i + 1))
            start = i + 1
    if start < cfg.n_layers:
        out.append((start, cfg.n_layers))
    return out


def n_attn_sites(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def init(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    shared = {
        "ln1": jnp.ones((1, cfg.d_model), jnp.float32),
        "attn": L.attn_init(k3, cfg, 1),
        "ln2": jnp.ones((1, cfg.d_model), jnp.float32),
        "mlp": L.ffn_init(k4, cfg, 1),
    }
    shared = jax.tree.map(lambda t: t[0], shared)
    return {
        "embed": L.embed_init(k1, cfg),
        "mamba": mamba2.layer_init(k2, cfg, cfg.n_layers),
        "shared_attn": shared,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ns = n_attn_sites(cfg)
    kv = (ns, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "mamba": mamba2.init_state(cfg, cfg.n_layers, batch_size, dtype),
        "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
    }


def forward(params, cfg: ModelConfig, ctx, batch, *, mode="train",
            remat=True, caches=None, cur_index=None, chunk=64):
    x = L.embed_apply(params["embed"], batch["tokens"])
    B, S = x.shape[:2]
    single = mode == "decode"
    if caches is None:
        caches = {"mamba": mamba2.init_state(cfg, cfg.n_layers, B, x.dtype)}
    mstate = caches["mamba"]
    if single:
        positions = jnp.full((B, 1), cur_index, jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)

    def seg_body(carry, inp):
        x, = carry
        pb, st = inp
        x, st_new = mamba2.block_apply(pb, x, cfg, st, chunk=chunk,
                                       single=single)
        x = jax.lax.with_sharding_constraint(
            x, ctx.sharding(ctx.dp_axes, None, None))
        return (x,), st_new

    if remat:
        seg_body = rematcfg.wrap(seg_body)

    new_mstate_parts = []
    new_k, new_v = [], []
    sh = params["shared_attn"]
    for si, (a, b) in enumerate(segments(cfg)):
        seg_params = jax.tree.map(lambda t: t[a:b], params["mamba"])
        seg_state = jax.tree.map(lambda t: t[a:b], mstate)
        (x,), st_new = jax.lax.scan(seg_body, (x,), (seg_params, seg_state))
        new_mstate_parts.append(st_new)
        if (b % cfg.attn_every) == 0 and b <= n_attn_sites(cfg) * cfg.attn_every:
            site = b // cfg.attn_every - 1
            if single:
                attn_out, (kc, vc) = _self_attn(
                    sh, x, cfg, positions=positions, window=0, mode=mode,
                    cache=(caches["k"][site], caches["v"][site]),
                    cur_index=cur_index)
                new_k.append(kc); new_v.append(vc)
            else:
                attn_out, (k, v) = _self_attn(
                    sh, x, cfg, positions=positions, window=0, mode=mode)
                if mode == "prefill":
                    new_k.append(k); new_v.append(v)
            x = x + attn_out
            x = x + L.ffn_apply(sh["mlp"], L.rms_norm(x, sh["ln2"],
                                                      cfg.norm_eps))

    new_mstate = jax.tree.map(
        lambda *parts: jnp.concatenate(parts, axis=0), *new_mstate_parts)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)
    logits = jax.lax.with_sharding_constraint(
        logits, ctx.sharding(ctx.dp_axes, None, ctx.tp_axis))
    cache_out = {"mamba": new_mstate}
    if new_k:
        cache_out["k"] = jnp.stack(new_k)
        cache_out["v"] = jnp.stack(new_v)
    return logits, jnp.float32(0), cache_out
