"""Performance-variant flags (§Perf hillclimb knobs).

Each flag is an independent, measurable change; the dryrun CLI sets them
via --variant so before/after HLO comparisons are one command apart.

  router_bf16_matmul  (default ON): MoE router as a bf16 matmul with fp32
      accumulation (preferred_element_type) instead of casting activations
      to fp32 — the cast promoted the *residual-stream cotangent* to fp32,
      doubling every cross-layer collective (measured on kimi train_4k).
  sp_residual: keep the residual stream sequence-sharded over the model
      axis between blocks (Megatron-SP style); attention gathers what it
      needs.
  banded_local: gemma3-style local layers use O(S*w) banded attention via
      a static-window superblock scan instead of masked O(S^2).
  seq_shard_attn: shard attention compute over the *sequence* on the model
      axis when head counts don't divide it (qwen2 14H, gemma3 8H,
      musicgen 24H on a 16-way axis) — replicated attention was 16x wasted
      compute.
"""
_FLAGS = {
    "router_bf16_matmul": True,
    "sp_residual": False,
    "banded_local": False,
    "seq_shard_attn": False,
    "a2a_int8": False,
}

VARIANTS = {
    "base": {},
    "spresid": {"sp_residual": True},
    "banded": {"banded_local": True, "seq_shard_attn": True},
    "seqattn": {"seq_shard_attn": True},
    "a2aint8": {"sp_residual": True, "a2a_int8": True},
    "compressed": {},   # int8 pod-axis gradient all-reduce (dryrun --compress)
    "allopt": {"sp_residual": True, "banded_local": True,
               "seq_shard_attn": True, "a2a_int8": True},
    "paperfaithful": {"router_bf16_matmul": False},
}


def set_flags(**kw):
    for k, v in kw.items():
        assert k in _FLAGS, k
        _FLAGS[k] = v


def set_variant(name: str):
    reset()
    set_flags(**VARIANTS[name])


def reset():
    _FLAGS.update(router_bf16_matmul=True, sp_residual=False,
                  banded_local=False, seq_shard_attn=False, a2a_int8=False)


def flag(name: str) -> bool:
    return _FLAGS[name]
