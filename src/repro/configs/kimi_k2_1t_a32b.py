"""Kimi K2 — trillion-param MoE [arXiv:2501.kimi2].

Assigned: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert)
vocab=163840, MoE 384 experts top-8.
Unlisted details follow the public Kimi-K2 card: 1 shared expert, first
layer dense (d_ff 18432), head_dim 128.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=2048, vocab_size=163_840,
        n_experts=384, top_k=8, n_shared_experts=1,
        first_k_dense=1, d_ff_dense=18_432,
        rope_theta=50_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256,
        n_experts=8, top_k=2, n_shared_experts=1,
        first_k_dense=1, d_ff_dense=128,
    )
