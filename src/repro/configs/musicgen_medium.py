"""MusicGen medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

Assigned: 48L d_model=1536 24H (GQA kv=24 = MHA) d_ff=6144 vocab=2048.
head_dim 64; 2-matrix GELU FFN (MusicGen uses a plain transformer MLP).
The EnCodec frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings; the backbone predicts codec tokens (vocab
2048). Single-stream channel (delay-pattern interleave is a data-layout
concern outside the backbone — DESIGN.md §14).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab_size=2048,
        ffn_kind="gelu", embeds_input=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64,
        ffn_kind="gelu", embeds_input=True,
    )
