"""Llama 3.2 Vision 90B — cross-attn image layers [hf:meta-llama/Llama-3.2-*-Vision].

Assigned: 100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Structure: 80 self-attention layers with a cross-attention layer inserted
after every 4th (20 sites) = 100 layers total. The vision encoder is a STUB
per the assignment: input_specs() provides precomputed patch embeddings
(n_image_tokens × d_model).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=28_672, vocab_size=128_256,
        cross_attn_every=4, n_image_tokens=1600,
        rope_theta=500_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-smoke", family="vlm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        cross_attn_every=2, n_image_tokens=16,
    )
