from repro.configs.base import (
    ModelConfig, OptimizerConfig, TrainConfig, ShapeSpec, SHAPES,
    shape_applicable,
)
from repro.configs.registry import ARCH_NAMES, get_config, get_smoke_config

__all__ = [
    "ModelConfig", "OptimizerConfig", "TrainConfig", "ShapeSpec", "SHAPES",
    "shape_applicable", "ARCH_NAMES", "get_config", "get_smoke_config",
]
