"""Gemma 3 4B — 5:1 local:global attention, 128k context [hf:google/gemma-3-*].

Assigned: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
head_dim 256 (Gemma3); sliding window 1024 on local layers, every 6th layer
global; qk-norm; tied embeddings. Qualifies for long_500k via the 5:1
local:global pattern (DESIGN.md §9).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=10_240, vocab_size=262_144,
        qk_norm=True, tie_embeddings=True,
        sliding_window=1024, local_global_ratio=5,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        qk_norm=True, tie_embeddings=True,
        sliding_window=16, local_global_ratio=2,
    )
