"""The paper's own workload configs (§IV): sparse pattern search.

Not an LM architecture — these configure the sparse pattern engine.
Numbers from the paper: vocab ~141k words, ~60 nnz/doc (0.04% sparsity),
query memory 2K nnz (8 KB BRAM), 8 kernels / 2 GB/s flash baseline and the
optimized 20-kernel / 3-query-batch variant (Table 2).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    name: str
    vocab_size: int = 141_000       # prominent-word bag size (§V.C)
    avg_nnz_per_doc: int = 60       # 0.04% sparsity (§V.C)
    max_query_nnz: int = 2048       # 8 KB query memory (§IV.A)
    doc_tile: int = 128             # ELL tile rows (documents per tile)
    nnz_pad: int = 128              # ELL row width (padded nnz per doc)
    query_batch: int = 1            # L in the paper's K*L kernel grid
    top_k: int = 16                 # results reported to host
    # kernel tiling (VMEM working set; DESIGN.md §11)
    block_docs: int = 128
    block_query: int = 512


def baseline() -> SearchConfig:
    """8-kernel / single-query configuration (paper Table 2 row 1)."""
    return SearchConfig(name="paper-baseline", query_batch=1)


def optimized() -> SearchConfig:
    """20-kernel / 3-query-batch configuration (paper Table 2 row 2)."""
    return SearchConfig(name="paper-optimized", query_batch=3)


def smoke() -> SearchConfig:
    return SearchConfig(
        name="paper-smoke", vocab_size=512, avg_nnz_per_doc=12,
        max_query_nnz=64, doc_tile=16, nnz_pad=16, top_k=4,
        block_docs=16, block_query=32)
