"""Zamba2 1.2B — Mamba2 backbone + shared attention block [arXiv:2411.15242].

Assigned: 38L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=32000,
ssm_state=64. The 38 layers are Mamba2 blocks (no per-layer FFN); one
*shared* attention+MLP block (d_ff 8192) is applied every 6th layer with
shared weights (per-application LoRA deltas omitted — DESIGN.md §14).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=32_000,
        ssm_state=64, ssm_headdim=64, d_inner_mult=2, attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        ssm_state=16, ssm_headdim=16, d_inner_mult=2, attn_every=2,
    )
