"""InternLM2 20B — GQA [arXiv:2403.17297].

Assigned: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
head_dim 128.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16_384, vocab_size=92_544,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
    )
