"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_MODULES: Dict[str, str] = {
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "llama-3.2-vision-90b": "repro.configs.llama32_vision_90b",
    "qwen2-0.5b": "repro.configs.qwen2_0p5b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "musicgen-medium": "repro.configs.musicgen_medium",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).config()


def get_smoke_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).smoke_config()
