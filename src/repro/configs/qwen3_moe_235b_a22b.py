"""Qwen3-MoE 235B-A22B — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family].

Assigned: 94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert)
vocab=151936, MoE 128e top-8. qk_norm per Qwen3; head_dim 128; no shared
expert, every layer MoE (Qwen3-MoE has no leading dense layers).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab_size=151_936,
        n_experts=128, top_k=8, qk_norm=True,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256,
        n_experts=4, top_k=2, qk_norm=True,
    )
