"""RWKV-6 "Finch" 7B — attention-free, data-dependent decay [arXiv:2404.05892].

Assigned: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.
Head size 64 (64 heads); channel-mix uses the RWKV r/k/v form.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
        d_ff=14_336, vocab_size=65_536,
        rwkv_head_size=64, ffn_kind="rwkv",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        rwkv_head_size=16, ffn_kind="rwkv",
    )
