"""Qwen2 0.5B — GQA with QKV bias [arXiv:2407.10671].

Assigned: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
head_dim 64; tied embeddings (per the released model).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
        d_ff=4864, vocab_size=151_936,
        qkv_bias=True, tie_embeddings=True,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        qkv_bias=True, tie_embeddings=True,
    )
