"""Configuration dataclasses for models, shapes, meshes and training.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeSpec`` entries in ``SHAPES``. Configs are
plain frozen dataclasses so they hash/compare and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (superset across the 10 assigned archs)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention details -------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # >0: window size for local layers
    local_global_ratio: int = 0    # gemma3: N local layers per 1 global
    attn_logit_softcap: float = 0.0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0         # leading dense layers before MoE layers
    d_ff_dense: int = 0            # d_ff of the dense layers in an MoE model
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM / linear-attention ----------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    d_inner_mult: int = 2
    attn_every: int = 0            # zamba2: shared attn block every N layers
    rwkv_head_size: int = 64

    # --- multimodal -----------------------------------------------------------
    cross_attn_every: int = 0      # vlm: insert a cross-attn layer after every N
    n_image_tokens: int = 0
    embeds_input: bool = False     # audio/vlm stub frontend: embeddings in

    # --- ffn -------------------------------------------------------------------
    ffn_kind: str = "swiglu"       # swiglu | gelu (2-matrix) | rwkv (r,k,v mix)

    # --- numerics --------------------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ----------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.d_inner_mult * self.d_model

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic rule for the long_500k shape (see DESIGN.md)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # gemma3-style mostly-local attention qualifies (5:1 local:global).
        return self.local_global_ratio > 0 and self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS roofline terms)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        return _param_count(self, active_only=True)


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    if cfg.ffn_kind == "gelu":      # up + down
        return 2 * cfg.d_model * d_ff
    if cfg.ffn_kind == "rwkv":      # receptance (d,d) + key (d,ff) + value (ff,d)
        return cfg.d_model * cfg.d_model + 2 * cfg.d_model * d_ff
    return 3 * cfg.d_model * d_ff   # swiglu: gate + up + down


def _attn_params(cfg: ModelConfig) -> int:
    p = cfg.d_model * cfg.q_dim + 2 * cfg.d_model * cfg.kv_dim + cfg.q_dim * cfg.d_model
    if cfg.qkv_bias:
        p += cfg.q_dim + 2 * cfg.kv_dim
    return p


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    emb = cfg.vocab_size * d
    head = 0 if cfg.tie_embeddings else cfg.vocab_size * d
    total = emb + head + d  # final norm

    if cfg.family == "ssm":  # rwkv6
        H = d // cfg.rwkv_head_size
        per_layer = (
            5 * d * d          # r,k,v,g,o projections
            + 6 * d            # token-shift lerp mus (r,k,v,g,w + x)
            + 2 * 64 * d       # w lora (d->64->d)
            + d                # u bonus
            + H * cfg.rwkv_head_size  # group-norm scale approx
            + _ffn_params(cfg, cfg.d_ff)
            + 2 * d            # norms
        )
        return total + cfg.n_layers * per_layer

    if cfg.family == "hybrid":  # zamba2: mamba2 layers + one shared attn block
        d_in = cfg.d_inner
        nh = d_in // cfg.ssm_headdim
        # Zamba2 mamba blocks carry no per-layer FFN; the shared attention
        # block owns the MLP (matches the 1.2B total).
        per_mamba = (
            d * d_in * 2       # in proj -> x, z
            + d * (2 * cfg.ssm_state + nh)  # B, C, dt projections
            + nh * 2           # A_log, D
            + d_in             # dt bias
            + d_in * d         # out proj
            + d                # norm
        )
        shared_attn = _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff) + 2 * d
        return total + cfg.n_layers * per_mamba + shared_attn

    # transformer families
    per_layer = _attn_params(cfg) + 2 * d
    if cfg.qk_norm:
        per_layer += 2 * cfg.head_dim
    n_moe_layers = 0
    if cfg.n_experts > 0:
        n_moe_layers = cfg.n_layers - cfg.first_k_dense
        d_ff_dense = cfg.d_ff_dense or cfg.d_ff
        total += cfg.first_k_dense * _ffn_params(cfg, d_ff_dense)
        router = cfg.d_model * cfg.n_experts
        experts = cfg.n_experts * _ffn_params(cfg, cfg.d_ff)
        shared = cfg.n_shared_experts * _ffn_params(cfg, cfg.d_ff)
        if active_only:
            experts = cfg.top_k * _ffn_params(cfg, cfg.d_ff)
        total += n_moe_layers * (router + experts + shared)
    else:
        total += cfg.n_layers * _ffn_params(cfg, cfg.d_ff)
    total += cfg.n_layers * per_layer

    if cfg.cross_attn_every > 0:  # vlm: extra cross-attn blocks
        n_cross = cfg.n_layers // (cfg.cross_attn_every + 1)
        total += n_cross * (_attn_params(cfg) + _ffn_params(cfg, cfg.d_ff) + 2 * d)
    return total


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "long_500k skipped: pure full-attention arch (DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# Training / runtime configs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    int8_states: bool = False       # quantized Adam m/v (distributed-memory trick)
    grad_compression: bool = False  # int8 gradient all-reduce w/ error feedback


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    opt: OptimizerConfig = OptimizerConfig()
    seq_len: int = 4096
    global_batch: int = 256
    microbatches: int = 1
    remat: bool = True
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
