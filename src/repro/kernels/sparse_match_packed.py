"""Packed-format sparse match kernel (beyond-paper optimization, §Perf C3).

The baseline kernel streams ELL (id int32, val float32) pairs = 8 B/nnz.
This variant keeps the corpus in HBM in (a tiled version of) the paper's
own Fig. 8 32-bit packing — [wordID:19 | count:12] with the top bit clear,
sentinel 0xFFFFFFFF for padding — and unpacks in-kernel with VPU
shifts/masks. 4 B/nnz halves HBM traffic per document; in the memory-bound
single-query regime that is a straight 2x docs/s.

The merge-join -> match-matrix reformulation is unchanged; only the
operand encoding differs. ops.correlate(backend="pallas_packed") wraps it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

Array = jax.Array

KEY_BITS = 19
VAL_BITS = 12
VAL_MASK = (1 << VAL_BITS) - 1
PAD_WORD = np.uint32(0xFFFFFFFF)


def pack(ids: Array, vals: Array) -> Array:
    """ELL (ids int32 -1-padded, vals float32 integral counts) -> uint32."""
    ids = np.asarray(ids)
    vals = np.asarray(vals)
    counts = np.clip(vals, 0, VAL_MASK).astype(np.uint32)
    packed = (ids.astype(np.int64) << VAL_BITS).astype(np.uint32) | counts
    return np.where(ids < 0, PAD_WORD, packed)


def _kernel(docs_ref, q_ids_ref, q_vals_ref, out_ref):
    j = pl.program_id(1)
    td, k = docs_ref.shape
    tq, l = q_vals_ref.shape

    packed = docs_ref[...].reshape(td * k)
    d_ids = (packed >> VAL_BITS).astype(jnp.int32)       # 0x7FFFF+ for pads
    d_vals = (packed & VAL_MASK).astype(jnp.float32)
    valid = packed != jnp.uint32(0xFFFFFFFF)
    d_ids = jnp.where(valid, d_ids, -1)

    eq = (d_ids[:, None] == q_ids_ref[...].reshape(1, tq)).astype(jnp.float32)
    matched = jnp.dot(eq, q_vals_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)  # [TD*K, L]
    pp = jnp.where(valid[:, None], d_vals[:, None] * matched, 0.0)
    scores = pp.reshape(td, k, l).sum(axis=1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = scores

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += scores


@functools.partial(jax.jit, static_argnames=("block_docs", "block_query",
                                             "interpret"))
def sparse_match_packed(docs_packed: Array, q_ids: Array, q_vals: Array, *,
                        block_docs: int = 128, block_query: int = 512,
                        interpret: bool = False) -> Array:
    """docs_packed: [D, K] uint32 (Fig. 8 word packing); q_ids: [Qm]
    (pad -2); q_vals: [Qm, L]. Returns correlation scores [D, L]."""
    D, K = docs_packed.shape
    Qm, L_ = q_vals.shape
    td = min(block_docs, D)
    tq = min(block_query, Qm)
    assert D % td == 0 and Qm % tq == 0, (D, td, Qm, tq)
    grid = (D // td, Qm // tq)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((td, K), lambda i, j: (i, 0)),
            pl.BlockSpec((tq,), lambda i, j: (j,)),
            pl.BlockSpec((tq, L_), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((td, L_), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((D, L_), jnp.float32),
        interpret=interpret,
    )(docs_packed, q_ids, q_vals)
