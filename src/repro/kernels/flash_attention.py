"""Pallas TPU flash-attention (forward) — the LM-side compute hot-spot.

The dry-run/roofline path deliberately uses the pure-jnp custom-VJP flash
attention (models/layers.py) so XLA's cost analysis sees real FLOPs; this
kernel is the TPU-target drop-in for serving, with explicit BlockSpec VMEM
tiling and running-softmax accumulators in VMEM scratch. Validated in
interpret mode against the naive oracle (tests/test_flash_kernel.py).

Tiling: grid (batch*heads, q_blocks, kv_blocks); per (b, i) the scratch
carries (m, l, acc) across the kv_block axis; causal blocks above the
diagonal are skipped with pl.when (no FLOPs, no DMA dependency on compute).
Working set per step: q tile bq x hd + kv tiles bk x hd + p tile bq x bk
(fp32) — (256, 512, 128): 0.6 MB, far under VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            causal: bool, scale: float, bq: int, bk: int, nkv: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    run = (not causal) or (j * bk <= i * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # [bq, bk]
        if causal:
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + p.sum(axis=1)
        acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(j == nkv - 1)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    block_q: int = 256, block_kv: int = 512,
                    interpret: bool = False) -> Array:
    """q, k, v: [BH, S, hd] (GQA callers expand kv heads in the wrapper).
    Returns [BH, S, hd]."""
    BH, S, hd = q.shape
    bq = min(block_q, S)
    bk = min(block_kv, S)
    if S % bq:
        bq = math.gcd(bq, S)
    if S % bk:
        bk = math.gcd(bk, S)
    nq, nkv = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(_kernel, causal=causal, scale=scale, bq=bq,
                             bk=bk, nkv=nkv)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_gqa(q: Array, k: Array, v: Array, *, causal: bool = True,
                        interpret: bool = False) -> Array:
    """Convenience GQA wrapper. q: [B, S, H, hd]; k, v: [B, S, KV, hd]."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, hd)
    o = flash_attention(qf, kf, vf, causal=causal, interpret=interpret)
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
