"""Pure-jnp oracle for the sparse_match kernel.

Scores by dense scatter (exact, no sentinel subtleties): each query becomes
a dense vocab vector; a document's partial products are gathers at its ELL
ids. Returns raw correlation scores (cosine numerator); normalization is
applied by ops.cosine_scores in both paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def dense_query(q_ids: Array, q_vals: Array, vocab_size: int) -> Array:
    """q_ids: [Qm] int32 (pad < 0), q_vals: [Qm, L] -> [V, L]."""
    safe = jnp.clip(q_ids, 0, vocab_size - 1)
    valid = (q_ids >= 0)[:, None]
    return jnp.zeros((vocab_size, q_vals.shape[1]), jnp.float32).at[safe].add(
        jnp.where(valid, q_vals.astype(jnp.float32), 0.0))


def sparse_match_ref(doc_ids: Array, doc_vals: Array, q_ids: Array,
                     q_vals: Array, vocab_size: int) -> Array:
    """doc_ids/doc_vals: [D, K] (-1 pad); q_ids: [Qm]; q_vals: [Qm, L].
    Returns correlation scores [D, L] (fp32)."""
    qd = dense_query(q_ids, q_vals, vocab_size)          # [V, L]
    safe = jnp.clip(doc_ids, 0, vocab_size - 1)
    gathered = qd[safe]                                   # [D, K, L]
    valid = (doc_ids >= 0)[..., None]
    pp = jnp.where(valid, doc_vals[..., None].astype(jnp.float32) * gathered,
                   0.0)
    return pp.sum(axis=1)                                 # [D, L]


def partial_product_count(doc_ids: Array, doc_vals: Array, q_ids: Array,
                          q_vals: Array, vocab_size: int) -> Array:
    """Number of nonzero partial products (the paper's §V.C throughput
    metric: 13M pp/s on the baseline slice)."""
    qmask = dense_query(q_ids, (q_vals != 0).astype(jnp.float32), vocab_size)
    safe = jnp.clip(doc_ids, 0, vocab_size - 1)
    hit = (qmask[safe] > 0) & (doc_ids >= 0)[..., None] & \
        (doc_vals != 0)[..., None]
    return hit.sum()
