"""Pallas TPU kernel: sparse pattern matching (the paper's Key Comparator +
Distance Accumulator, fused — DESIGN.md §11).

The FPGA's sequential merge-join becomes a *match matrix* on the MXU: for a
document ELL tile (ids, vals) and a (merged multi-query) id/value tile,

    eq[dk, q]   = (doc_ids[dk] == q_ids[q])          # Key Comparator
    matched     = eq @ q_vals                         # [TD*K, L]
    scoresΔ     = sum_K (doc_vals ⊙ matched)          # Distance Accumulator

Query batching (the paper's L dimension, §II.A / Table 2) appears as the L
value-columns of the merged query stream: one id stream, L value columns,
raising arithmetic intensity exactly like the paper's 20-kernel / 3-query
configuration.

Grid: (doc_tiles, query_tiles); the query tile (the paper's 8 KB "query
memory") is pinned in VMEM per BlockSpec, document tiles stream through
VMEM double-buffered by the Pallas pipeline (the prefetch-predictor
analogue — no rewind exists in this formulation, so there is nothing to
mispredict).

Sentinels: document padding is -1, query padding is -2 — they never match
each other or real ids.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DOC_PAD = -1
QUERY_PAD = -2


def _kernel(doc_ids_ref, doc_vals_ref, q_ids_ref, q_vals_ref, out_ref):
    j = pl.program_id(1)
    td, k = doc_ids_ref.shape
    tq, l = q_vals_ref.shape

    d_ids = doc_ids_ref[...].reshape(td * k, 1)
    q_ids = q_ids_ref[...].reshape(1, tq)
    eq = (d_ids == q_ids).astype(jnp.float32)               # [TD*K, TQ]
    matched = jnp.dot(eq, q_vals_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)    # [TD*K, L]
    pp = doc_vals_ref[...].astype(jnp.float32).reshape(td * k, 1) * matched
    scores = pp.reshape(td, k, l).sum(axis=1)                # [TD, L]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = scores

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += scores


@functools.partial(jax.jit, static_argnames=("block_docs", "block_query",
                                             "interpret"))
def sparse_match(doc_ids: Array, doc_vals: Array, q_ids: Array,
                 q_vals: Array, *, block_docs: int = 128,
                 block_query: int = 512, interpret: bool = False) -> Array:
    """doc_ids/doc_vals: [D, K]; q_ids: [Qm]; q_vals: [Qm, L].
    D % block_docs == 0 and Qm % block_query == 0 (ops.py pads).
    Returns correlation scores [D, L] fp32."""
    D, K = doc_ids.shape
    Qm, L_ = q_vals.shape
    td = min(block_docs, D)
    tq = min(block_query, Qm)
    assert D % td == 0 and Qm % tq == 0, (D, td, Qm, tq)
    grid = (D // td, Qm // tq)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((td, K), lambda i, j: (i, 0)),
            pl.BlockSpec((td, K), lambda i, j: (i, 0)),
            pl.BlockSpec((tq,), lambda i, j: (j,)),
            pl.BlockSpec((tq, L_), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((td, L_), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((D, L_), jnp.float32),
        interpret=interpret,
    )(doc_ids, doc_vals, q_ids, q_vals)
