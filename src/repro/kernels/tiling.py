"""Tiling strategies for the fused kernel (DESIGN.md §12.3).

The fused kernel's grid is (doc tiles, query tiles); its VMEM working
set per grid step is the packed doc tile (``block_docs * (1 + nnz_pad)``
uint32 words), the query tile (``block_query`` ids + ``block_query * L``
values), and the persistent correlation scratch (``block_docs * L``
fp32). The right shapes therefore depend on *corpus density* (nnz_pad:
denser docs want narrower doc tiles) and on the *L bucket* (wider
batches want narrower query tiles) — knobs the static SearchConfig
cannot see per query.

Strategy classes make the choice explicit and testable:

  - ``FixedTiling`` — always the config's ``block_docs``/``block_query``
    (the staged kernels' behavior; the default, so fused and staged
    share program-shape families);
  - ``AutoTiling`` — fits the working set to a VMEM budget, shrinking
    ``block_docs`` for dense corpora and ``block_query`` for wide L
    buckets, always in power-of-two steps so every chosen query tile
    divides the §7 merged-stream capacity.

The query-side choice is **memoized per L bucket**: for one strategy
instance, ``query_tile(Lp)`` is a pure function of the bucket, so the
autotuner can never add program shapes beyond the existing
``log2(max_batch) + 1`` compile-cache bound — one (Lp, Q-capacity)
bucket still maps to exactly one program (tests/test_tiling.py pins
this). The doc-side choice is made **once per corpus scope** (engine
construction), because it is part of the packed-slab layout and the
slab-cache key — re-tiling mid-session would orphan every cached slab.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

DEFAULT_VMEM_BUDGET = 4 * 1024 * 1024   # bytes; ~25% of a TPU core's VMEM


def _pow2_floor(n: int) -> int:
    return 1 << max(int(n).bit_length() - 1, 0)


@dataclasses.dataclass(frozen=True)
class TileShape:
    """One resolved (doc, query) tile pair for a fused program."""
    block_docs: int
    block_query: int


class TilingStrategy:
    """Base: ``doc_tile`` once per corpus, ``query_tile`` per L bucket.

    Subclasses implement ``_doc_tile`` / ``_query_tile``; the base class
    owns the per-bucket memo table that the compile-cache invariant
    leans on (``bucket_shapes`` exposes it to tests and telemetry)."""

    def __init__(self):
        self._bucket_memo: Dict[int, int] = {}

    # -- corpus-scope choice (fixed for the engine's lifetime) ---------
    def doc_tile(self, *, nnz_pad: int, n_docs: int) -> int:
        bd = int(self._doc_tile(nnz_pad=nnz_pad, n_docs=max(n_docs, 1)))
        if bd < 1:
            raise ValueError(f"doc_tile must be >= 1, got {bd}")
        return bd

    # -- bucket-scope choice (memoized: one shape per L bucket) --------
    def query_tile(self, Lp: int) -> int:
        tq = self._bucket_memo.get(Lp)
        if tq is None:
            tq = int(self._query_tile(Lp=max(Lp, 1)))
            if tq < 1:
                raise ValueError(f"query_tile must be >= 1, got {tq}")
            self._bucket_memo[Lp] = tq
        return tq

    @property
    def bucket_shapes(self) -> Dict[int, int]:
        """L bucket -> chosen query tile, for every bucket seen so far.
        len(bucket_shapes) bounds the strategy's contribution to the
        program count: one entry, one (Lp, tq) family."""
        return dict(self._bucket_memo)

    def _doc_tile(self, *, nnz_pad: int, n_docs: int) -> int:
        raise NotImplementedError

    def _query_tile(self, *, Lp: int) -> int:
        raise NotImplementedError


class FixedTiling(TilingStrategy):
    """The config's static shapes, for every density and bucket — fused
    programs then live in the same shape families as the staged
    kernels'."""

    def __init__(self, block_docs: int, block_query: int):
        super().__init__()
        if block_docs < 1 or block_query < 1:
            raise ValueError("tile sides must be >= 1")
        self.block_docs = int(block_docs)
        self.block_query = int(block_query)

    def _doc_tile(self, *, nnz_pad: int, n_docs: int) -> int:
        return self.block_docs

    def _query_tile(self, *, Lp: int) -> int:
        return self.block_query


class AutoTiling(TilingStrategy):
    """Budget-driven shapes. Doc side: the largest power-of-two tile
    whose packed words + correlation scratch (at the reference L) fit
    half the budget — dense corpora (large nnz_pad) get narrower tiles.
    Query side: the largest power-of-two divisor of ``block_query``
    whose id+value tile fits the other half at the bucket's L — wide
    buckets get narrower query tiles (more grid steps, same VMEM).

    Both sides clamp to the config's static shapes as upper bounds, so
    AutoTiling only ever *shrinks* tiles — the merged-stream capacity
    (a multiple of ``block_query``) stays divisible by every choice.
    """

    def __init__(self, block_docs: int, block_query: int, *,
                 vmem_budget: int = DEFAULT_VMEM_BUDGET, ref_L: int = 8):
        super().__init__()
        if block_docs < 1 or block_query < 1:
            raise ValueError("tile sides must be >= 1")
        if vmem_budget < 4096:
            raise ValueError("vmem_budget unrealistically small")
        self.block_docs = int(block_docs)
        self.block_query = int(block_query)
        self.vmem_budget = int(vmem_budget)
        self.ref_L = int(ref_L)

    def _doc_tile(self, *, nnz_pad: int, n_docs: int) -> int:
        # per doc row: (1 + nnz_pad) packed words + ref_L fp32 scratch
        row_bytes = 4 * (1 + nnz_pad + self.ref_L)
        fit = _pow2_floor(max((self.vmem_budget // 2) // row_bytes, 1))
        return max(min(fit, self.block_docs, _pow2_floor(n_docs) * 2), 8)

    def _query_tile(self, *, Lp: int) -> int:
        # per query item: one id word + Lp fp32 value columns
        item_bytes = 4 * (1 + Lp)
        fit = _pow2_floor(max((self.vmem_budget // 2) // item_bytes, 1))
        tq = self.block_query
        while tq >= 16 and tq > fit:
            tq //= 2          # power-of-two descent: tq | block_query,
        return tq             # floored so it never halves below 8
