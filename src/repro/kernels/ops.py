"""jit'd public wrappers around the sparse_match kernel family.

Handles padding to tile multiples, merged multi-query streams, sentinel
conventions and cosine normalization. ``backend``:
  - "pallas": the TPU kernel (interpret=True on CPU — used by tests)
  - "jnp":    gather-based scoring (engine default on CPU; also the
              in-memory CPU baseline of the paper's Fig. 13)
  - "pallas_packed": the Fig. 8 packed-word kernel (uint32 corpus)
  - "pallas_fused": decode+match+top-k in one kernel over packed doc
    tiles — wrapped by ``fused_topk`` (DESIGN.md §12), which returns
    folded [L, k] winners instead of a correlation matrix
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topk import fold_topk
from repro.kernels import ref as ref_mod
from repro.kernels.fused import fused_match_topk
from repro.kernels.sparse_match import sparse_match, QUERY_PAD
from repro.kernels.sparse_match_packed import sparse_match_packed

Array = jax.Array


def _pad_to(x: Array, n: int, axis: int, fill) -> Array:
    need = n - x.shape[axis]
    if need <= 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, need)
    return jnp.pad(x, pads, constant_values=fill)


def merge_queries(q_ids: np.ndarray, q_vals: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Stack L queries ([L, Qn] ids, [L, Qn] vals, pad<0) into one merged
    id stream with L value columns: ids [Qm], vals [Qm, L].

    Rows with zero non-pad terms simply contribute no items (their value
    column stays all-zero, so they score 0 against everything), and an
    empty batch (L = 0, or every row empty) yields the well-defined
    zero-length stream — not a concatenate error."""
    L_, _ = q_ids.shape
    if L_ == 0:
        return np.empty(0, np.int32), np.zeros((0, 0), np.float32)
    ids_out, vals_out = [], []
    for l in range(L_):
        keep = q_ids[l] >= 0
        ids_out.append(q_ids[l][keep])
        v = np.zeros((keep.sum(), L_), np.float32)
        v[:, l] = q_vals[l][keep]
        vals_out.append(v)
    ids = np.concatenate(ids_out).astype(np.int32)
    vals = np.concatenate(vals_out, axis=0)
    order = np.argsort(ids, kind="stable")
    return ids[order], vals[order]


@functools.partial(jax.jit, static_argnames=("backend", "block_docs",
                                             "block_query", "vocab_size"))
def correlate(doc_ids: Array, doc_vals: Array, q_ids: Array, q_vals: Array,
              *, backend: str = "jnp", vocab_size: int = 0,
              block_docs: int = 128, block_query: int = 512) -> Array:
    """Correlation (cosine numerator) [D, L]."""
    D = doc_ids.shape[0]
    L_ = q_vals.shape[1]
    if D == 0 or L_ == 0:
        # degenerate program shapes (empty corpus / empty batch): the
        # well-defined zero correlation, not an empty-grid kernel launch
        return jnp.zeros((D, L_), jnp.float32)
    if backend in ("pallas", "pallas_packed"):
        Qm = q_ids.shape[0]
        td = min(block_docs, max(D, 8))
        tq = min(block_query, max(Qm, 8))
        Dp = -(-D // td) * td
        # a zero-length merged stream (every query row empty) still pads
        # to one full query tile: the kernel then scores all-pad items
        # to the all-zero row instead of launching an empty grid whose
        # output would be uninitialized
        Qp = max(-(-Qm // tq) * tq, tq)
        qi = _pad_to(q_ids, Qp, 0, QUERY_PAD)
        qv = _pad_to(q_vals, Qp, 0, 0.0)
        # query padding might collide with doc padding sentinel: remap
        qi = jnp.where(qi < 0, QUERY_PAD, qi)
        interpret = jax.default_backend() != "tpu"
        if backend == "pallas_packed":
            # doc_ids here is the packed uint32 corpus (Fig. 8 in HBM);
            # the pad sentinel must be a uint32 scalar — a bare python
            # 0xFFFFFFFF overflows jnp.pad's int32 weak-type parsing
            # whenever D is not a block multiple
            dp = _pad_to(doc_ids, Dp, 0, np.uint32(0xFFFFFFFF))
            out = sparse_match_packed(dp, qi, qv, block_docs=td,
                                      block_query=tq, interpret=interpret)
            return out[:D]
        di = _pad_to(doc_ids, Dp, 0, -1)
        dv = _pad_to(doc_vals, Dp, 0, 0.0)
        out = sparse_match(di, dv, qi, qv, block_docs=td, block_query=tq,
                           interpret=interpret)
        return out[:D]
    assert vocab_size > 0, "jnp backend needs vocab_size"
    qi = jnp.where(q_ids < 0, -1, q_ids)
    return ref_mod.sparse_match_ref(doc_ids, doc_vals, qi, q_vals, vocab_size)


def cosine_scores(corr: Array, doc_norms: Array, q_norms: Array) -> Array:
    """corr: [D, L]; doc_norms: [D]; q_norms: [L] -> cosine in [-1, 1]."""
    denom = doc_norms[:, None] * q_norms[None, :]
    return jnp.where(denom > 0, corr / jnp.maximum(denom, 1e-12), -jnp.inf)


@functools.partial(jax.jit, static_argnames=("k", "block_docs",
                                             "block_query"))
def fused_topk(tiles: Array, q_ids: Array, q_vals: Array, q_norms: Array,
               *, k: int, block_docs: int, block_query: int = 512
               ) -> Tuple[Array, Array]:
    """The ``pallas_fused`` scoring surface: packed doc tiles ([T, cap]
    uint32 from ``kernels.fused.tile_stream``) + merged query stream ->
    folded (vals [L, k], ids [L, k]) winners. One kernel replaces the
    decode -> correlate -> local_topk dispatch chain (DESIGN.md §12).

    Each doc tile emits its best ``min(k, block_docs)`` candidates —
    never explicit pad entries mid-stream — and the fold concatenates
    them in tile order, so ties resolve exactly as a flat global top_k
    over document rows would (see ``core.topk.fold_topk``)."""
    T = tiles.shape[0]
    L_ = q_vals.shape[1]
    kp = min(k, block_docs)
    if T == 0 or L_ == 0:
        # empty corpus / empty batch: the same (-inf, -1) no-result rows
        # the staged path's local_topk padding produces
        return (jnp.full((L_, k), -jnp.inf, jnp.float32),
                jnp.full((L_, k), -1, jnp.int32))
    Qm = q_ids.shape[0]
    tq = min(block_query, max(Qm, 8))
    Qp = max(-(-Qm // tq) * tq, tq)      # >= one tile even when Qm == 0
    qi = _pad_to(q_ids, Qp, 0, QUERY_PAD)
    qi = jnp.where(qi < 0, QUERY_PAD, qi)
    qv = _pad_to(q_vals, Qp, 0, 0.0)
    interpret = jax.default_backend() != "tpu"
    pv, pi = fused_match_topk(tiles, qi, qv, q_norms,
                              block_docs=block_docs, kp=kp,
                              block_query=tq, interpret=interpret)
    # concatenate per-tile candidates in tile order, then fold to k
    cv = jnp.transpose(pv, (1, 0, 2)).reshape(L_, T * kp)
    ci = jnp.transpose(pi, (1, 0, 2)).reshape(L_, T * kp)
    return fold_topk(cv, ci, k)
