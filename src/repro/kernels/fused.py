"""Fused decode + match + partial-top-k Pallas kernel (DESIGN.md §12).

The paper's accelerator wins by *fusion*: the flash interface logic
decodes the Fig. 8 stream, the key comparator + distance accumulator
match it, and only high-score document ids leave the chip — one pass,
no intermediate materialization. The staged software path still runs

    decode_to_ell (host numpy) -> correlate (kernel) -> local_topk

as three dispatches with a host-resident ELL intermediate ([D, K] int32
ids + [D, K] float32 vals + norms) between the first two. This module
collapses the chain into one ``pallas_call`` over the packed uint32
stream itself:

  - **decode** — in-kernel VPU shifts/masks split each 32-bit word into
    header (bit 31 set: ``[1 | docID:31]``) or pair (``[0 | wordID:19 |
    count:12]``); a cumulative sum over the header bits assigns every
    word to its document row, and a one-hot row matrix turns segment
    reductions (per-doc norm, per-doc score) into MXU matmuls;
  - **match** — the same merge-join -> match-matrix reformulation as
    ``sparse_match``: ``eq = (ids == q_ids)``, ``eq @ q_vals``, scaled
    by the decoded counts and segment-summed per document row;
  - **top-k** — the epilogue (last query-tile grid step) computes the
    cosine scores against in-kernel doc norms and emits each doc tile's
    ``min(k, block_docs)`` best candidates; the host-side wrapper folds
    the per-tile candidate lists with the ``core.topk`` primitives.

Host staging is reduced to ``tile_stream``: an O(n) boundary-index pass
that splits the raw stream at document boundaries into fixed-capacity
``[T, cap]`` uint32 tiles (``cap = block_docs * (1 + nnz_pad)``, pad
word 0xFFFFFFFF) so no document straddles a grid block. No ELL arrays,
no float conversion, no norms are materialized on the host — 4 B/word
travels to the device exactly as it sits in the segment file.

Numerics: counts are 12-bit integers, so in the no-overflow regime
(score and norm partial sums below 2**24) every accumulation order is
exact in fp32 and the fused result is *bit-identical* to the staged
``jnp`` reference — including IEEE-correctly-rounded ``sqrt`` for the
norms (fp64->fp32 double rounding of sqrt is innocuous at these
widths). tests/test_fused_kernel.py proves this on every serving
surface.

Tiling (``block_docs``, ``block_query``) comes from the strategy
classes in ``kernels.tiling``; shapes are memoized per L-bucket so the
§7 compile-cache bound (<= log2(max_batch)+1 programs per shape
family) still holds. ``interpret=True`` runs the same kernel on CPU —
the differential suites in CI exercise the identical code path.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:                                      # scratch constructors (TPU path
    from jax.experimental.pallas import tpu as pltpu   # + interpret mode)
except ImportError:                       # pragma: no cover - old jax
    pltpu = None

from repro.core.stream_format import (HEADER_BIT, KEY_BITS, KEY_MASK,
                                      MAX_DOC_ID, VAL_BITS, VAL_MASK)

Array = jax.Array

PAD_WORD = np.uint32(0xFFFFFFFF)


class PackedSlab(NamedTuple):
    """A corpus slab in fused-kernel layout: the Fig. 8 stream split
    into fixed-capacity doc tiles, still packed uint32. The fused
    scoring unit — the counterpart of the staged path's DeviceSlab."""
    tiles: jax.Array      # [T, cap] uint32 (PAD_WORD padding)


# ---------------------------------------------------------------------------
# host-side stream tiling (boundary index pass — NOT an ELL decode)
# ---------------------------------------------------------------------------
def tile_stream(stream: np.ndarray, *, block_docs: int, nnz_pad: int,
                pad_docs_to: Optional[int] = None
                ) -> Tuple[np.ndarray, int, int]:
    """Split a Fig. 8 uint32 stream into ``[T, cap]`` fixed-capacity doc
    tiles for the fused kernel. Applies the exact truncation rule of
    ``decode_to_ell`` (pairs beyond ``nnz_pad`` per document are
    dropped) so fused stats and scores match the staged path.

    ``pad_docs_to`` pads the tile count to ``ceil(pad_docs_to /
    block_docs)`` (all-PAD rows) so every segment of a store shares one
    program shape — the fused analogue of ``Corpus.pad_docs_to``.

    Returns ``(tiles, n_docs, n_truncated)``.
    """
    stream = np.asarray(stream, np.uint32)
    cap = block_docs * (1 + nnz_pad)
    is_hdr = (stream & HEADER_BIT) != 0
    n_docs = int(is_hdr.sum())
    target = n_docs if pad_docs_to is None else int(pad_docs_to)
    if target < n_docs:
        raise ValueError(f"pad_docs_to {target} < n_docs {n_docs}")
    n_tiles = -(-target // block_docs) if target else 0
    if n_docs == 0:
        return np.full((n_tiles, cap), PAD_WORD, np.uint32), 0, 0
    if bool((stream == PAD_WORD).any()):
        # header word of doc_id MAX_DOC_ID collides with the pad
        # sentinel; the staged backends handle it, the fused one refuses
        raise ValueError(
            f"stream contains word 0x{int(PAD_WORD):08X} (doc_id "
            f"{MAX_DOC_ID}), which aliases the fused-kernel pad")
    # per-word document segment + within-document position
    hdr_pos = np.flatnonzero(is_hdr)
    seg = np.cumsum(is_hdr) - 1
    pos = np.arange(stream.size) - hdr_pos[seg]    # 0 = header, 1.. = pair
    keep = is_hdr | (pos <= nnz_pad)
    n_trunc = int(stream.size - int(keep.sum()))
    kept = stream[keep]
    # re-index the kept stream and scatter into (tile, column) slots
    is_hdr_k = (kept & HEADER_BIT) != 0
    hdr_pos_k = np.flatnonzero(is_hdr_k)
    doc_of = np.cumsum(is_hdr_k) - 1               # document per word
    tile_of = doc_of // block_docs
    tile_base = hdr_pos_k[tile_of * block_docs]    # tile's first word
    col = np.arange(kept.size) - tile_base
    tiles = np.full((n_tiles, cap), PAD_WORD, np.uint32)
    tiles[tile_of, col] = kept
    return tiles, n_docs, n_trunc


def corpus_to_stream(corpus) -> np.ndarray:
    """Re-encode an ELL ``Corpus`` (integral Fig. 8-representable
    counts) as the packed uint32 stream — the bridge for surfaces that
    only hold decoded rows (resident engine corpus, ingest memtable).
    Padding rows (doc_id < 0) are skipped; within-row pair order is
    preserved. Raises for values the 19/12-bit packing cannot carry."""
    ids = np.asarray(corpus.ids)
    vals = np.asarray(corpus.vals)
    doc_ids = np.asarray(corpus.doc_ids)
    rows = doc_ids >= 0
    valid = (ids >= 0) & rows[:, None]
    v = vals[valid]
    if v.size and (not np.all(v == np.round(v)) or v.min() < 0
                   or v.max() > VAL_MASK):
        raise ValueError(
            "fused/packed backends need integral counts in "
            f"[0, {VAL_MASK}] (Fig. 8 12-bit packing); use the jnp or "
            "pallas backend for arbitrary float values")
    if ids[valid].size and int(ids[valid].max()) > KEY_MASK:
        raise ValueError(f"word id exceeds {KEY_BITS}-bit packing")
    if rows.any() and int(doc_ids[rows].max()) >= MAX_DOC_ID:
        raise ValueError(f"doc_id >= {MAX_DOC_ID} aliases the fused pad")
    lens = valid.sum(1)[rows]
    d_ids = doc_ids[rows].astype(np.uint32)
    starts = np.zeros(d_ids.size, np.int64)
    np.cumsum(lens[:-1] + 1, out=starts[1:])
    out = np.empty(int(lens.sum() + d_ids.size), np.uint32)
    out[starts] = HEADER_BIT | d_ids
    r, c = np.nonzero(valid[rows])
    rank = np.arange(r.size) - np.searchsorted(r, r)
    out[starts[r] + 1 + rank] = (
        (ids[rows][r, c].astype(np.uint32) << VAL_BITS)
        | vals[rows][r, c].astype(np.uint32))
    return out


# ---------------------------------------------------------------------------
# the fused kernel
# ---------------------------------------------------------------------------
def _fused_kernel(tiles_ref, q_ids_ref, q_vals_ref, q_norms_ref,
                  vals_out_ref, ids_out_ref,
                  corr_ref, dnorm_ref, docid_ref, *, kp: int, nq: int):
    """Grid (doc_tiles, query_tiles), query axis innermost. Scratch
    (corr accumulator, doc norms, doc ids) persists across the query
    axis; the epilogue runs once per doc tile at the last query step."""
    j = pl.program_id(1)
    words = tiles_ref[0, :]                          # [cap] uint32
    cap = words.shape[0]
    bd = docid_ref.shape[0]

    # -- in-kernel Fig. 8 decode (VPU shifts/masks) --------------------
    is_pad = words == jnp.uint32(PAD_WORD)
    is_hdr = jnp.logical_and((words & jnp.uint32(HEADER_BIT)) != 0,
                             jnp.logical_not(is_pad))
    valid_pair = jnp.logical_and(jnp.logical_not(is_pad),
                                 jnp.logical_not(is_hdr))
    row = jnp.cumsum(is_hdr.astype(jnp.int32)) - 1   # doc row per word
    onehot = row[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (cap, bd), 1)                     # word -> doc row
    d_ids = jnp.where(valid_pair,
                      ((words >> VAL_BITS) & jnp.uint32(KEY_MASK))
                      .astype(jnp.int32), -1)
    d_vals = jnp.where(valid_pair,
                       (words & jnp.uint32(VAL_MASK)).astype(jnp.float32),
                       0.0)

    @pl.when(j == 0)
    def _prologue():
        oh = onehot.astype(jnp.float32)
        # per-doc L2 norm of the decoded counts (segment sum via MXU)
        sumsq = jnp.dot(d_vals * d_vals, oh,
                        preferred_element_type=jnp.float32)      # [bd]
        dnorm_ref[...] = jnp.sqrt(sumsq)
        hdr_id = jnp.where(is_hdr,
                           (words & jnp.uint32(MAX_DOC_ID))
                           .astype(jnp.int32), -1)
        docid_ref[...] = jnp.max(
            jnp.where(onehot, hdr_id[:, None], -1), axis=0)      # [bd]
        corr_ref[...] = jnp.zeros_like(corr_ref)

    # -- match: merge-join as a match matrix (MXU) ---------------------
    eq = (d_ids[:, None] == q_ids_ref[...][None, :]).astype(jnp.float32)
    matched = jnp.dot(eq, q_vals_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)   # [cap, L]
    pp = d_vals[:, None] * matched
    corr_ref[...] += jnp.dot(onehot.astype(jnp.float32).T, pp,
                             preferred_element_type=jnp.float32)  # [bd, L]

    # -- epilogue: cosine + per-tile partial top-k ---------------------
    @pl.when(j == nq - 1)
    def _epilogue():
        doc_id = docid_ref[...]
        denom = dnorm_ref[...][:, None] * q_norms_ref[...][None, :]
        cos = jnp.where(denom > 0,
                        corr_ref[...] / jnp.maximum(denom, 1e-12),
                        -jnp.inf)
        # invalid rows (tile padding) can never surface; real documents
        # keep their id whatever their score (see core.topk.local_topk)
        cos = jnp.where(doc_id[:, None] >= 0, cos, -jnp.inf)
        # rank with NaN pinned above every finite score (lax.top_k's own
        # totalorder outside Pallas); the in-kernel sort orders NaN
        # *last*, which would let -inf padding displace a real document
        # whose score went non-finite — the rename bug's sibling
        rank = jnp.where(jnp.isnan(cos), jnp.inf, cos)
        _, idx = jax.lax.top_k(rank.T, kp)           # [L, kp]
        v = jnp.take_along_axis(cos.T, idx, axis=1)
        ids = jnp.take(doc_id, idx)
        vals_out_ref[...] = v[None]
        ids_out_ref[...] = jnp.where(ids >= 0, ids, -1)[None]


@functools.partial(jax.jit, static_argnames=("block_docs", "kp",
                                             "block_query", "interpret"))
def fused_match_topk(tiles: Array, q_ids: Array, q_vals: Array,
                     q_norms: Array, *, block_docs: int, kp: int,
                     block_query: int = 512,
                     interpret: bool = False) -> Tuple[Array, Array]:
    """tiles: [T, cap] uint32 (from ``tile_stream``, cap = block_docs *
    (1 + nnz_pad)); q_ids: [Qm] int32 merged stream (pads already
    remapped by ops.py so they can never match a decoded word id);
    q_vals: [Qm, L]; q_norms: [L]. Qm % block_query == 0 (ops.py pads).
    Returns per-tile candidates (vals [T, L, kp], ids [T, L, kp]) — fold
    with ``core.topk.fold_topk``."""
    T, cap = tiles.shape
    Qm, L_ = q_vals.shape
    tq = min(block_query, Qm)
    assert Qm % tq == 0, (Qm, tq)
    nq = Qm // tq
    grid = (T, nq)
    scratch = []
    if pltpu is not None:
        scratch = [pltpu.VMEM((block_docs, L_), jnp.float32),
                   pltpu.VMEM((block_docs,), jnp.float32),
                   pltpu.VMEM((block_docs,), jnp.int32)]
    return pl.pallas_call(
        functools.partial(_fused_kernel, kp=kp, nq=nq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap), lambda i, j: (i, 0)),
            pl.BlockSpec((tq,), lambda i, j: (j,)),
            pl.BlockSpec((tq, L_), lambda i, j: (j, 0)),
            pl.BlockSpec((L_,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, L_, kp), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, L_, kp), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, L_, kp), jnp.float32),
            jax.ShapeDtypeStruct((T, L_, kp), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(tiles, q_ids, q_vals, q_norms)
