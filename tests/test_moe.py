"""MoE dispatch correctness: shard_map EP path vs dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.distributed.meshctx import single_device_ctx
from repro.models import moe


def _dense_moe_ref(p, x, cfg):
    """Dense (all-experts) reference: exact, no capacity drops."""
    T, d = x.shape
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gw, eid = jax.lax.top_k(probs, cfg.top_k)
    gw = gw / gw.sum(-1, keepdims=True)
    # every expert over every token, then mask-combine
    h = jnp.einsum("td,edf->tef", x, p["w_gate"])
    u = jnp.einsum("td,edf->tef", x, p["w_up"])
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["w_down"])
    out = jnp.zeros((T, d), x.dtype)
    for j in range(cfg.top_k):
        sel = jnp.take_along_axis(y_all, eid[:, j][:, None, None], axis=1)[:, 0]
        out = out + sel * gw[:, j][:, None].astype(x.dtype)
    return out


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "qwen3-moe-235b-a22b"])
def test_moe_matches_dense_reference(arch):
    cfg = get_smoke_config(arch)
    ctx = single_device_ctx()
    key = jax.random.PRNGKey(0)
    p = moe.moe_init(key, cfg, n=1)
    layer = jax.tree.map(lambda a: a[0], p)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32)

    # generous capacity so nothing drops -> must match dense ref exactly
    cfg_nodrop = cfg.__class__(**{**cfg.__dict__, "capacity_factor": 8.0})
    y, aux = moe_apply_f32(layer, x, cfg_nodrop, ctx)
    ref = _dense_moe_ref({k: v.astype(jnp.float32) for k, v in layer.items()
                          if k != "shared"}, x.reshape(B * S, -1), cfg)
    if "shared" in layer:
        sh = {k: v.astype(jnp.float32) for k, v in layer["shared"].items()}
        xf = x.reshape(B * S, -1)
        ref = ref + (jax.nn.silu(xf @ sh["w_gate"]) * (xf @ sh["w_up"])) @ sh["w_down"]
    np.testing.assert_allclose(np.asarray(y).reshape(B * S, -1),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def moe_apply_f32(layer, x, cfg, ctx):
    layer = jax.tree.map(lambda a: a.astype(jnp.float32), layer)
    return moe.moe_apply(layer, x, cfg, ctx)


def test_moe_grads_flow():
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    ctx = single_device_ctx()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, n=1)
    layer = jax.tree.map(lambda a: a[0].astype(jnp.float32), p)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)

    def loss(params):
        y, aux = moe.moe_apply(params, x, cfg, ctx)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(layer)
    norms = jax.tree.map(lambda a: float(jnp.linalg.norm(a)), g)
    flat = jax.tree.leaves(norms)
    assert all(np.isfinite(v) for v in flat)
    assert any(v > 0 for v in flat)
