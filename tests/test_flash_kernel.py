"""Pallas flash-attention kernel vs naive oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_gqa
from tests.test_attention import naive_attention


@pytest.mark.parametrize("case", [
    # (BH, S, hd, causal, bq, bk)
    (2, 64, 16, True, 16, 16),
    (1, 128, 32, True, 32, 64),
    (3, 48, 8, False, 16, 16),
    (2, 96, 16, True, 32, 16),     # S not a multiple of default blocks
])
def test_flash_kernel_matches_naive(case):
    BH, S, hd, causal, bq, bk = case
    key = jax.random.PRNGKey(hash(case) % 2**31)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (BH, S, hd), jnp.float32)
    k = jax.random.normal(kk, (BH, S, hd), jnp.float32)
    v = jax.random.normal(kv, (BH, S, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_kv=bk,
                          interpret=True)
    # oracle expects [B, S, H, hd]
    want = naive_attention(q[:, :, None].transpose(0, 1, 2, 3).reshape(BH, S, 1, hd),
                           k.reshape(BH, S, 1, hd), v.reshape(BH, S, 1, hd),
                           causal=causal)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.reshape(BH, S, hd)),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_gqa_wrapper():
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, KV, hd), jnp.float32)
    got = flash_attention_gqa(q, k, v, causal=True, interpret=True)
    want = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_bf16():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (2, 64, 16), jnp.bfloat16)
    k = jax.random.normal(key, (2, 64, 16), jnp.bfloat16)
    v = jax.random.normal(key, (2, 64, 16), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=16, block_kv=16,
                          interpret=True)
    want = naive_attention(
        jnp.asarray(q, jnp.float32).reshape(2, 64, 1, 16),
        jnp.asarray(k, jnp.float32).reshape(2, 64, 1, 16),
        jnp.asarray(v, jnp.float32).reshape(2, 64, 1, 16), causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want.reshape(2, 64, 16)),
                               rtol=3e-2, atol=3e-2)
