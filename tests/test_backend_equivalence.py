"""Differential property suite: every scoring path must agree.

The repo has four ways to compute the correlation matrix [D, L]:

  - ``kernels/ref.py`` (dense scatter/gather oracle, called directly)
  - the ``jnp`` gather backend (``ops.correlate(backend="jnp")``)
  - the Pallas ELL kernel (``backend="pallas"``, interpret=True on CPU)
  - the Pallas packed-stream kernel (``backend="pallas_packed"``)

One parametrized suite drives all of them over random ELL corpora with
every adversarial sentinel the formats define: -1 doc padding, -2 query
padding, duplicate ids (within docs and within the merged stream),
empty documents and empty queries. Disagreement beyond 1e-5 is a
scoring bug, not tolerance noise — counts are small integers.

The engine-level half runs all *four* end-to-end backends (adding
``pallas_fused``, DESIGN.md §12) over adversarial fixtures: non-finite
query values (the local_topk isfinite-mask regression), zero-term
query rows, the all-empty batch, single-doc corpora, and a randomized
fused-vs-jnp bit-identity property over corpora *and* tile shapes.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.configs.paper_search import SearchConfig
from repro.core.corpus import from_stream
from repro.core.engine import PatternSearchEngine
from repro.core.stream_format import encode
from repro.distributed.meshctx import single_device_ctx
from repro.kernels import ops, ref
from repro.kernels.sparse_match_packed import pack
from repro.kernels.tiling import FixedTiling

BACKENDS = ["jnp", "pallas", "pallas_packed"]
ENGINE_BACKENDS = BACKENDS + ["pallas_fused"]
VOCAB = 256


def _adversarial_case(seed):
    """Random ELL docs + merged query stream, seeded so failures replay.

    Deliberately hostile: some doc rows fully empty (-1), some rows with
    duplicate ids, vals of zero on valid ids, -2 query padding scattered
    *inside* the merged stream (not only at the tail), duplicate query
    ids within one column, and sometimes an all-padding query column.
    """
    rng = np.random.default_rng(seed)
    D = int(rng.integers(1, 33))
    K = int(rng.integers(1, 17))
    Qm = int(rng.integers(1, 49))
    L = int(rng.integers(1, 5))

    ids = np.full((D, K), -1, np.int32)
    vals = np.zeros((D, K), np.float32)
    for d in range(D):
        if rng.random() < 0.15:
            continue                               # empty document
        k = int(rng.integers(1, K + 1))
        row = rng.integers(0, VOCAB, k)
        if k > 1 and rng.random() < 0.3:
            row[0] = row[1]                        # duplicate id in a doc
        ids[d, :k] = np.sort(row).astype(np.int32)
        vals[d, :k] = rng.integers(0, 30, k)       # zero vals possible

    mi = np.full(Qm, -2, np.int32)
    mv = np.zeros((Qm, L), np.float32)
    for j in range(Qm):
        if rng.random() < 0.2:
            continue                               # in-stream query pad
        mi[j] = int(rng.integers(0, VOCAB))
        col = int(rng.integers(0, L))
        mv[j, col] = float(rng.integers(1, 30))
    if L > 1 and rng.random() < 0.3:
        mv[:, 0] = 0.0                             # empty query column
    order = np.argsort(np.where(mi < 0, VOCAB + 1, mi), kind="stable")
    return ids, vals, mi[order], mv[order]


def _correlate(backend, ids, vals, mi, mv):
    if backend == "ref":
        return ref.sparse_match_ref(jnp.asarray(ids), jnp.asarray(vals),
                                    jnp.asarray(mi), jnp.asarray(mv), VOCAB)
    docs = pack(ids, vals) if backend == "pallas_packed" else ids
    return ops.correlate(jnp.asarray(docs), jnp.asarray(vals),
                         jnp.asarray(mi), jnp.asarray(mv), backend=backend,
                         vocab_size=VOCAB, block_docs=8, block_query=8)


@pytest.mark.parametrize("backend", BACKENDS)
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_backend_matches_ref_oracle(backend, seed):
    ids, vals, mi, mv = _adversarial_case(seed)
    got = np.asarray(_correlate(backend, ids, vals, mi, mv))
    want = np.asarray(_correlate("ref", ids, vals, mi, mv))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_all_sentinels_contribute_nothing(backend):
    """Fully-padded docs x fully-padded queries score exactly zero even
    when the padded slots carry large values."""
    ids = np.full((8, 8), -1, np.int32)
    vals = np.full((8, 8), 1000.0, np.float32)
    mi = np.full(8, -2, np.int32)
    mv = np.full((8, 2), 1000.0, np.float32)
    out = np.asarray(_correlate(backend, ids, vals, mi, mv))
    assert out.shape == (8, 2)
    np.testing.assert_array_equal(out, 0.0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_duplicate_ids_accumulate_consistently(backend):
    """A word id repeated in a doc row and in the merged stream must
    multiply out identically everywhere (4 pairings of id 7)."""
    ids = np.array([[7, 7, -1, -1]], np.int32)
    vals = np.array([[2.0, 3.0, 0.0, 0.0]], np.float32)
    mi = np.array([7, 7, -2, -2], np.int32)
    mv = np.array([[1.0], [10.0], [5.0], [5.0]], np.float32)
    out = np.asarray(_correlate(backend, ids, vals, mi, mv))
    np.testing.assert_allclose(out, [[(2 + 3) * (1 + 10)]], rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_engine_merged_path_matches_per_query(seed):
    """End to end through merge_queries: the L-column batch scores each
    column exactly as an L=1 run of the same query (the paper's K*L
    batching is exact; this is what makes serve-layer coalescing safe)."""
    rng = np.random.default_rng(seed)
    D, K, Qn, L = 16, 8, 8, int(rng.integers(2, 5))
    ids = np.full((D, K), -1, np.int32)
    vals = np.zeros((D, K), np.float32)
    for d in range(D):
        k = int(rng.integers(1, K + 1))
        ids[d, :k] = np.sort(rng.choice(VOCAB, k, replace=False))
        vals[d, :k] = rng.integers(1, 20, k)
    qid = np.full((L, Qn), -1, np.int32)
    qval = np.zeros((L, Qn), np.float32)
    for l in range(L):
        if rng.random() < 0.2:
            continue                                # empty query
        q = int(rng.integers(1, Qn + 1))
        qid[l, :q] = np.sort(rng.choice(VOCAB, q, replace=False))
        qval[l, :q] = rng.integers(1, 20, q)
    mi, mv = ops.merge_queries(qid, qval)
    if mi.size == 0:
        mi, mv = np.array([-2], np.int32), np.zeros((1, L), np.float32)
    batched = np.asarray(_correlate("ref", ids, vals, mi, mv))
    for l in range(L):
        mi1, mv1 = ops.merge_queries(qid[l:l + 1], qval[l:l + 1])
        if mi1.size == 0:
            mi1, mv1 = np.array([-2], np.int32), np.zeros((1, 1), np.float32)
        single = np.asarray(_correlate("ref", ids, vals, mi1, mv1))
        np.testing.assert_allclose(batched[:, l], single[:, 0],
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine-level adversarial fixtures, all four end-to-end backends
# ---------------------------------------------------------------------------
def _cfg(**kw):
    base = dict(name="equiv-test", vocab_size=VOCAB, avg_nnz_per_doc=6,
                nnz_pad=8, top_k=4, block_docs=8, block_query=16)
    base.update(kw)
    return SearchConfig(**base)


def _engine(backend, docs, cfg, **kw):
    corpus = from_stream(encode(docs), cfg.nnz_pad)
    return PatternSearchEngine(corpus, cfg, single_device_ctx(), backend,
                               **kw)


def _assert_same(a, b, label=""):
    np.testing.assert_array_equal(a.doc_ids, b.doc_ids, err_msg=label)
    np.testing.assert_array_equal(a.scores, b.scores, err_msg=label)


@pytest.mark.parametrize("backend", ENGINE_BACKENDS)
def test_nonfinite_query_value_keeps_real_doc_id(backend):
    """Regression for the local_topk isfinite mask: an inf query value
    drives the matching document's cosine score non-finite (inf/inf ->
    NaN through the norm), and the old ``isfinite(vals)`` id mask then
    renamed that *real* document to -1 — indistinguishable from "no
    result". Every backend must keep a real id at the top slot.

    Cross-backend score equality (and NaN *rank*) deliberately NOT
    asserted here: the matmul-formulation kernels (pallas/packed/fused)
    produce NaN for non-matching docs too (0 * inf inside the match
    matrix) where the gather backends give them 0, and the top-k
    reduction chain orders NaN differently per stage (lax.top_k sorts
    it first, the merge argsort last) — documented non-finite
    divergences. What every backend MUST agree on is that the real
    document is still reported under its real id."""
    docs = [(0, [(3, 2), (10, 1)]),
            (1, [(7, 5)]),              # only doc 1 holds word 7
            (2, [(12, 4), (20, 2)])]
    eng = _engine(backend, docs, _cfg())
    qi = np.array([[7, -1]], np.int32)
    qv = np.array([[np.inf, 0.0]], np.float32)
    res = eng.search(qi, qv)
    row_ids = res.doc_ids[0]
    assert 1 in row_ids                   # kept its id, not renamed -1
    pos = int(np.flatnonzero(row_ids == 1)[0])
    assert not np.isfinite(res.scores[0, pos])


def test_zero_term_and_all_empty_rows_bit_identical():
    """A zero-term query row inside a batch, and a batch of *only*
    empty rows, are well-defined (score 0 against every real doc) and
    must agree bitwise across all four backends."""
    docs = [(d, [(d + 1, 2), (d + 50, 1)]) for d in range(6)]
    cfg = _cfg()
    mixed_i = np.array([[3, 4], [-1, -1], [51, -1]], np.int32)
    mixed_v = np.array([[1.0, 2.0], [0.0, 0.0], [3.0, 0.0]], np.float32)
    empty_i = np.full((2, 3), -1, np.int32)
    empty_v = np.zeros((2, 3), np.float32)
    results = {}
    for b in ENGINE_BACKENDS:
        eng = _engine(b, docs, cfg)
        results[b] = (eng.search(mixed_i, mixed_v),
                      eng.search(empty_i, empty_v),
                      eng.search(np.empty((0, 2), np.int32),
                                 np.empty((0, 2), np.float32)))
    for b in ENGINE_BACKENDS[1:]:
        _assert_same(results["jnp"][0], results[b][0], f"{b} mixed")
        _assert_same(results["jnp"][1], results[b][1], f"{b} all-empty")
        assert results[b][2].doc_ids.shape == (0, cfg.top_k)
    # the empty row scored: real ids, all-zero scores, nothing renamed
    zrow = results["jnp"][0]
    assert (zrow.doc_ids[1] >= 0).all()
    np.testing.assert_array_equal(zrow.scores[1], 0.0)


def test_single_doc_corpus_bit_identical():
    docs = [(17, [(5, 3), (9, 1)])]
    cfg = _cfg()
    qi = np.array([[5, -1], [9, 5]], np.int32)
    qv = np.array([[2.0, 0.0], [1.0, 1.0]], np.float32)
    ref_r = _engine("jnp", docs, cfg).search(qi, qv)
    assert ref_r.doc_ids[0, 0] == 17 and (ref_r.doc_ids[:, 1:] == -1).all()
    for b in ENGINE_BACKENDS[1:]:
        _assert_same(ref_r, _engine(b, docs, cfg).search(qi, qv), b)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_fused_bit_identical_to_jnp_over_random_corpora_and_tiles(seed):
    """The tentpole property (DESIGN.md §12): for integral counts in
    the exact-fp32 regime, the fused kernel is *bit-identical* to the
    staged jnp path — over random corpora, random queries, and random
    (block_docs, block_query) tile shapes."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(nnz_pad=int(rng.integers(2, 10)),
               top_k=int(rng.integers(1, 7)),
               block_docs=int(2 ** rng.integers(2, 6)),
               block_query=int(2 ** rng.integers(3, 7)))
    docs = []
    for d in range(int(rng.integers(1, 60))):
        nw = int(rng.integers(0, 12))
        ws = sorted(rng.choice(VOCAB, nw, replace=False).tolist())
        docs.append((d, [(int(w), int(rng.integers(1, 30))) for w in ws]))
    L = int(rng.integers(1, 5))
    qi = np.full((L, 5), -1, np.int32)
    qv = np.zeros((L, 5), np.float32)
    for l in range(L):
        if rng.random() < 0.2:
            continue
        q = int(rng.integers(1, 6))
        qi[l, :q] = np.sort(rng.choice(VOCAB, q, replace=False))
        qv[l, :q] = rng.integers(1, 20, q)
    tiling = FixedTiling(cfg.block_docs, cfg.block_query)
    ref_r = _engine("jnp", docs, cfg).search(qi, qv)
    got = _engine("pallas_fused", docs, cfg, tiling=tiling).search(qi, qv)
    _assert_same(ref_r, got, f"seed={seed} cfg={cfg}")
