"""Differential property suite: every scoring path must agree.

The repo has four ways to compute the correlation matrix [D, L]:

  - ``kernels/ref.py`` (dense scatter/gather oracle, called directly)
  - the ``jnp`` gather backend (``ops.correlate(backend="jnp")``)
  - the Pallas ELL kernel (``backend="pallas"``, interpret=True on CPU)
  - the Pallas packed-stream kernel (``backend="pallas_packed"``)

One parametrized suite drives all of them over random ELL corpora with
every adversarial sentinel the formats define: -1 doc padding, -2 query
padding, duplicate ids (within docs and within the merged stream),
empty documents and empty queries. Disagreement beyond 1e-5 is a
scoring bug, not tolerance noise — counts are small integers.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.sparse_match_packed import pack

BACKENDS = ["jnp", "pallas", "pallas_packed"]
VOCAB = 256


def _adversarial_case(seed):
    """Random ELL docs + merged query stream, seeded so failures replay.

    Deliberately hostile: some doc rows fully empty (-1), some rows with
    duplicate ids, vals of zero on valid ids, -2 query padding scattered
    *inside* the merged stream (not only at the tail), duplicate query
    ids within one column, and sometimes an all-padding query column.
    """
    rng = np.random.default_rng(seed)
    D = int(rng.integers(1, 33))
    K = int(rng.integers(1, 17))
    Qm = int(rng.integers(1, 49))
    L = int(rng.integers(1, 5))

    ids = np.full((D, K), -1, np.int32)
    vals = np.zeros((D, K), np.float32)
    for d in range(D):
        if rng.random() < 0.15:
            continue                               # empty document
        k = int(rng.integers(1, K + 1))
        row = rng.integers(0, VOCAB, k)
        if k > 1 and rng.random() < 0.3:
            row[0] = row[1]                        # duplicate id in a doc
        ids[d, :k] = np.sort(row).astype(np.int32)
        vals[d, :k] = rng.integers(0, 30, k)       # zero vals possible

    mi = np.full(Qm, -2, np.int32)
    mv = np.zeros((Qm, L), np.float32)
    for j in range(Qm):
        if rng.random() < 0.2:
            continue                               # in-stream query pad
        mi[j] = int(rng.integers(0, VOCAB))
        col = int(rng.integers(0, L))
        mv[j, col] = float(rng.integers(1, 30))
    if L > 1 and rng.random() < 0.3:
        mv[:, 0] = 0.0                             # empty query column
    order = np.argsort(np.where(mi < 0, VOCAB + 1, mi), kind="stable")
    return ids, vals, mi[order], mv[order]


def _correlate(backend, ids, vals, mi, mv):
    if backend == "ref":
        return ref.sparse_match_ref(jnp.asarray(ids), jnp.asarray(vals),
                                    jnp.asarray(mi), jnp.asarray(mv), VOCAB)
    docs = pack(ids, vals) if backend == "pallas_packed" else ids
    return ops.correlate(jnp.asarray(docs), jnp.asarray(vals),
                         jnp.asarray(mi), jnp.asarray(mv), backend=backend,
                         vocab_size=VOCAB, block_docs=8, block_query=8)


@pytest.mark.parametrize("backend", BACKENDS)
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_backend_matches_ref_oracle(backend, seed):
    ids, vals, mi, mv = _adversarial_case(seed)
    got = np.asarray(_correlate(backend, ids, vals, mi, mv))
    want = np.asarray(_correlate("ref", ids, vals, mi, mv))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_all_sentinels_contribute_nothing(backend):
    """Fully-padded docs x fully-padded queries score exactly zero even
    when the padded slots carry large values."""
    ids = np.full((8, 8), -1, np.int32)
    vals = np.full((8, 8), 1000.0, np.float32)
    mi = np.full(8, -2, np.int32)
    mv = np.full((8, 2), 1000.0, np.float32)
    out = np.asarray(_correlate(backend, ids, vals, mi, mv))
    assert out.shape == (8, 2)
    np.testing.assert_array_equal(out, 0.0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_duplicate_ids_accumulate_consistently(backend):
    """A word id repeated in a doc row and in the merged stream must
    multiply out identically everywhere (4 pairings of id 7)."""
    ids = np.array([[7, 7, -1, -1]], np.int32)
    vals = np.array([[2.0, 3.0, 0.0, 0.0]], np.float32)
    mi = np.array([7, 7, -2, -2], np.int32)
    mv = np.array([[1.0], [10.0], [5.0], [5.0]], np.float32)
    out = np.asarray(_correlate(backend, ids, vals, mi, mv))
    np.testing.assert_allclose(out, [[(2 + 3) * (1 + 10)]], rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_engine_merged_path_matches_per_query(seed):
    """End to end through merge_queries: the L-column batch scores each
    column exactly as an L=1 run of the same query (the paper's K*L
    batching is exact; this is what makes serve-layer coalescing safe)."""
    rng = np.random.default_rng(seed)
    D, K, Qn, L = 16, 8, 8, int(rng.integers(2, 5))
    ids = np.full((D, K), -1, np.int32)
    vals = np.zeros((D, K), np.float32)
    for d in range(D):
        k = int(rng.integers(1, K + 1))
        ids[d, :k] = np.sort(rng.choice(VOCAB, k, replace=False))
        vals[d, :k] = rng.integers(1, 20, k)
    qid = np.full((L, Qn), -1, np.int32)
    qval = np.zeros((L, Qn), np.float32)
    for l in range(L):
        if rng.random() < 0.2:
            continue                                # empty query
        q = int(rng.integers(1, Qn + 1))
        qid[l, :q] = np.sort(rng.choice(VOCAB, q, replace=False))
        qval[l, :q] = rng.integers(1, 20, q)
    mi, mv = ops.merge_queries(qid, qval)
    if mi.size == 0:
        mi, mv = np.array([-2], np.int32), np.zeros((1, L), np.float32)
    batched = np.asarray(_correlate("ref", ids, vals, mi, mv))
    for l in range(L):
        mi1, mv1 = ops.merge_queries(qid[l:l + 1], qval[l:l + 1])
        if mi1.size == 0:
            mi1, mv1 = np.array([-2], np.int32), np.zeros((1, 1), np.float32)
        single = np.asarray(_correlate("ref", ids, vals, mi1, mv1))
        np.testing.assert_allclose(batched[:, l], single[:, 0],
                                   rtol=1e-5, atol=1e-5)
