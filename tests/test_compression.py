"""Int8 gradient compression: collective-level numerics on a real 2-pod
placeholder mesh (subprocess). Error feedback must make the compressed mean
track the exact mean over steps."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.distributed.meshctx import MeshCtx
from repro.distributed import compression

mesh = jax.make_mesh((2,), ("pod",))
ctx = MeshCtx(mesh=mesh, dp_axes=("pod",), fsdp_axis="pod", tp_axis="pod")
reduce = compression.make_pod_grad_reducer(ctx, None, compress=True)

rng = np.random.default_rng(0)
# per-pod gradients differ; exact mean is the target
gA = {"w": rng.standard_normal((4, 256)).astype(np.float32)}
gB = {"w": rng.standard_normal((4, 256)).astype(np.float32)}
stacked = {"w": np.stack([gA["w"], gB["w"]])}  # [pod, ...]
sh = NamedSharding(mesh, P("pod"))
g_sharded = {"w": jax.device_put(stacked["w"].reshape(2*4, 256),
                                 NamedSharding(mesh, P("pod", None)))}

# drive via shard_map-compatible jit: treat the leading dim as the pod shard
err = {"w": jnp.zeros((4, 256), jnp.float32)}
exact = (gA["w"] + gB["w"]) / 2

@jax.jit
def run(g, e):
    from repro.distributed.compat import shard_map
    f = shard_map(lambda gg, ee: compression.compressed_mean_tree(
                      gg, ee, ctx, "pod"),
                  mesh=mesh, in_specs=(P("pod"), P()), out_specs=(P(), P()),
                  check_vma=False)
    return f(g, e)

total_err = None
g_in = {"w": jax.device_put(stacked["w"].reshape(8, 256),
                            NamedSharding(mesh, P("pod", None)))}
mean, err_out = run({"w": g_in["w"]}, err)
got = np.asarray(mean["w"])
rel = np.abs(got - exact).max() / (np.abs(exact).max() + 1e-9)
assert rel < 0.02, rel
# error feedback: applying the SAME grads again, accumulated result
# converges to the exact sum (residual carried forward)
mean2, _ = run({"w": g_in["w"]}, err_out)
two_step = (np.asarray(mean["w"]) + np.asarray(mean2["w"]))
rel2 = np.abs(two_step - 2 * exact).max() / (np.abs(exact).max() + 1e-9)
assert rel2 < rel * 2 + 0.02, (rel2, rel)
print("COMPRESSION_OK", rel)
"""


def test_compressed_mean_on_pod_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COMPRESSION_OK" in r.stdout
