"""Per-query trace spans end-to-end (DESIGN.md §8.2): span-tree
mechanics, sampling, the store and cluster request paths (per-shard
subtrees, straggler attribution), the exporters, and the differential
acceptance gate — tracing on vs off must be bit-identical on every
scoring surface."""
import json

import numpy as np
import pytest

from repro.cluster import FlashClusterSession
from repro.cluster.store import build_sharded_store
from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.obs import NULL_SPAN, Obs, QueryTrace, Tracer
from repro.obs.export import (render_summary, render_trace, write_metrics,
                              write_traces)
from repro.serve import SearchService
from repro.storage import FlashSearchSession, FlashStore
from repro.storage.store import _corpus_docs

CFG = smoke()


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    corpus = corpus_lib.synthesize(400, CFG.vocab_size, CFG.avg_nnz_per_doc,
                                   CFG.nnz_pad, seed=11)
    root = str(tmp_path_factory.mktemp("obs") / "store")
    store = FlashStore.create(root, vocab_size=CFG.vocab_size,
                              docs_per_segment=100)
    store.append_corpus(corpus)
    return corpus, root


def _query(corpus, idx=7):
    qi, qv = corpus_lib.make_query(corpus, idx, CFG.max_query_nnz)
    return qi[None], qv[None]


def _names(span):
    return [c.name for c in span.children]


# -- span mechanics ----------------------------------------------------

def test_span_tree_nests_and_is_well_formed():
    tr = QueryTrace("query", surface="test")
    with tr.root.child("plan") as p:
        p.set(segments=3)
    c = tr.root.child("score", segment="s0")
    c.end(docs=10)
    tr.finish()
    assert tr.well_formed()
    assert _names(tr.root) == ["plan", "score"]
    assert tr.root.children[1].attrs == {"segment": "s0", "docs": 10}
    d = tr.to_dict()["root"]
    assert d["start_ms"] == 0.0
    assert all(ch["start_ms"] >= 0 for ch in d["children"])


def test_unended_child_is_not_well_formed():
    tr = QueryTrace("query")
    tr.root.child("dangling")                # never ended
    tr.finish()
    assert not tr.well_formed()


def test_null_span_is_self_propagating():
    s = NULL_SPAN.child("anything", deep=1).child("deeper")
    assert s is NULL_SPAN
    assert s.set(x=1) is NULL_SPAN
    assert s.to_dict() == {}


def test_tracer_sampling_cadence():
    t = Tracer(sample_every=0)
    assert t.start("query") is None          # off by default
    t2 = Tracer(sample_every=2)
    picks = [t2.start("query") is not None for _ in range(6)]
    assert picks == [True, False, True, False, True, False]
    tr = t2.start("query")
    tr.finish()
    assert t2.last_trace is tr
    assert list(t2.recent)[-1] is tr


# -- store surface -----------------------------------------------------

def test_store_query_trace_structure(setup):
    corpus, root = setup
    obs = Obs(trace_sample=1)
    sess = FlashSearchSession(FlashStore.open(root), CFG, obs=obs)
    qi, qv = _query(corpus)
    sess.search(qi, qv)
    tr = sess.last_trace
    assert tr is not None and tr.well_formed()
    assert tr.root.attrs["surface"] == "store"
    kids = _names(tr.root)
    assert kids[0] == "plan"
    assert "merge" in kids
    loads = [c for c in tr.root.children if c.name == "load"]
    scores = [c for c in tr.root.children if c.name == "score"]
    assert loads and scores
    # cold first query: every load came from disk with decode/upload ms
    assert all(c.attrs["source"] == "disk" for c in loads)
    assert all(c.attrs["decode_ms"] >= 0 for c in loads)
    # warm second query: same segments now served from the slab cache
    sess.search(qi, qv)
    warm = [c for c in sess.last_trace.root.children if c.name == "load"]
    assert all(c.attrs["source"] == "cache" for c in warm)
    assert sess.last_trace.well_formed()
    sess.close()


def test_store_stage_histograms_populated(setup):
    corpus, root = setup
    obs = Obs()
    sess = FlashSearchSession(FlashStore.open(root), CFG, obs=obs)
    qi, qv = _query(corpus)
    sess.search(qi, qv)
    stages = {labels["stage"] for name, labels, kind, m in
              obs.registry.items() if name == "stage_ms" and m.count}
    assert {"plan", "decode", "upload", "score", "merge"} <= stages
    assert obs.registry.counter("queries_total", surface="store").value == 1
    sess.close()


# -- cluster surface ---------------------------------------------------

def test_cluster_trace_has_per_shard_subtrees(setup, tmp_path):
    corpus, _ = setup
    cl = build_sharded_store(str(tmp_path / "c"), _corpus_docs(corpus),
                             n_shards=2, replicas=1,
                             vocab_size=CFG.vocab_size, docs_per_segment=100)
    obs = Obs(trace_sample=1)
    sess = FlashClusterSession(cl, CFG, obs=obs)
    qi, qv = _query(corpus)
    r1 = sess.search(qi, qv)
    tr = sess.last_trace
    assert tr is not None and tr.well_formed()
    assert tr.root.attrs["surface"] == "cluster"
    shards = [c for c in tr.root.children if c.name == "shard"]
    assert len(shards) == 2
    for sh in shards:
        reps = [c for c in sh.children if c.name == "replica"]
        assert len(reps) == 1
        inner = _names(reps[0])
        assert inner[0] == "plan" and "merge" in inner
        assert "score" in inner
    gathers = [c for c in tr.root.children if c.name == "gather"]
    assert len(gathers) == 1
    assert tr.root.attrs["straggler_shard"] in (0, 1)
    assert tr.root.attrs["straggler_ms"] >= 0
    # per-query accounting lands once, on the cluster surface — the
    # shard sessions joined the parent trace instead of double counting
    assert obs.registry.counter("queries_total", surface="cluster").value == 1
    assert obs.registry.counter("queries_total", surface="store").value == 0
    # differential: same cluster served without observability
    sess2 = FlashClusterSession(cl, CFG, obs=Obs.disabled())
    r2 = sess2.search(qi, qv)
    np.testing.assert_array_equal(r1.doc_ids, r2.doc_ids)
    np.testing.assert_array_equal(r1.scores, r2.scores)
    sess.close()


# -- differential: tracing on must not change results ------------------

def test_store_results_bit_identical_tracing_on_vs_off(setup):
    corpus, root = setup
    on = FlashSearchSession(FlashStore.open(root), CFG,
                            obs=Obs(trace_sample=1))
    off = FlashSearchSession(FlashStore.open(root), CFG, obs=Obs.disabled())
    for idx in (0, 123, 399):
        qi, qv = _query(corpus, idx)
        a, b = on.search(qi, qv), off.search(qi, qv)
        np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
        np.testing.assert_array_equal(a.scores, b.scores)
    assert on.last_trace is not None
    on.close()
    off.close()


def test_engine_results_bit_identical_with_obs(setup):
    corpus, _ = setup
    qi, qv = _query(corpus, 42)
    e1 = PatternSearchEngine(corpus, CFG, single_device_ctx(),
                             obs=Obs(trace_sample=1))
    e2 = PatternSearchEngine(corpus, CFG, single_device_ctx(),
                             obs=Obs.disabled())
    a, b = e1.search(qi, qv), e2.search(qi, qv)
    np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
    np.testing.assert_array_equal(a.scores, b.scores)
    assert e1.obs.registry.counter("engine_compile_traces").value >= 1


def test_service_results_bit_identical_and_batch_annotated(setup):
    corpus, root = setup
    qi, qv = _query(corpus, 55)
    got = {}
    for tag, obs in (("on", Obs(trace_sample=1)), ("off", Obs.disabled())):
        sess = FlashSearchSession(FlashStore.open(root), CFG, obs=obs)
        svc = SearchService(sess, max_batch=2, max_delay_ms=1.0)
        futs = [svc.submit(qi[0], qv[0]) for _ in range(4)]
        got[tag] = [f.result() for f in futs]
        if tag == "on":
            tr = svc.last_trace
            assert tr is not None and tr.well_formed()
            assert "batch_size" in tr.root.attrs
            assert tr.root.attrs["queue_wait_ms_max"] >= 0
        svc.close()
        sess.close()
    for a, b in zip(got["on"], got["off"]):
        np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
        np.testing.assert_array_equal(a.scores, b.scores)


# -- exporters ---------------------------------------------------------

def test_exporters_write_metrics_and_traces(setup, tmp_path):
    corpus, root = setup
    obs = Obs(trace_sample=1, slow_ms=0.0)
    sess = FlashSearchSession(FlashStore.open(root), CFG, obs=obs)
    qi, qv = _query(corpus)
    sess.search(qi, qv)

    mpath = str(tmp_path / "metrics.prom")
    write_metrics(obs, mpath)
    text = open(mpath).read()
    assert "# TYPE repro_query_ms histogram" in text
    assert 'repro_queries_total{surface="store"} 1' in text

    tpath = str(tmp_path / "traces.json")
    assert write_traces(obs, tpath) == 1
    dump = json.load(open(tpath))
    assert dump["schema"] == "repro-traces-v1"
    root_node = dump["traces"][0]["root"]
    assert root_node["name"] == "query"
    assert any(c["name"] == "plan" for c in root_node["children"])

    rendered = render_trace(sess.last_trace)
    assert rendered.splitlines()[0].startswith("query")
    assert "plan" in rendered

    summary = render_summary(sess)
    assert "== observability summary ==" in summary
    assert "stage latency" in summary
    assert "slow queries" in summary
    sess.close()


def test_render_summary_disabled_degrades():
    class Bare:
        pass
    assert "disabled" in render_summary(Bare(), Obs.disabled())
