"""Distributed engine numerics on a real (placeholder) multi-device mesh.

Runs in a subprocess so the 8-device XLA_FLAGS never leaks into the other
tests (they must see 1 device per the assignment)."""
import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import numpy as np
import jax
from jax.sharding import Mesh
from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import MeshCtx

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))
ctx = MeshCtx(mesh=mesh, dp_axes=("data",), fsdp_axis="data",
              tp_axis="model")
cfg = smoke()
corpus = corpus_lib.synthesize(256, cfg.vocab_size, cfg.avg_nnz_per_doc,
                               cfg.nnz_pad, seed=5)
eng = PatternSearchEngine(corpus, cfg, ctx, backend="jnp")
idxs = [3, 77, 150, 200]   # L=4 over model axis of 2
qs = [corpus_lib.make_query(corpus, i, cfg.max_query_nnz) for i in idxs]
qi = np.stack([q[0] for q in qs]); qv = np.stack([q[1] for q in qs])
r = eng.search(qi, qv)
print(json.dumps({
    "top1": [int(x) for x in r.doc_ids[:, 0]],
    "score1": [float(x) for x in r.scores[:, 0]],
}))
"""


def test_engine_on_8_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["top1"] == [3, 77, 150, 200]          # self-search exact
    for s in res["score1"]:
        assert abs(s - 1.0) < 1e-4
