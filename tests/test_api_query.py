"""The typed request/response surface (repro/serve/api.py): Query and
QueryOptions validation, the positional deprecation shim (exercised
exactly once here, per the migration contract), SearchResponse duck
compatibility, and truncate_k."""
import warnings

import numpy as np
import pytest

from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine, SearchResult
from repro.distributed.meshctx import single_device_ctx
from repro.serve.api import (DeadlineExceeded, OverloadError, Query,
                             QueryOptions, QueryStats, SearchResponse,
                             coerce_request, truncate_k)


# ---------------------------------------------------------------------------
# Query
# ---------------------------------------------------------------------------
def test_query_validates_and_normalizes():
    q = Query([3, 7, -1], [1.0, 2.0, 0.0])
    assert q.ids.dtype == np.int32 and q.vals.dtype == np.float32
    assert q.is_single and q.n_rows == 1
    qi, qv = q.rows()
    assert qi.shape == (1, 3) == qv.shape
    fi, fv = q.flat()
    assert fi.shape == (3,) == fv.shape


def test_query_copies_its_arrays():
    ids = np.array([1, 2], np.int32)
    q = Query(ids, np.ones(2, np.float32))
    ids[0] = 99
    assert q.ids[0] == 1                    # caller mutation can't leak in


def test_query_rejects_bad_shapes():
    with pytest.raises(ValueError, match="differ"):
        Query(np.zeros((1, 4), np.int32), np.zeros((1, 3), np.float32))
    with pytest.raises(ValueError, match="1-D .* or 2-D"):
        Query(np.zeros((1, 1, 4), np.int32), np.zeros((1, 1, 4), np.float32))


def test_query_batch_rows_and_flat():
    q = Query(np.zeros((3, 4), np.int32), np.zeros((3, 4), np.float32))
    assert not q.is_single and q.n_rows == 3
    assert q.rows()[0].shape == (3, 4)
    with pytest.raises(ValueError, match="one query per Future"):
        q.flat()
    one = Query(np.zeros((1, 4), np.int32), np.zeros((1, 4), np.float32))
    assert one.flat()[0].shape == (4,)      # [1, Qn] flattens


# ---------------------------------------------------------------------------
# QueryOptions
# ---------------------------------------------------------------------------
def test_query_options_defaults_are_legacy():
    o = QueryOptions()
    assert o.deadline_ms is None and o.priority == 0
    assert o.tenant == "default" and o.k is None
    assert not o.allow_partial and o.hedging is None


def test_query_options_validate():
    with pytest.raises(ValueError):
        QueryOptions(k=0)
    with pytest.raises(ValueError):
        QueryOptions(tenant="")
    QueryOptions(deadline_ms=5.0, priority=2, k=1, allow_partial=True,
                 hedging=False)             # all knobs accepted


# ---------------------------------------------------------------------------
# the deprecation shim (the one sanctioned exercise of the legacy form)
# ---------------------------------------------------------------------------
def test_query_coerce_positional_warns_once_and_matches_typed():
    ids = np.array([5, 9, -1], np.int32)
    vals = np.array([2.0, 1.0, 0.0], np.float32)
    with pytest.warns(DeprecationWarning, match="positional arrays"):
        q, opts = coerce_request(ids, vals, None, surface="test.search")
    assert opts is None
    np.testing.assert_array_equal(q.ids, ids)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        q2, o2 = coerce_request(Query(ids, vals), None,
                                QueryOptions(k=2))  # typed: silent
    assert o2.k == 2
    np.testing.assert_array_equal(q2.ids, q.ids)


def test_query_coerce_rejects_ambiguous_and_incomplete():
    q = Query(np.zeros(2, np.int32), np.zeros(2, np.float32))
    with pytest.raises(TypeError, match="not both"):
        coerce_request(q, np.zeros(2, np.float32), None)
    with pytest.raises(TypeError, match="needs both"):
        coerce_request(np.zeros(2, np.int32), None, None)


def test_query_engine_shim_end_to_end():
    cfg = SearchConfig(name="api", vocab_size=400, avg_nnz_per_doc=8,
                       nnz_pad=16, top_k=3)
    corpus = corpus_lib.synthesize(40, cfg.vocab_size, 8, cfg.nnz_pad, seed=2)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(), backend="jnp")
    qi, qv = corpus_lib.make_query(corpus, 1, 8)
    typed = eng.search(Query(qi[None], qv[None]))
    with pytest.warns(DeprecationWarning):
        legacy = eng.search(qi[None], qv[None])
    np.testing.assert_array_equal(typed.doc_ids, legacy.doc_ids)
    np.testing.assert_array_equal(typed.scores, legacy.scores)
    resp = eng.search(Query(qi[None], qv[None]), options=QueryOptions(k=2))
    assert isinstance(resp, SearchResponse)
    np.testing.assert_array_equal(resp.doc_ids, typed.doc_ids[:, :2])


# ---------------------------------------------------------------------------
# SearchResponse / QueryStats / truncate_k
# ---------------------------------------------------------------------------
def test_query_response_quacks_like_search_result():
    res = SearchResult(np.arange(6).reshape(2, 3),
                       np.ones((2, 3), np.float32))
    resp = SearchResponse(res, QueryStats(queue_wait_ms=1.5))
    np.testing.assert_array_equal(resp.doc_ids, res.doc_ids)
    np.testing.assert_array_equal(resp.scores, res.scores)
    assert resp.stats.queue_wait_ms == 1.5


def test_query_truncate_k_prefix_only():
    res = SearchResult(np.arange(8).reshape(2, 4),
                       np.arange(8, dtype=np.float32).reshape(2, 4))
    assert truncate_k(res, None) is res
    assert truncate_k(res, 4) is res        # not smaller: no copy
    cut = truncate_k(res, 2)
    np.testing.assert_array_equal(cut.doc_ids, res.doc_ids[:, :2])
    np.testing.assert_array_equal(cut.scores, res.scores[:, :2])


def test_query_scheduling_errors_are_typed():
    assert issubclass(OverloadError, RuntimeError)
    assert issubclass(DeadlineExceeded, TimeoutError)
    e = OverloadError("full", tenant="t", reason="quota", depth=3, limit=4)
    assert (e.tenant, e.reason, e.depth, e.limit) == ("t", "quota", 3, 4)
    d = DeadlineExceeded("late", deadline_ms=10.0, late_ms=2.5, where="queue")
    assert (d.deadline_ms, d.late_ms, d.where) == (10.0, 2.5, "queue")
