"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward + one train-grad step + prefill/decode on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_NAMES, get_config, get_smoke_config
from repro.distributed.meshctx import single_device_ctx
from repro.models import model as M

jax.config.update("jax_enable_x64", False)


def _smoke_batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.embeds_input:
        batch["embeds"] = jax.random.normal(
            k, (B, S, cfg.d_model), jnp.float32) * 0.02
        batch["labels"] = batch.pop("tokens")
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            k, (B, cfg.n_image_tokens, cfg.d_model), jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    ctx = single_device_ctx()
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    B, S = 2, 16

    fwd = jax.jit(lambda p, b: M.apply_train(p, cfg, ctx, b)[:2])
    logits, aux = fwd(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert np.isfinite(float(aux))

    loss_f = jax.jit(lambda p, b: M.loss_fn(p, cfg, ctx, b)[0])
    loss = loss_f(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0

    grads = jax.jit(jax.grad(lambda p: M.loss_fn(p, cfg, ctx, batch)[0]))(params)
    gn = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: float(jnp.sum(jnp.square(g.astype(jnp.float32)))), grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_then_decode(arch):
    cfg = get_smoke_config(arch)
    ctx = single_device_ctx()
    params = M.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _smoke_batch(cfg, B, S)

    prefill = jax.jit(lambda p, b: M.apply_prefill(p, cfg, ctx, b))
    logits, _, cache = prefill(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert cache is not None, f"{arch}: prefill must return a cache"

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        # grow the KV cache to max_len for decode
        full = M.init_cache(cfg, B, S + 4)
        def place(dst, src):
            if dst.shape == src.shape:
                return src
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * src.ndim)
        cache = jax.tree.map(place, full, cache)

    step = {"tokens": jnp.full((B, 1), 3, jnp.int32)}
    if cfg.embeds_input:
        step = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32),
                "labels": jnp.full((B, 1), 3, jnp.int32)}
    if cfg.family == "vlm":
        step["image_embeds"] = batch["image_embeds"]
    decode = jax.jit(lambda p, s, c, i: M.apply_decode(p, cfg, ctx, s, c, i))
    logits2, _, cache2 = decode(params, step, cache, jnp.int32(S))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_is_exact(arch):
    """The full (non-smoke) configs carry the exact assigned hyperparams."""
    cfg = get_config(arch)
    assigned = {
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840, 384, 8),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936, 128, 8),
        "rwkv6-7b": (32, 4096, None, None, 14336, 65536, 0, 0),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000, 0, 0),
        "llama-3.2-vision-90b": (80, 8192, 64, 8, 28672, 128256, 0, 0),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936, 0, 0),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544, 0, 0),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144, 0, 0),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936, 0, 0),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048, 0, 0),
    }[arch]
    L_, d, H, kv, ff, V, E, k = assigned
    assert cfg.n_layers == L_ and cfg.d_model == d
    if H is not None:
        assert cfg.n_heads == H and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == V
    assert cfg.n_experts == E and cfg.top_k == k


def test_param_counts_in_band():
    """Analytic param counts should land near the advertised sizes."""
    expect = {
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "qwen3-moe-235b-a22b": (2.0e11, 2.6e11),
        "rwkv6-7b": (6.0e9, 8.5e9),
        "zamba2-1.2b": (0.9e9, 1.5e9),
        "llama-3.2-vision-90b": (8.0e10, 10.0e10),
        "qwen2-0.5b": (3.5e8, 6.5e8),
        "internlm2-20b": (1.7e10, 2.3e10),
        "gemma3-4b": (3.0e9, 5.0e9),
        "qwen3-4b": (3.2e9, 5.0e9),
        "musicgen-medium": (1.1e9, 1.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_long_context_rule():
    longs = {a: get_config(a).supports_long_context for a in ARCH_NAMES}
    assert longs["rwkv6-7b"] and longs["zamba2-1.2b"] and longs["gemma3-4b"]
    assert not longs["kimi-k2-1t-a32b"] and not longs["qwen2-0.5b"]
    assert not longs["musicgen-medium"]
