"""The CI perf-regression gate (DESIGN.md §13): per-row tolerance
bands, the absolute noise floor, informational new/missing rows,
schema validation, and the end-to-end exit status of bench_compare."""
import json
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
from bench_compare import (DEFAULT_TOL, GATES, compare, compare_row,
                           load_rows)  # noqa: E402

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks")


def _report(rows):
    return {"schema": "repro-bench-v1",
            "benches": {"storage": {
                "rows": [{"name": n, "us_per_call": v, "derived": {}}
                         for n, v in rows.items()]}}}


def _write(path, rows):
    path.write_text(json.dumps(_report(rows)))
    return str(path)


# -- per-row verdicts --------------------------------------------------

def test_warm_row_band_is_15_percent():
    name = "storage/warm_query_ms"
    assert GATES[name] == 0.15
    st, delta, tol = compare_row(name, 10_000.0, 12_000.0)   # +20%
    assert st == "FAIL" and delta == pytest.approx(0.20) and tol == 0.15
    st, *_ = compare_row(name, 10_000.0, 11_000.0)           # +10%: inside
    assert st == "ok"
    st, *_ = compare_row(name, 10_000.0, 8_000.0)            # -20%
    assert st == "improved"


def test_cold_and_unlisted_rows_get_loose_bands():
    st, _, tol = compare_row("storage/cold_query_ms", 10_000.0, 14_000.0)
    assert st == "ok" and tol == 0.50                        # +40% < 50%
    st, _, tol = compare_row("some/new_row", 10_000.0, 14_000.0)
    assert st == "ok" and tol == DEFAULT_TOL
    st, *_ = compare_row("some/new_row", 10_000.0, 16_000.0)
    assert st == "FAIL"


def test_noise_floor_suppresses_tiny_rows():
    # 120 us -> 400 us is a +233% "regression" made of scheduler jitter
    st, delta, _ = compare_row("storage/warm_query_ms", 120.0, 400.0)
    assert st == "noise" and delta > 2.0
    # but a row that *crosses* the floor still gates
    st, *_ = compare_row("storage/warm_query_ms", 450.0, 900.0)
    assert st == "FAIL"
    # and the floor is tunable
    st, *_ = compare_row("storage/warm_query_ms", 120.0, 400.0, min_us=50.0)
    assert st == "FAIL"


def test_zero_baseline_is_noise():
    st, delta, _ = compare_row("x", 0.0, 5000.0)
    assert st == "noise" and delta == 0.0


# -- full-report diff --------------------------------------------------

def test_new_and_missing_rows_are_informational():
    base = {"storage/warm_query_ms": 10_000.0, "storage/gone": 9_000.0}
    cur = {"storage/warm_query_ms": 10_100.0, "storage/added": 7_000.0}
    lines, failed = compare(base, cur)
    assert failed == []
    joined = "\n".join(lines)
    assert "only in baseline" in joined and "new row" in joined
    assert "informational" in joined


def test_compare_collects_failures():
    base = {"storage/warm_query_ms": 10_000.0,
            "storage/fused_warm_query_ms": 10_000.0}
    cur = {"storage/warm_query_ms": 12_000.0,          # +20%: fails
           "storage/fused_warm_query_ms": 10_500.0}    # +5%: ok
    lines, failed = compare(base, cur)
    assert failed == ["storage/warm_query_ms"]
    assert any(l.strip().startswith("FAIL") for l in lines)


# -- file loading ------------------------------------------------------

def test_load_rows_flattens_report(tmp_path):
    p = _write(tmp_path / "a.json", {"x": 1.0, "y": 2.0})
    assert load_rows(p) == {"x": 1.0, "y": 2.0}


def test_load_rows_rejects_unknown_schema(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"schema": "other-v9", "benches": {}}))
    with pytest.raises(SystemExit):
        load_rows(str(p))


def test_committed_baseline_loads_and_self_compares():
    baseline = os.path.join(BENCH_DIR, "BENCH_baseline.json")
    rows = load_rows(baseline)
    assert "storage/warm_query_ms" in rows
    assert all(g in rows for g in GATES if g.startswith("storage/"))
    lines, failed = compare(rows, rows)        # identity: nothing gates
    assert failed == []


# -- CLI end-to-end ----------------------------------------------------

def _run(*argv):
    return subprocess.run(
        [sys.executable, os.path.join(BENCH_DIR, "bench_compare.py"),
         *argv], capture_output=True, text=True)


def test_cli_exit_codes(tmp_path):
    base = _write(tmp_path / "base.json",
                  {"storage/warm_query_ms": 10_000.0})
    good = _write(tmp_path / "good.json",
                  {"storage/warm_query_ms": 10_800.0})
    bad = _write(tmp_path / "bad.json",
                 {"storage/warm_query_ms": 13_000.0})
    r = _run(base, good)
    assert r.returncode == 0 and "no gated regressions" in r.stdout
    r = _run(base, bad)
    assert r.returncode == 1
    assert "regressed beyond tolerance" in r.stderr


def test_cli_update_baseline(tmp_path):
    base = _write(tmp_path / "base.json",
                  {"storage/warm_query_ms": 10_000.0})
    cur = _write(tmp_path / "cur.json",
                 {"storage/warm_query_ms": 13_000.0})
    r = _run(base, cur, "--update-baseline")
    assert r.returncode == 0
    assert load_rows(base) == {"storage/warm_query_ms": 13_000.0}
