"""Cluster scatter/gather correctness: bit-equivalence to a single-store
scan of the union corpus, adversarial shard layouts, replica failover,
and the per-shard compile-cache bound (DESIGN.md §5)."""
import shutil
import threading

import numpy as np
import pytest

from repro.cluster import (ClusterSearchError, FlashClusterSession,
                           build_sharded_store)
from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.storage import FlashSearchSession, FlashStore
from repro.storage.store import _corpus_docs


def _union_session(tmp, docs, cfg, docs_per_segment=64, name="union"):
    store = FlashStore.create(str(tmp / name), vocab_size=cfg.vocab_size,
                              docs_per_segment=docs_per_segment)
    if docs:
        store.append_docs(docs)
    return FlashSearchSession(store, cfg)


def _query_rows(pairs_list, qn):
    qi = np.full((len(pairs_list), qn), -1, np.int32)
    qv = np.zeros((len(pairs_list), qn), np.float32)
    for l, pairs in enumerate(pairs_list):
        for j, (w, c) in enumerate(pairs):
            qi[l, j] = w
            qv[l, j] = c
    return qi, qv


def _assert_same(r, ref):
    np.testing.assert_array_equal(r.doc_ids, ref.doc_ids)
    np.testing.assert_array_equal(r.scores, ref.scores)


# ---------------------------------------------------------------------------
# the ISSUE acceptance shape: 4 shards x 2 replicas vs the union store
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    cfg = smoke()
    corpus = corpus_lib.synthesize(400, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=5)
    docs = _corpus_docs(corpus)
    tmp = tmp_path_factory.mktemp("cluster")
    union = _union_session(tmp, docs, cfg)
    cl = build_sharded_store(str(tmp / "c4x2"), docs, n_shards=4,
                             replicas=2, policy="hash",
                             vocab_size=cfg.vocab_size, docs_per_segment=32)
    sess = FlashClusterSession(cl, cfg)
    yield cfg, corpus, union, sess
    sess.close()
    union.close()


def _queries(corpus, cfg, idxs):
    qs = [corpus_lib.make_query(corpus, i, cfg.max_query_nnz) for i in idxs]
    return np.stack([q[0] for q in qs]), np.stack([q[1] for q in qs])


def test_cluster_matches_union_store_exactly(setup):
    cfg, corpus, union, sess = setup
    qi, qv = _queries(corpus, cfg, [3, 111, 250, 399])
    _assert_same(sess.search(qi, qv), union.search(qi, qv))
    st = sess.last_stats
    assert st.docs_scored == corpus.n_docs       # every doc in some shard
    assert all(s is not None for s in st.per_shard)
    assert st.failovers == 0


def test_cluster_range_policy_matches_too(setup, tmp_path):
    cfg, corpus, union, _ = setup
    cl = build_sharded_store(str(tmp_path / "range"),
                             _corpus_docs(corpus), n_shards=3,
                             policy="range", vocab_size=cfg.vocab_size,
                             docs_per_segment=32)
    with FlashClusterSession(cl, cfg) as sess:
        qi, qv = _queries(corpus, cfg, [42, 200])
        _assert_same(sess.search(qi, qv), union.search(qi, qv))


def test_concurrent_submits_match_serial_rows(setup):
    """16 clients through the cluster's coalescing service: every Future
    resolves to exactly the union store's serial row."""
    cfg, corpus, union, sess = setup
    idxs = [7 * i % 400 for i in range(16)]
    refs = {}
    for i in idxs:
        qi, qv = _queries(corpus, cfg, [i])
        refs[i] = union.search(qi, qv)
    svc = sess.service(max_batch=8, max_delay_ms=5.0)
    errs = []

    def client(i):
        try:
            q = corpus_lib.make_query(corpus, i, cfg.max_query_nnz)
            r = svc.submit(q[0], q[1]).result(timeout=120)
            np.testing.assert_array_equal(r.doc_ids, refs[i].doc_ids[0])
            np.testing.assert_array_equal(r.scores, refs[i].scores[0])
        except Exception as e:                    # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in idxs]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs


def test_per_shard_compile_counts_within_bucket_bound(setup):
    """After serving every batch size up to max_batch, each shard's
    engine holds to the §7.2 bound: <= log2(max_batch) + 1 programs."""
    cfg, corpus, union, sess = setup
    rng = np.random.default_rng(0)
    L = 1
    while L <= 8:
        qi, qv = _queries(corpus, cfg,
                          [int(rng.integers(400)) for _ in range(L)])
        sess.search(qi, qv)
        L *= 2
    assert all(c <= 4 for c in sess.compile_stats["per_shard"])  # log2(8)+1


# ---------------------------------------------------------------------------
# adversarial layouts (distinct scores by construction)
# ---------------------------------------------------------------------------
def _graded_docs(n):
    """doc i = {word 0: 1, word i+1: i+2} -> query {0} scores strictly
    decrease with i: equivalence is tie-free even at the top-k tail."""
    return [(i, [(0, 1), (i + 1, i + 2)]) for i in range(n)]


def test_all_shards_skipped_returns_sentinel(tmp_path):
    cfg = smoke()
    docs = _graded_docs(24)
    cl = build_sharded_store(str(tmp_path / "c"), docs, n_shards=4,
                             policy="hash", vocab_size=cfg.vocab_size,
                             docs_per_segment=4)
    with FlashClusterSession(cl, cfg) as sess:
        qi, qv = _query_rows([[(200, 1)], [(300, 2)]], 4)  # absent words
        r = sess.search(qi, qv)
        assert r.doc_ids.shape == (2, cfg.top_k)
        assert (r.doc_ids == -1).all()
        assert np.isneginf(r.scores).all()
        st = sess.last_stats
        assert st.skip_rate == 1.0
        assert st.segments_scored == 0 and st.docs_scored == 0


def test_empty_shards_and_k_gt_shard_rows(tmp_path):
    """6 docs over 4 range shards (some empty, every shard smaller than
    top_k=4): cluster == union, -1 tail included."""
    cfg = smoke()
    docs = _graded_docs(6)
    union = _union_session(tmp_path, docs, cfg, docs_per_segment=2)
    cl = build_sharded_store(str(tmp_path / "c"), docs, n_shards=4,
                             policy="range", vocab_size=cfg.vocab_size,
                             docs_per_segment=2)
    assert 0 in [s["n_docs"] for s in cl.manifest["shards"]] or \
        max(s["n_docs"] for s in cl.manifest["shards"]) < cfg.top_k
    with FlashClusterSession(cl, cfg) as sess:
        qi, qv = _query_rows([[(0, 1)]], 4)
        r, ref = sess.search(qi, qv), union.search(qi, qv)
        _assert_same(r, ref)
        np.testing.assert_array_equal(r.doc_ids[0],
                                      [0, 1, 2, 3])       # graded order
    # k exceeds every doc: tail is the -1 / -inf sentinel
    cl2 = build_sharded_store(str(tmp_path / "c2"), _graded_docs(2),
                              n_shards=4, policy="hash",
                              vocab_size=cfg.vocab_size)
    with FlashClusterSession(cl2, cfg) as sess:
        r = sess.search(*_query_rows([[(0, 1)]], 4))
        assert (r.doc_ids[0, 2:] == -1).all()
        assert np.isneginf(r.scores[0, 2:]).all()
    union.close()


def test_dup_doc_id_across_shards_keeps_higher_score(tmp_path):
    """A doc id present in two shards (adversarial hand-append) must
    surface once, with its best score — _merge_results' dedup at the
    gather stage."""
    cfg = smoke()
    cl = build_sharded_store(str(tmp_path / "c"), _graded_docs(8),
                             n_shards=2, policy="range",
                             vocab_size=cfg.vocab_size, docs_per_segment=4)
    # id 100 in both shards: shard 0's copy scores lower (extra word),
    # shard 1's copy is a perfect match for the probe query
    cl.store(0, 0).append_docs([(100, [(50, 3), (60, 4)])])
    cl.store(1, 0).append_docs([(100, [(50, 3)])])
    with FlashClusterSession(cl, cfg) as sess:
        r = sess.search(*_query_rows([[(50, 3)]], 4))
        assert r.doc_ids[0, 0] == 100
        np.testing.assert_allclose(r.scores[0, 0], 1.0, rtol=1e-6)
        assert (r.doc_ids[0] == 100).sum() == 1      # deduped
        assert (r.doc_ids[0, 1:] == -1).all()        # nothing else matches


# ---------------------------------------------------------------------------
# replica failover
# ---------------------------------------------------------------------------
def test_kill_one_replica_mid_run_degrades_nothing(tmp_path):
    cfg = smoke()
    corpus = corpus_lib.synthesize(200, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=9)
    docs = _corpus_docs(corpus)
    union = _union_session(tmp_path, docs, cfg)
    cl = build_sharded_store(str(tmp_path / "c"), docs, n_shards=4,
                             replicas=2, policy="hash",
                             vocab_size=cfg.vocab_size, docs_per_segment=16)
    sess = FlashClusterSession(cl, cfg)
    qi, qv = _queries(corpus, cfg, [1, 99, 150])
    _assert_same(sess.search(qi, qv), union.search(qi, qv))   # warm, healthy

    # kill shard 2's primary replica mid-run: delete its directory, so the
    # next touch fails the way a dead slice would
    shutil.rmtree(sess.router._session(2, 0).store.root)
    sess.router._sessions[2][0] = _Exploding(sess.router._sessions[2][0])

    _assert_same(sess.search(qi, qv), union.search(qi, qv))   # failed over
    assert sess.router.health()[2] == [False, True]
    assert sess.last_stats.failovers == 1
    _assert_same(sess.search(qi, qv), union.search(qi, qv))
    assert sess.router.failovers == 1        # dead replica never retried
    sess.close()
    union.close()


class _Exploding:
    """Stands in for a session whose backing replica died."""

    def __init__(self, inner):
        self._inner = inner

    def search(self, *a, **k):
        raise OSError("replica storage gone")

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_all_replicas_down_raises_cluster_error(tmp_path):
    cfg = smoke()
    cl = build_sharded_store(str(tmp_path / "c"), _graded_docs(12),
                             n_shards=2, replicas=2, policy="hash",
                             vocab_size=cfg.vocab_size, docs_per_segment=4)
    sess = FlashClusterSession(cl, cfg)
    qi, qv = _query_rows([[(0, 1)]], 4)
    sess.search(qi, qv)                          # open every primary
    for r in range(2):
        sess.router._sessions[0][r] = _Exploding(
            sess.router._session(0, r))
    with pytest.raises(ClusterSearchError, match="shard 0"):
        sess.search(qi, qv)
    # every replica failed -> the fault travels with the query, so no
    # replica is health-marked: one bad request must not brick the shard
    assert sess.router.health()[0] == [True, True]
    assert sess.router.failovers == 0
    sess.close()


def test_malformed_query_does_not_poison_health(tmp_path):
    """A query that fails identically on every replica raises without
    health marks; the next well-formed query is served normally."""
    cfg = smoke()
    cl = build_sharded_store(str(tmp_path / "c"), _graded_docs(12),
                             n_shards=2, replicas=2, policy="hash",
                             vocab_size=cfg.vocab_size, docs_per_segment=4)
    sess = FlashClusterSession(cl, cfg)
    bad_qi = np.full((1, 4), -1, np.int32)       # ids/vals width mismatch
    bad_qi[0, 0] = 0
    bad_qv = np.ones((1, 3), np.float32)
    with pytest.raises(ClusterSearchError):
        sess.search(bad_qi, bad_qv)
    assert all(h == [True, True] for h in sess.router.health())
    qi, qv = _query_rows([[(0, 1)]], 4)
    assert sess.search(qi, qv).doc_ids[0, 0] == 0   # still serving
    sess.close()


def test_cluster_session_rejects_vocab_mismatch(tmp_path):
    cfg = smoke()                                 # vocab_size = 512
    cl = build_sharded_store(str(tmp_path / "c"), _graded_docs(4),
                             n_shards=2, vocab_size=1024)
    with pytest.raises(ValueError, match="vocab_size"):
        FlashClusterSession(cl, cfg)
    cl.close()


def test_submit_after_close_raises(tmp_path):
    cfg = smoke()
    cl = build_sharded_store(str(tmp_path / "c"), _graded_docs(4),
                             n_shards=2, vocab_size=cfg.vocab_size)
    sess = FlashClusterSession(cl, cfg)
    sess.close()
    with pytest.raises(RuntimeError, match="closed"):
        sess.submit(np.array([0], np.int32), np.array([1.0], np.float32))
