"""Storage tier units: filters, segment files, FlashStore, prefetcher."""
import os
import threading

import numpy as np
import pytest

from repro.core import stream_format as sf
from repro.storage import filter as filter_lib
from repro.storage import segment as segment_lib
from repro.storage.prefetch import Prefetcher
from repro.storage.store import FlashStore


def _rand_docs(n, vocab, rng, max_pairs=30, start_id=0):
    return [(start_id + i,
             sorted((int(w), int(rng.integers(1, 20))) for w in
                    rng.choice(vocab, int(rng.integers(1, max_pairs)),
                               replace=False)))
            for i in range(n)]


# ---------------------------------------------------------------------------
# filters
# ---------------------------------------------------------------------------
def test_bitmap_filter_exact():
    words = np.array([0, 3, 17, 511])
    f = filter_lib.BitmapFilter.build(words, vocab_size=512)
    assert f.contains(words).all()
    absent = np.setdiff1d(np.arange(512), words)
    assert not f.contains(absent).any()
    assert not f.contains_any(absent)
    assert f.contains_any([5, 17])
    # negative / out-of-range ids never match
    assert not f.contains([-1, 600]).any()


def test_bitmap_filter_roundtrip():
    f = filter_lib.BitmapFilter.build([2, 9], vocab_size=100)
    g = filter_lib.from_meta(f.meta(), f.to_bytes())
    np.testing.assert_array_equal(f.bits, g.bits)
    assert g.contains_any([9]) and not g.contains_any([3])


def test_bloom_filter_no_false_negatives_and_low_fp():
    rng = np.random.default_rng(0)
    words = rng.choice(1 << 19, 2000, replace=False)
    f = filter_lib.BloomFilter.build(words, bits_per_key=10)
    assert f.contains(words).all()          # Bloom never false-negatives
    absent = np.setdiff1d(rng.choice(1 << 19, 20_000, replace=False), words)
    fp = f.contains(absent).mean()
    assert fp < 0.02, f"false positive rate {fp:.4f}"
    g = filter_lib.from_meta(f.meta(), f.to_bytes())
    np.testing.assert_array_equal(f.words, g.words)


def test_build_filter_auto_selects():
    assert isinstance(filter_lib.build_filter([1], vocab_size=512),
                      filter_lib.BitmapFilter)
    assert isinstance(filter_lib.build_filter([1], vocab_size=1 << 24),
                      filter_lib.BloomFilter)
    assert isinstance(filter_lib.build_filter([1], vocab_size=None),
                      filter_lib.BloomFilter)


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------
def test_segment_roundtrip_and_pages(tmp_path):
    rng = np.random.default_rng(1)
    docs = _rand_docs(57, 500, rng)
    path = str(tmp_path / "seg.rsps")
    segment_lib.write_segment(path, docs, page_items=64, vocab_size=512)
    with segment_lib.Segment(path) as seg:
        assert seg.n_docs == 57
        assert seg.doc_id_range == (0, 56)
        assert sf.decode(seg.stream()) == docs
        # pages tile the stream exactly and respect the size budget
        rebuilt = np.concatenate(
            [seg.page_stream(i) for i in range(seg.n_pages)])
        np.testing.assert_array_equal(rebuilt, seg.stream())
        assert all(p["n_items"] <= 64 for p in seg.footer["pages"])
        # every page is independently decodable (doc-aligned splits)
        per_page = [d for i in range(seg.n_pages)
                    for d in sf.decode(seg.page_stream(i))]
        assert per_page == docs
        # filter covers exactly the segment's vocabulary
        words = np.unique([w for _, ps in docs for w, _ in ps])
        assert seg.vocab_filter.contains(words).all()
        assert not seg.vocab_filter.contains_any(
            np.setdiff1d(np.arange(512), words))


def test_segment_oversized_doc_gets_own_page(tmp_path):
    docs = [(0, [(w, 1) for w in range(100)]),   # 101 items > page budget
            (1, [(5, 2)])]
    path = str(tmp_path / "big.rsps")
    segment_lib.write_segment(path, docs, page_items=32, vocab_size=512)
    with segment_lib.Segment(path) as seg:
        assert seg.n_pages == 2
        assert seg.footer["pages"][0]["n_items"] == 101
        assert sf.decode(seg.stream()) == docs


def test_segment_rejects_corruption(tmp_path):
    path = str(tmp_path / "seg.rsps")
    segment_lib.write_segment(path, [(0, [(1, 1)])], vocab_size=16)
    raw = open(path, "rb").read()
    bad = str(tmp_path / "bad.rsps")
    with open(bad, "wb") as f:
        f.write(raw[:-4])                    # truncated footer magic
    with pytest.raises(ValueError):
        segment_lib.Segment(bad)


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------
def test_store_append_open_scan(tmp_path):
    rng = np.random.default_rng(2)
    root = str(tmp_path / "store")
    store = FlashStore.create(root, vocab_size=512, docs_per_segment=20)
    docs = _rand_docs(70, 500, rng)
    store.append_docs(docs)
    assert store.n_segments == 4             # 20+20+20+10
    assert store.n_docs == 70
    assert store.max_segment_docs == 20
    store.close()
    # reopen from disk and decode everything back
    store2 = FlashStore.open(root)
    assert store2.n_docs == 70
    got = []
    for seg in store2.segments():
        got.extend(seg.docs())
    assert got == docs
    corpus = store2.scan_corpus(nnz_pad=32)
    assert corpus.n_docs == 70
    store2.close()


def test_store_create_refuses_overwrite(tmp_path):
    root = str(tmp_path / "store")
    FlashStore.create(root, vocab_size=16).close()
    with pytest.raises(FileExistsError):
        FlashStore.create(root, vocab_size=16)


def test_store_compact_merges_and_gcs(tmp_path):
    rng = np.random.default_rng(3)
    root = str(tmp_path / "store")
    store = FlashStore.create(root, vocab_size=512, docs_per_segment=8)
    for lo in range(0, 30, 10):              # three small appends
        store.append_docs(_rand_docs(10, 500, rng, start_id=lo))
    assert store.n_segments == 6             # ceil(10/8) * 3
    # plant an orphan from a hypothetical crashed append
    orphan = os.path.join(root, "seg-999999.rsps")
    open(orphan, "wb").write(b"junk")
    before = {d for d, _ in
              (doc for seg in store.segments() for doc in seg.docs())}
    store.compact(docs_per_segment=16)
    assert store.n_segments == 2             # 30 docs / 16
    assert not os.path.exists(orphan)
    after = [doc for seg in store.segments() for doc in seg.docs()]
    assert {d for d, _ in after} == before
    assert store.n_docs == 30
    store.close()


def test_compact_fsyncs_directory_after_manifest_swap(tmp_path, monkeypatch):
    """The manifest swap is only durable once the directory entry is
    flushed: compact() must fsync the store dir *after* os.replace, or a
    crash could resurrect the old manifest — which names segments the
    GC below already deleted."""
    from repro.storage import store as store_mod
    rng = np.random.default_rng(7)
    root = str(tmp_path / "store")
    store = FlashStore.create(root, vocab_size=512, docs_per_segment=8)
    store.append_docs(_rand_docs(10, 500, rng))
    events = []
    real_replace, real_fsync_dir = os.replace, store_mod.fsync_dir
    monkeypatch.setattr(
        os, "replace",
        lambda src, dst: (events.append(("replace", dst))
                          if dst.endswith("MANIFEST.json") else None,
                          real_replace(src, dst))[-1])
    monkeypatch.setattr(
        store_mod, "fsync_dir",
        lambda path: (events.append(("fsync_dir", path)),
                      real_fsync_dir(path))[-1])
    store.compact()
    replace_at = [i for i, (kind, _) in enumerate(events)
                  if kind == "replace"]
    fsync_at = [i for i, (kind, path) in enumerate(events)
                if kind == "fsync_dir" and path == root]
    assert replace_at and fsync_at
    assert fsync_at[-1] > replace_at[-1]     # dirent flushed after the swap
    store.close()


def test_compact_fsyncs_segment_data_before_manifest(tmp_path, monkeypatch):
    """A durable manifest must never reference unsynced segment data:
    compact's rewrites fsync their file before the manifest swap."""
    rng = np.random.default_rng(9)
    root = str(tmp_path / "store")
    store = FlashStore.create(root, vocab_size=512, docs_per_segment=8)
    store.append_docs(_rand_docs(20, 500, rng))
    synced = []
    real_fsync = os.fsync
    monkeypatch.setattr(os, "fsync",
                        lambda fd: (synced.append(fd), real_fsync(fd))[-1])
    store.compact(docs_per_segment=16)
    # 2 rewritten segments + the manifest tmp file, before the dir fsync
    assert len(synced) >= 3
    store.close()


def test_compact_crash_between_swap_and_gc_recovers(tmp_path, monkeypatch):
    """Crash injection at compact's commit point (the directory fsync,
    right after the manifest rename): the new manifest is live, the
    replaced segment files are still on disk, and the next compact GCs
    them without losing a document — the sibling of the crashed-rebalance
    test in test_cluster_partition.py."""
    from repro.storage import store as store_mod
    rng = np.random.default_rng(8)
    root = str(tmp_path / "store")
    store = FlashStore.create(root, vocab_size=512, docs_per_segment=8)
    for lo in range(0, 30, 10):
        store.append_docs(_rand_docs(10, 500, rng, start_id=lo))
    before = {d for seg in store.segments() for d, _ in seg.docs()}
    old_names = {e.name for e in store.entries}

    class Crash(RuntimeError):
        pass

    def crashing_fsync_dir(path):
        raise Crash("power loss after rename, before dirent flush")

    monkeypatch.setattr(store_mod, "fsync_dir", crashing_fsync_dir)
    with pytest.raises(Crash):
        store.compact(docs_per_segment=16)
    monkeypatch.setattr(store_mod, "fsync_dir", lambda path: None)
    # the swap itself landed: a reopen sees the compacted manifest, with
    # the replaced files still occupying the directory
    store2 = FlashStore.open(root)
    assert {e.name for e in store2.entries}.isdisjoint(old_names)
    leftovers = {f for f in os.listdir(root) if f.endswith(".rsps")} \
        - {e.name for e in store2.entries}
    assert leftovers == old_names
    store2.compact()                          # GC pass removes them
    on_disk = {f for f in os.listdir(root) if f.endswith(".rsps")}
    assert on_disk == {e.name for e in store2.entries}
    assert {d for seg in store2.segments()
            for d, _ in seg.docs()} == before
    store2.close()


# ---------------------------------------------------------------------------
# prefetcher
# ---------------------------------------------------------------------------
def test_prefetcher_preserves_order_and_overlaps():
    loaded = []

    def load(i):
        loaded.append(i)
        return i * 10

    with Prefetcher(range(20), load, depth=2) as pf:
        assert list(pf) == [i * 10 for i in range(20)]
    assert loaded == list(range(20))


def test_prefetcher_propagates_worker_exception():
    def load(i):
        if i == 3:
            raise RuntimeError("disk on fire")
        return i

    pf = Prefetcher(range(10), load, depth=2)
    got = []
    with pytest.raises(RuntimeError, match="disk on fire"):
        for v in pf:
            got.append(v)
    assert got == [0, 1, 2]


def test_prefetcher_close_stops_worker():
    started = threading.Event()

    def load(i):
        started.set()
        return i

    pf = Prefetcher(range(1_000_000), load, depth=2)
    started.wait(timeout=5)
    pf.close()
    assert not pf._worker.is_alive()
