"""Data pipeline: determinism, resumability, epoch-tagged prefetch."""
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import PrefetchingLoader, SyntheticLMData
from repro.distributed.meshctx import single_device_ctx


def test_batches_are_pure_function_of_step():
    cfg = get_smoke_config("qwen3-4b")
    d1 = SyntheticLMData(cfg, 4, 32, seed=7)
    d2 = SyntheticLMData(cfg, 4, 32, seed=7)
    for step in [0, 5, 1000, 123456]:
        np.testing.assert_array_equal(d1.batch_at(step)["tokens"],
                                      d2.batch_at(step)["tokens"])
    assert not np.array_equal(d1.batch_at(1)["tokens"],
                              d1.batch_at(2)["tokens"])


def test_loader_sequences_and_seek():
    cfg = get_smoke_config("qwen2-0.5b")
    data = SyntheticLMData(cfg, 2, 16, seed=3)
    loader = PrefetchingLoader(data, single_device_ctx())
    try:
        b0 = loader.next(0)
        b1 = loader.next(1)
        np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                      data.batch_at(0)["tokens"])
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      data.batch_at(1)["tokens"])
        # restart semantics: seek discards speculative prefetches (the
        # paper's epoch-tagged mispredict discard, host edition)
        loader.seek(10)
        b10 = loader.next(10)
        np.testing.assert_array_equal(np.asarray(b10["tokens"]),
                                      data.batch_at(10)["tokens"])
    finally:
        loader.close()


def test_vlm_and_audio_batches_have_frontend_stubs():
    vlm = get_smoke_config("llama-3.2-vision-90b")
    b = SyntheticLMData(vlm, 2, 8, seed=0).batch_at(0)
    assert b["image_embeds"].shape == (2, vlm.n_image_tokens, vlm.d_model)
    audio = get_smoke_config("musicgen-medium")
    b = SyntheticLMData(audio, 2, 8, seed=0).batch_at(0)
    assert b["embeds"].shape == (2, 8, audio.d_model)
    assert b["labels"].shape == (2, 8)
