"""Property test (ISSUE acceptance): trace span trees stay well-formed
— every span ended, every child interval nested inside its parent —
under ANY random interleaving of append / seal / compact / search on a
live ingesting session with tracing at sample_every=1, and the registry
counters keep exact query accounting throughout (DESIGN.md §8.2).

Runs under real hypothesis when installed and under the
``tests/hypothesis_compat`` random-sampling fallback otherwise."""
import shutil
import tempfile

import numpy as np

from hypothesis_compat import given, settings, strategies as st

from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.obs import Obs
from repro.storage import FlashSearchSession, FlashStore
from repro.storage.store import _corpus_docs

CFG = smoke()
_CORPUS = corpus_lib.synthesize(80, CFG.vocab_size, CFG.avg_nnz_per_doc,
                                CFG.nnz_pad, seed=23)
_POOL = _corpus_docs(_CORPUS)

# append-heavy so the structural ops see a growing store; every search
# is a trace + counter checkpoint
_OP = st.sampled_from(["append", "append", "append", "append",
                       "seal", "compact", "search"])


def _probe(pairs):
    qi = np.full((1, CFG.max_query_nnz), -1, np.int32)
    qv = np.zeros((1, CFG.max_query_nnz), np.float32)
    for j, (w, c) in enumerate(pairs[:CFG.max_query_nnz]):
        qi[0, j] = w
        qv[0, j] = c
    return qi, qv


@settings(max_examples=6, deadline=None)
@given(ops=st.lists(_OP, min_size=3, max_size=24))
def test_traces_stay_well_formed_under_interleavings(ops):
    tmp = tempfile.mkdtemp(prefix="obs-prop-")
    obs = Obs(trace_sample=1)
    sess = None
    try:
        store = FlashStore.create(f"{tmp}/live", vocab_size=CFG.vocab_size,
                                  docs_per_segment=8)
        sess = FlashSearchSession(store, CFG, obs=obs)
        sess.enable_ingest(seal_docs=6, fold_min_segments=2,
                           auto_compact=False)
        appended = []
        searches = 0
        nxt = iter(_POOL)
        for op in ops + ["search"]:          # always verify the end state
            if op == "append":
                d, p = next(nxt)
                sess.append(d, p)
                appended.append((d, p))
            elif op == "seal":
                sess.flush_ingest()
            elif op == "compact":
                sess.ingest.compact_once()
            else:
                probe = appended[-1] if appended else _POOL[0]
                qi, qv = _probe(probe[1])
                sess.search(qi, qv)
                searches += 1
                tr = sess.last_trace
                assert tr is not None, "sample_every=1 must trace all"
                assert tr.well_formed(), \
                    f"malformed trace after ops {ops!r}"
                assert tr.root.t1 is not None      # finished at return

        # every retained trace — not just the last — is well-formed
        assert all(t.well_formed() for t in obs.tracer.recent)
        # exact accounting: one trace and one counted query per search
        reg = obs.registry
        assert reg.counter("queries_total", surface="store").value \
            == searches
        assert reg.histogram("query_ms", surface="store").count == searches
        # ingest instrumentation conserves documents: sealed + memtable
        # equals appended (counters are cumulative and single-writer)
        sealed = reg.counter("ingest_docs_sealed").value
        assert sealed + len(sess.ingest.memtable) == len(appended)
        assert reg.counter("ingest_appends").value == len(appended)
    finally:
        if sess is not None:
            sess.close()
        shutil.rmtree(tmp, ignore_errors=True)
