"""Live ingestion tier: WAL durability, memtable/seal/compaction
mechanics, snapshot consistency under concurrent writers, and the
differential contract — a live session's results are bit-identical to a
from-scratch store over the same documents (DESIGN.md §6)."""
import os
import threading

import numpy as np
import pytest

from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.ingest import IngestConfig, IngestPipeline, WAL_NAME, WriteAheadLog
from repro.storage import FlashSearchSession, FlashStore
from repro.storage.store import _corpus_docs


def _docs(n, vocab=500, seed=0, start_id=0):
    rng = np.random.default_rng(seed)
    return [(start_id + i,
             sorted((int(w), int(rng.integers(1, 20))) for w in
                    rng.choice(vocab, int(rng.integers(1, 12)),
                               replace=False)))
            for i in range(n)]


def _fresh_session(tmp, docs, cfg, per=16, name="ref"):
    store = FlashStore.create(str(tmp / name), vocab_size=cfg.vocab_size,
                              docs_per_segment=per)
    if docs:
        store.append_docs(docs)
    return FlashSearchSession(store, cfg)


def _query(cfg, pairs):
    qi = np.full((1, cfg.max_query_nnz), -1, np.int32)
    qv = np.zeros((1, cfg.max_query_nnz), np.float32)
    for j, (w, c) in enumerate(pairs[:cfg.max_query_nnz]):
        qi[0, j] = w
        qv[0, j] = c
    return qi, qv


def _assert_same(r, ref):
    np.testing.assert_array_equal(r.doc_ids, ref.doc_ids)
    np.testing.assert_array_equal(r.scores, ref.scores)


# ---------------------------------------------------------------------------
# WriteAheadLog
# ---------------------------------------------------------------------------
def test_wal_append_reopen_replays(tmp_path):
    path = str(tmp_path / "wal.log")
    docs = _docs(5)
    with WriteAheadLog(path) as wal:
        seqs = [wal.append(d) for d in docs]
    assert seqs == [1, 2, 3, 4, 5]
    with WriteAheadLog(path) as wal:
        assert wal.records() == list(zip(seqs, docs))
        assert wal.last_seq == 5
        assert wal.records(after_seq=3) == list(zip(seqs, docs))[3:]


def test_wal_truncates_torn_tail(tmp_path):
    path = str(tmp_path / "wal.log")
    docs = _docs(4)
    with WriteAheadLog(path) as wal:
        for d in docs:
            wal.append(d)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 3)                 # tear the last record
    with WriteAheadLog(path) as wal:         # repairs in place
        assert [d for _, d in wal.records()] == docs[:3]
        wal.append(docs[3])                  # and accepts new appends
    with WriteAheadLog(path) as wal:
        assert [d for _, d in wal.records()] == docs


def test_wal_rejects_corrupt_record_body(tmp_path):
    path = str(tmp_path / "wal.log")
    docs = _docs(3)
    with WriteAheadLog(path) as wal:
        for d in docs:
            wal.append(d)
        good_one = wal._f.tell()
    # flip a byte inside record 2's payload: CRC must reject it and
    # everything after it
    with open(path, "r+b") as f:
        f.seek(-5, os.SEEK_END)
        b = f.read(1)
        f.seek(-5, os.SEEK_END)
        f.write(bytes([b[0] ^ 0xFF]))
    with WriteAheadLog(path) as wal:
        assert [d for _, d in wal.records()] == docs[:2]
    assert os.path.getsize(path) < good_one


def test_wal_torn_header_rewrites_fresh(tmp_path):
    """Crash between creating wal.log and the magic reaching disk: the
    torn header is repaired like a torn tail, never a permanent error."""
    path = str(tmp_path / "wal.log")
    with open(path, "wb") as f:
        f.write(b"RSP")                      # partial magic
    with WriteAheadLog(path) as wal:
        assert wal.n_records == 0
        wal.append(_docs(1)[0])
    with WriteAheadLog(path) as wal:
        assert wal.n_records == 1


def test_wal_foreign_file_refused(tmp_path):
    """A full header that reads differently is a foreign file — refuse
    to clobber it instead of 'repairing' someone else's data."""
    path = str(tmp_path / "wal.log")
    with open(path, "wb") as f:
        f.write(b"NOTAWAL!" + b"x" * 32)
    with pytest.raises(ValueError, match="magic"):
        WriteAheadLog(path)


def test_wal_reset_discards_and_seq_survives(tmp_path):
    path = str(tmp_path / "wal.log")
    with WriteAheadLog(path) as wal:
        for d in _docs(3):
            wal.append(d)
        wal.reset()
        assert wal.n_records == 0
        assert wal.append(_docs(1, start_id=99)[0]) == 4   # seq keeps counting


# ---------------------------------------------------------------------------
# pipeline mechanics: seal, recovery windows, compaction
# ---------------------------------------------------------------------------
def test_seal_threshold_creates_delta_segments_and_resets_wal(tmp_path):
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=512,
                              docs_per_segment=32)
    pipe = IngestPipeline(store, IngestConfig(seal_docs=4,
                                              auto_compact=False))
    for d, p in _docs(10):
        pipe.append(d, p)
    assert store.n_segments == 2             # two seals of 4
    assert store.n_docs == 8
    assert len(pipe.memtable) == 2           # undurable tail
    assert pipe.wal.n_records == 2           # WAL reset at each seal
    assert store.manifest["ingest_seq"] == 8
    assert pipe.seal() == 2                  # manual flush
    assert store.n_docs == 10 and pipe.wal.n_records == 0
    pipe.close()


def test_reopen_replays_only_unsealed_records(tmp_path):
    """Crash between manifest swap and WAL reset must not duplicate:
    replay skips records at or below the manifest's ingest_seq."""
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=512,
                              docs_per_segment=32)
    docs = _docs(6)
    pipe = IngestPipeline(store, IngestConfig(seal_docs=4,
                                              auto_compact=False))
    for d, p in docs:
        pipe.append(d, p)
    # simulate the crash window: rebuild a WAL that still holds every
    # record (as if reset() never ran after the seal at seq 4)
    pipe.wal.close()
    os.unlink(os.path.join(store.root, WAL_NAME))
    with WriteAheadLog(os.path.join(store.root, WAL_NAME)) as wal:
        for d in docs:
            wal.append(d)
    store2 = FlashStore.open(store.root)
    pipe2 = IngestPipeline(store2, IngestConfig(seal_docs=100,
                                                auto_compact=False))
    assert pipe2.stats.replayed == 2         # seqs 5, 6 only
    assert pipe2.memtable.docs() == docs[4:]
    pipe2.close()


def test_reopen_after_clean_seal_starts_sequence_above_watermark(tmp_path):
    """An empty WAL plus ingest_seq=N in the manifest must hand out
    sequence numbers above N, or the next replay would skip new docs."""
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=512,
                              docs_per_segment=32)
    pipe = IngestPipeline(store, IngestConfig(seal_docs=2,
                                              auto_compact=False))
    for d, p in _docs(4):
        pipe.append(d, p)
    pipe.close()                             # WAL empty, ingest_seq == 4
    store2 = FlashStore.open(store.root)
    pipe2 = IngestPipeline(store2, IngestConfig(seal_docs=100,
                                                auto_compact=False))
    seq = pipe2.append(*_docs(1, start_id=50)[0])
    assert seq == 5
    pipe2.close()
    store3 = FlashStore.open(store.root)
    pipe3 = IngestPipeline(store3, IngestConfig(seal_docs=100,
                                                auto_compact=False))
    assert pipe3.stats.replayed == 1
    pipe3.close()


def test_crash_before_manifest_leaves_orphan_and_wal_recovers(tmp_path):
    """Seal dying after the segment write but before the manifest swap:
    the WAL still holds the docs, and compaction GCs the orphan file."""
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=512,
                              docs_per_segment=32)
    pipe = IngestPipeline(store, IngestConfig(seal_docs=100,
                                              auto_compact=False))
    docs = _docs(5)
    for d, p in docs:
        pipe.append(d, p)
    orig = store._write_manifest

    def boom(durable=False, manifest=None):
        raise OSError("simulated crash at the commit point")

    store._write_manifest = boom
    with pytest.raises(OSError):
        pipe.seal()
    store._write_manifest = orig
    pipe.wal.close()
    orphans = [f for f in os.listdir(store.root) if f.endswith(".rsps")]
    assert orphans and store.n_segments == 0   # file exists, uncommitted
    assert len(pipe.memtable) == 5             # in-memory state unrolled-back
    store2 = FlashStore.open(store.root)
    assert store2.n_segments == 0
    pipe2 = IngestPipeline(store2, IngestConfig(seal_docs=100,
                                                auto_compact=False))
    assert [d for d in pipe2.memtable.docs()] == docs   # WAL replay
    store2.compact()                          # GCs the orphan
    assert not [f for f in os.listdir(store2.root) if f.endswith(".rsps")]
    pipe2.close()


def test_append_after_close_raises(tmp_path):
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=512,
                              docs_per_segment=32)
    pipe = IngestPipeline(store, IngestConfig(auto_compact=False))
    pipe.append(*_docs(1)[0])
    pipe.close()
    with pytest.raises(RuntimeError, match="closed"):
        pipe.append(*_docs(1, start_id=9)[0])
    pipe.close()                             # idempotent


def test_capture_is_lazy_and_memtable_build_is_cached(tmp_path):
    """A capture costs no file descriptors (segments open lazily, like
    the cold read path), and an unchanged memtable's ELL build is
    reused across snapshots instead of re-encoding per query."""
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=512,
                              docs_per_segment=4)
    store.append_docs(_docs(8))
    pipe = IngestPipeline(store, IngestConfig(seal_docs=100,
                                              auto_compact=False))
    for d, p in _docs(3, start_id=50):
        pipe.append(d, p)
    snap = pipe.capture()
    assert len(snap.entries) == 2 and snap._segments == {}   # no fds yet
    c1, _ = snap.memtable_corpus(16)
    snap2 = pipe.capture()
    c2, _ = snap2.memtable_corpus(16)
    assert c2 is c1                          # cache hit: same build
    snap.close()
    snap2.close()
    pipe.append(*_docs(1, start_id=99)[0])   # mutation invalidates
    snap3 = pipe.capture()
    c3, _ = snap3.memtable_corpus(16)
    assert c3 is not c1 and c3.n_docs == 4
    snap3.close()
    pipe.close()


def test_compactor_folds_tail_run_only(tmp_path):
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=512,
                              docs_per_segment=8)
    store.append_docs(_docs(16))             # two full base segments
    base = [e.name for e in store.entries]
    pipe = IngestPipeline(store, IngestConfig(seal_docs=2,
                                              fold_min_segments=3,
                                              auto_compact=False))
    for d, p in _docs(6, start_id=100):      # three 2-doc deltas
        pipe.append(d, p)
    assert store.n_segments == 5
    assert pipe.compact_once() == 3          # folds only the delta run
    assert [e.name for e in store.entries][:2] == base   # base untouched
    assert store.n_segments == 3             # 2 base + 1 folded (6 docs)
    assert store.n_docs == 22
    assert pipe.compact_once() == 0          # idempotent: nothing to fold
    # replaced delta files are GC'd from disk
    on_disk = {f for f in os.listdir(store.root) if f.endswith(".rsps")}
    assert on_disk == {e.name for e in store.entries}
    pipe.close()


def test_snapshot_survives_compaction_gc(tmp_path):
    """A snapshot captured before a fold still scores the *old* files:
    the compactor parks replaced files in the graveyard while the
    snapshot is registered, and they are unlinked only when the last
    snapshot closes — readers are never perturbed (DESIGN.md §6.2)."""
    cfg = smoke()
    corpus = corpus_lib.synthesize(60, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=3)
    docs = _corpus_docs(corpus)
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=cfg.vocab_size,
                              docs_per_segment=16)
    sess = FlashSearchSession(store, cfg)
    pipe = sess.enable_ingest(seal_docs=8, fold_min_segments=2,
                              auto_compact=False)
    for d, p in docs:
        sess.append(d, p)
    snap = pipe.capture()
    old_names = [e.name for e in snap.entries]
    assert pipe.compact_once() > 0
    assert [e.name for e in store.entries] != old_names
    replaced = set(old_names) - {e.name for e in store.entries}
    for name in replaced:                     # deferred GC: still on disk
        assert os.path.exists(os.path.join(store.root, name))
    qi, qv = corpus_lib.make_query(corpus, 33, cfg.max_query_nnz)
    ref = _fresh_session(tmp_path, docs, cfg)
    try:
        r = sess._search_view(snap, snap, qi[None], qv[None])
        _assert_same(r, ref.search(qi[None], qv[None]))
        _assert_same(sess.search(qi[None], qv[None]),
                     ref.search(qi[None], qv[None]))
    finally:
        snap.close()
        ref.close()
    for name in replaced:                     # last close drained the GC
        assert not os.path.exists(os.path.join(store.root, name))
    sess.close()


# ---------------------------------------------------------------------------
# session surface + differential contract
# ---------------------------------------------------------------------------
def test_growing_memtable_compiles_log_many_shapes(tmp_path):
    """A memtable that outgrows the largest segment pads to doublings of
    the slab shape: interleaved append/search must trace O(log) engine
    programs, not one per append (the §7.2 bound must survive live
    writes)."""
    cfg = smoke()
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=cfg.vocab_size,
                              docs_per_segment=8)
    store.append_docs(_docs(8, vocab=cfg.vocab_size))
    with FlashSearchSession(store, cfg) as sess:
        sess.enable_ingest(seal_docs=512, auto_compact=False)
        qi = np.full((1, cfg.max_query_nnz), -1, np.int32)
        qv = np.zeros((1, cfg.max_query_nnz), np.float32)
        qi[0, 0], qv[0, 0] = 1, 1.0
        for i, (d, p) in enumerate(_docs(40, vocab=cfg.vocab_size,
                                         start_id=100)):
            sess.append(d, p)
            sess.search(qi, qv)
        # slab 8 docs -> memtable pads 8/16/32/64: <= 4 doc shapes for
        # the single L bucket (one trace each), not ~40
        assert sess.engine.compile_stats["n_traces"] <= 4


def test_append_requires_enable_ingest(tmp_path):
    cfg = smoke()
    store = FlashStore.create(str(tmp_path / "s"),
                              vocab_size=cfg.vocab_size)
    with FlashSearchSession(store, cfg) as sess:
        with pytest.raises(RuntimeError, match="enable_ingest"):
            sess.append(0, [(1, 1)])
        assert sess.flush_ingest() == 0
        pipe = sess.enable_ingest(auto_compact=False)
        assert sess.enable_ingest() is pipe     # idempotent


def test_append_validates_vocab_range(tmp_path):
    cfg = smoke()
    store = FlashStore.create(str(tmp_path / "s"),
                              vocab_size=cfg.vocab_size)
    with FlashSearchSession(store, cfg) as sess:
        sess.enable_ingest(auto_compact=False)
        with pytest.raises(ValueError, match="vocab_size"):
            sess.append(0, [(cfg.vocab_size, 1)])


def test_live_session_matches_fresh_store_every_phase(tmp_path):
    """The headline differential: after appends land in (a) memtable,
    (b) sealed deltas, (c) compacted segments, search results stay
    bit-identical to a from-scratch store over the same doc set."""
    cfg = smoke()
    corpus = corpus_lib.synthesize(90, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=4)
    docs = _corpus_docs(corpus)
    store = FlashStore.create(str(tmp_path / "live"),
                              vocab_size=cfg.vocab_size, docs_per_segment=16)
    store.append_docs(docs[:40])
    sess = FlashSearchSession(store, cfg)
    sess.enable_ingest(seal_docs=8, fold_min_segments=3, auto_compact=False)
    qi, qv = corpus_lib.make_query(corpus, 70, cfg.max_query_nnz)

    def check(n, tag):
        ref = _fresh_session(tmp_path, docs[:n], cfg, name=f"ref{n}{tag}")
        try:
            _assert_same(sess.search(qi[None], qv[None]),
                         ref.search(qi[None], qv[None]))
        finally:
            ref.close()

    for i, (d, p) in enumerate(docs[40:], start=41):
        sess.append(d, p)
        if i in (43, 56, 90):                # memtable / post-seal points
            check(i, "a")
    assert sess.last_stats.memtable_docs == len(sess.ingest.memtable.docs())
    sess.ingest.compact_once()
    check(90, "b")
    sess.close()


def test_search_under_concurrent_appends_is_prefix_consistent(tmp_path):
    """Queries racing a writer: every search sees an atomic prefix of
    the append stream (doc counts monotone, never torn mid-seal), and
    the final result is bit-identical to a fresh store."""
    cfg = smoke()
    corpus = corpus_lib.synthesize(120, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=5)
    docs = _corpus_docs(corpus)
    store = FlashStore.create(str(tmp_path / "live"),
                              vocab_size=cfg.vocab_size, docs_per_segment=16)
    sess = FlashSearchSession(store, cfg)
    sess.enable_ingest(seal_docs=8, fold_min_segments=3,
                       compact_poll_s=0.01)   # auto-compactor on
    qi, qv = corpus_lib.make_query(corpus, 60, cfg.max_query_nnz)
    sess.search(qi[None], qv[None])           # compile before the race
    stop = threading.Event()
    errs = []

    def writer():
        try:
            for d, p in docs:
                sess.append(d, p)
        except Exception as e:                # pragma: no cover
            errs.append(e)
        finally:
            stop.set()

    t = threading.Thread(target=writer)
    t.start()
    counts = []
    while not stop.is_set():
        sess.search(qi[None], qv[None])
        counts.append(sess.last_stats.docs_scored)
    t.join()
    assert not errs
    assert counts == sorted(counts)           # prefix-consistent snapshots
    ref = _fresh_session(tmp_path, docs, cfg)
    try:
        _assert_same(sess.search(qi[None], qv[None]),
                     ref.search(qi[None], qv[None]))
    finally:
        ref.close()
        sess.close()


def test_cluster_append_routes_to_owner_and_matches_union(tmp_path):
    """Cluster appends: every doc lands on its partitioner-owned shard,
    on every replica, and scatter/gather results stay bit-identical to a
    fresh union store over built + appended docs."""
    from repro.cluster import FlashClusterSession, build_sharded_store
    cfg = smoke()
    corpus = corpus_lib.synthesize(100, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=7)
    docs = _corpus_docs(corpus)
    cl = build_sharded_store(str(tmp_path / "cl"), docs[:60], n_shards=3,
                             replicas=2, policy="hash",
                             vocab_size=cfg.vocab_size, docs_per_segment=16)
    sess = FlashClusterSession(cl, cfg)
    with pytest.raises(RuntimeError, match="enable_ingest"):
        sess.append(*docs[60])
    sess.enable_ingest(seal_docs=4, fold_min_segments=3, auto_compact=False)
    part = cl.partitioner
    for d, p in docs[60:]:
        shard = sess.append(d, p)
        assert shard == int(part.shard_of(np.asarray([d], np.int64))[0])
    # replicas stay content-identical: both hold the same appended docs
    sess.flush_ingest()
    for s in range(cl.n_shards):
        d0 = sorted(cl.store(s, 0).scan_corpus(cfg.nnz_pad).doc_ids)
        d1 = sorted(cl.store(s, 1).scan_corpus(cfg.nnz_pad).doc_ids)
        assert d0 == d1
    ref = _fresh_session(tmp_path, docs, cfg)
    qi, qv = corpus_lib.make_query(corpus, 80, cfg.max_query_nnz)
    try:
        _assert_same(sess.search(qi[None], qv[None]),
                     ref.search(qi[None], qv[None]))
        assert sess.last_stats.docs_scored == len(docs)
    finally:
        ref.close()
        sess.close()


def test_cluster_append_marks_diverged_replica_down(tmp_path):
    """A replica whose append fails while a sibling's succeeded is
    content-divergent: it leaves rotation (reads and writes) and the
    error surfaces; later appends proceed on the healthy replica."""
    from repro.cluster import FlashClusterSession, build_sharded_store
    cfg = smoke()
    docs = _docs(30, vocab=cfg.vocab_size)
    cl = build_sharded_store(str(tmp_path / "cl"), docs[:20], n_shards=2,
                             replicas=2, policy="hash",
                             vocab_size=cfg.vocab_size, docs_per_segment=8)
    sess = FlashClusterSession(cl, cfg)
    sess.enable_ingest(seal_docs=4, auto_compact=False)
    d, p = docs[20]
    shard = int(cl.partitioner.shard_of(np.asarray([d], np.int64))[0])
    bad = sess.router._session(shard, 1)
    orig_append = bad.append
    bad.append = lambda *a, **k: (_ for _ in ()).throw(OSError("disk full"))
    with pytest.raises(OSError, match="disk full"):
        sess.append(d, p)
    assert sess.router.health()[shard] == [True, False]
    bad.append = orig_append
    # the doc landed on replica 0 only; later appends skip the downed
    # replica and the shard keeps accepting writes
    assert sess.append(*docs[21]) in (0, 1)
    sess.close()


def test_cluster_append_is_rebalance_aware(tmp_path):
    """After an in-process rebalance to a new shard count/policy, appends
    route by the *new* partition spec (fresh generation's owner shard)."""
    from repro.cluster import FlashClusterSession, build_sharded_store
    cfg = smoke()
    corpus = corpus_lib.synthesize(80, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=8)
    docs = _corpus_docs(corpus)
    root = str(tmp_path / "cl")
    cl = build_sharded_store(root, docs[:40], n_shards=2, policy="hash",
                             vocab_size=cfg.vocab_size, docs_per_segment=16)
    sess = FlashClusterSession(cl, cfg)
    sess.enable_ingest(seal_docs=4, auto_compact=False)
    for d, p in docs[40:60]:
        sess.append(d, p)
    # seal the live tail, then rebalance in place with the session OPEN:
    # the router notices the generation moved, closes the stale shard
    # sessions (their gen-000 directories are gone) and rebuilds against
    # the new topology — appends route by the new spec, searches serve on
    sess.flush_ingest()
    cl.rebalance(n_shards=3, policy="range")
    part = cl.partitioner
    assert part.spec()["policy"] == "range"
    for d, p in docs[60:]:
        assert sess.append(d, p) == int(
            part.shard_of(np.asarray([d], np.int64))[0])
    assert sess.router.health() == [[True]] * 3   # arrays resized to 3
    ref = _fresh_session(tmp_path, docs, cfg)
    qi, qv = corpus_lib.make_query(corpus, 70, cfg.max_query_nnz)
    try:
        _assert_same(sess.search(qi[None], qv[None]),
                     ref.search(qi[None], qv[None]))
    finally:
        ref.close()
        sess.close()


def test_submit_service_sees_appended_docs(tmp_path):
    """The coalescing serving surface composes with ingest: a submitted
    query's batch snapshot includes previously appended docs."""
    cfg = smoke()
    corpus = corpus_lib.synthesize(30, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=6)
    docs = _corpus_docs(corpus)
    store = FlashStore.create(str(tmp_path / "s"),
                              vocab_size=cfg.vocab_size, docs_per_segment=8)
    with FlashSearchSession(store, cfg) as sess:
        sess.enable_ingest(seal_docs=64, auto_compact=False)
        for d, p in docs:
            sess.append(d, p)
        qi, qv = corpus_lib.make_query(corpus, 17, cfg.max_query_nnz)
        r = sess.submit(qi, qv).result(timeout=60)
        assert int(r.doc_ids[0]) == 17        # self-search from memtable
