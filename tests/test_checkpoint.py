"""Checkpoint manager: atomicity, async, GC, QTensor round-trip, and
ELASTIC restore across different mesh shapes (subprocess device counts)."""
import json
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.train.optimizer import QTensor, quantize_block


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (16, 8), jnp.float32),
        "b": {"w": jax.random.normal(k, (4, 4), jnp.bfloat16),
              "q": quantize_block(jax.random.normal(k, (8, 128)))},
        "step": jnp.int32(7),
    }


def _assert_tree_equal(a, b):
    fa = jax.tree.leaves(a, is_leaf=lambda x: isinstance(x, QTensor))
    fb = jax.tree.leaves(b, is_leaf=lambda x: isinstance(x, QTensor))
    for x, y in zip(fa, fb):
        if isinstance(x, QTensor):
            np.testing.assert_array_equal(np.asarray(x.q), np.asarray(y.q))
            np.testing.assert_allclose(np.asarray(x.scale),
                                       np.asarray(y.scale))
        else:
            np.testing.assert_array_equal(
                np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_roundtrip_and_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in [1, 2, 3]:
        m.save(s, t, extra={"next_step": s + 1})
    assert m.all_steps() == [2, 3]          # GC keeps 2
    got, extra = m.restore(3, t)
    assert extra["next_step"] == 4
    _assert_tree_equal(t, got)


def test_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    t = _tree()
    m.save_async(5, t)
    m.wait()
    assert m.latest_step() == 5
    got, _ = m.restore(5, t)
    _assert_tree_equal(t, got)


def test_atomic_commit_no_partial(tmp_path):
    """A .tmp dir must never be visible as a checkpoint."""
    m = CheckpointManager(str(tmp_path), keep=3)
    os.makedirs(os.path.join(str(tmp_path), "step_000000009.tmp"))
    assert m.all_steps() == []
    m.save(1, _tree())
    assert m.all_steps() == [1]


ELASTIC_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager

mode = sys.argv[1]
d = sys.argv[2]
mesh = jax.make_mesh((%(ndev)d,), ("data",))
sh = NamedSharding(mesh, P("data"))
m = CheckpointManager(d, keep=3)
if mode == "save":
    x = jnp.arange(32 * 8, dtype=jnp.float32).reshape(32, 8)
    x = jax.device_put(x, sh)
    m.save(1, {"x": x}, extra={"mesh": %(ndev)d})
    print("SAVED")
else:
    like = {"x": jnp.zeros((32, 8), jnp.float32)}
    got, extra = m.restore(1, like, {"x": sh})
    assert got["x"].sharding.is_equivalent_to(sh, 2)
    np.testing.assert_array_equal(
        np.asarray(got["x"]),
        np.arange(32 * 8, dtype=np.float32).reshape(32, 8))
    print("RESTORED_FROM_MESH", extra["mesh"], "ONTO", %(ndev)d)
"""


def _run(ndev, mode, d):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT % {"ndev": ndev},
                        mode, d], env=env, capture_output=True, text=True,
                       timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


def test_elastic_reshard_across_mesh_sizes(tmp_path):
    d = str(tmp_path / "el")
    _run(4, "save", d)                       # save sharded over 4 devices
    out = _run(2, "restore", d)              # restore onto 2 devices
    assert "RESTORED_FROM_MESH 4 ONTO 2" in out
    out = _run(8, "restore", d)              # ... and onto 8
    assert "RESTORED_FROM_MESH 4 ONTO 8" in out
