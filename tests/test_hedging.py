"""Replica hedging (DESIGN.md §7.3): run_hedged mechanics, the
telemetry-seeded HedgePolicy threshold, and end-to-end cluster hedging
— a straggling replica is outrun, results stay bit-identical, and slow
is never marked down."""
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.cluster import FlashClusterSession, build_sharded_store
from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.obs import MetricsRegistry, Obs
from repro.serve import (HedgePolicy, Query, QueryOptions, SpawnExecutor,
                         run_hedged)
from repro.storage import FlashSearchSession, FlashStore
from repro.storage.store import _corpus_docs


@pytest.fixture(scope="module")
def pool():
    with ThreadPoolExecutor(max_workers=4) as ex:
        yield ex


# ---------------------------------------------------------------------------
# run_hedged mechanics
# ---------------------------------------------------------------------------
def test_hedge_fast_primary_never_fires(pool):
    out = run_hedged([lambda: "fast", lambda: "never"], pool,
                     hedge_after_s=0.5)
    assert out.result == "fast"
    assert out.winner_index == 0
    assert out.hedges_fired == 0 and not out.hedge_won


def test_hedge_fires_and_wins_on_straggler(pool):
    fired = []

    def slow():
        time.sleep(0.5)
        return "slow"

    out = run_hedged([slow, lambda: "hedge"], pool, hedge_after_s=0.02,
                     on_hedge=fired.append)
    assert out.result == "hedge"
    assert out.winner_index == 1
    assert out.hedges_fired == 1 and out.hedge_won
    assert fired == [1]


def test_hedge_fires_but_loses_to_primary(pool):
    def primary():
        time.sleep(0.08)
        return "primary"

    def laggard():
        time.sleep(1.0)
        return "laggard"

    out = run_hedged([primary, laggard], pool, hedge_after_s=0.02)
    assert out.result == "primary"
    assert out.hedges_fired == 1 and not out.hedge_won   # fired, lost


def test_hedge_error_fires_next_attempt_immediately(pool):
    def boom():
        raise OSError("replica gone")

    t0 = time.monotonic()
    out = run_hedged([boom, lambda: "backup"], pool, hedge_after_s=5.0)
    assert out.result == "backup" and out.hedge_won
    # the error fired the hedge at once, not after the 5s straggler timer
    assert time.monotonic() - t0 < 2.0
    assert isinstance(out.errors[0], OSError)


def test_hedge_all_attempts_failed_raises_first_error(pool):
    def boom_a():
        raise OSError("a")

    def boom_b():
        raise ValueError("b")

    with pytest.raises(OSError, match="a"):
        run_hedged([boom_a, boom_b], pool, hedge_after_s=0.01)


def test_hedge_single_attempt_degenerates_to_plain_call(pool):
    assert run_hedged([lambda: 7], pool, hedge_after_s=0.001).result == 7
    with pytest.raises(ValueError):
        run_hedged([], pool, hedge_after_s=0.001)


def test_hedge_attempts_never_starve_behind_abandoned_losers():
    """Regression: back-to-back hedged calls against a persistent
    straggler. Query 1's abandoned loser is still sleeping (and holding
    the per-replica serialization lock) when query 2 arrives; query 2's
    primary attempt queues on that lock, so its hedge is the only path
    to an answer — it must *start* immediately when the timer fires,
    not wait for executor capacity held by the loser. On the old
    bounded 2-worker hedge pool this took the straggler's full 0.4 s."""
    ex = SpawnExecutor()
    replica0 = threading.Lock()   # per-replica serialization, as in the router

    def slow():
        with replica0:
            time.sleep(0.4)
            return "slow"

    out1 = run_hedged([slow, lambda: "fast"], ex, hedge_after_s=0.005)
    assert out1.result == "fast" and out1.hedge_won
    t0 = time.monotonic()
    out2 = run_hedged([slow, lambda: "fast"], ex, hedge_after_s=0.005)
    wall = time.monotonic() - t0
    assert out2.result == "fast" and out2.hedge_won
    assert wall < 0.2, f"hedge starved behind the abandoned loser: {wall:.3f}s"
    # shutdown joins the stragglers so nothing outlives the test
    ex.shutdown(wait=True)


# ---------------------------------------------------------------------------
# HedgePolicy: threshold seeded from the rolling-window histogram
# ---------------------------------------------------------------------------
def test_hedge_policy_reads_windowed_percentile():
    reg = MetricsRegistry()
    h = reg.histogram("cluster_shard_ms")
    for ms in (10.0,) * 19 + (200.0,):
        h.observe(ms)
    pol = HedgePolicy(percentile=0.5, min_ms=1.0, fallback_ms=999.0)
    thr = pol.hedge_after_ms(reg)
    assert 1.0 <= thr < 200.0               # seeded from data, not fallback
    assert thr != 999.0


def test_hedge_policy_falls_back_cold_and_floors():
    reg = MetricsRegistry()                 # histogram never observed
    pol = HedgePolicy(percentile=0.95, min_ms=5.0, fallback_ms=42.0)
    assert pol.hedge_after_ms(reg) == 42.0
    assert pol.hedge_after_ms(None) == 42.0
    # the floor wins over a uniformly-fast window
    reg2 = MetricsRegistry()
    h = reg2.histogram("cluster_shard_ms")
    for _ in range(50):
        h.observe(0.01)
    assert HedgePolicy(min_ms=5.0).hedge_after_ms(reg2) == 5.0


def test_hedge_policy_validates():
    with pytest.raises(ValueError):
        HedgePolicy(percentile=1.5)
    with pytest.raises(ValueError):
        HedgePolicy(fallback_ms=0.0)


# ---------------------------------------------------------------------------
# end-to-end: a slow replica is outrun, bit-identically, with no marks
# ---------------------------------------------------------------------------
class _Slow:
    """Wraps a shard-replica session with a fixed pre-search delay
    (the chaos injection: a stuck device, a compactor stall)."""

    def __init__(self, inner, delay_s):
        self._inner = inner
        self._delay = delay_s

    def search(self, *a, **k):
        time.sleep(self._delay)
        return self._inner.search(*a, **k)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def _cluster(tmp_path, cfg, n_shards=2, replicas=2, **kw):
    corpus = corpus_lib.synthesize(120, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=11)
    docs = _corpus_docs(corpus)
    cl = build_sharded_store(str(tmp_path / "c"), docs, n_shards=n_shards,
                             replicas=replicas, policy="hash",
                             vocab_size=cfg.vocab_size, docs_per_segment=16)
    union = FlashStore.create(str(tmp_path / "u"),
                              vocab_size=cfg.vocab_size, docs_per_segment=64)
    union.append_docs(docs)
    sess = FlashClusterSession(cl, cfg, **kw)
    return corpus, sess, FlashSearchSession(union, cfg)


def test_hedge_outruns_slow_replica_bit_identically(tmp_path):
    cfg = smoke()
    corpus, sess, union = _cluster(
        tmp_path, cfg,
        hedge_policy=HedgePolicy(percentile=0.5, min_ms=1.0, fallback_ms=20.0))
    try:
        qi, qv = corpus_lib.make_query(corpus, 7, cfg.max_query_nnz)
        q = Query(qi[None], qv[None])
        ref = union.search_typed(Query(qi[None], qv[None]))
        sess.search_typed(q)                # open every primary replica
        # make shard 0's primary a straggler, far past the 20ms threshold
        sess.router._sessions[0][0] = _Slow(sess.router._sessions[0][0], 0.6)
        t0 = time.monotonic()
        res = sess.search_typed(q)
        wall = time.monotonic() - t0
        np.testing.assert_array_equal(res.doc_ids, ref.doc_ids)
        np.testing.assert_array_equal(res.scores, ref.scores)
        st = sess.last_stats
        assert st.hedges >= 1 and st.hedge_wins >= 1
        assert not st.partial and st.shards_missing == ()
        # slow is not failed: the straggler stays in rotation
        assert not sess.router._down[0][0]
        assert wall < 0.55, f"hedge did not outrun the 0.6s straggler " \
                            f"({wall*1e3:.0f}ms)"
    finally:
        sess.close()
        union.close()


def test_hedge_per_query_opt_out_pins_it_off(tmp_path):
    cfg = smoke()
    corpus, sess, union = _cluster(
        tmp_path, cfg,
        hedge_policy=HedgePolicy(percentile=0.5, min_ms=1.0, fallback_ms=5.0))
    try:
        qi, qv = corpus_lib.make_query(corpus, 3, cfg.max_query_nnz)
        q = Query(qi[None], qv[None])
        sess.search_typed(q)
        sess.router._sessions[0][0] = _Slow(sess.router._sessions[0][0], 0.15)
        res = sess.search_typed(q, options=QueryOptions(hedging=False))
        assert sess.last_stats.hedges == 0  # opt-out beat the router default
        ref = union.search_typed(Query(qi[None], qv[None]))
        np.testing.assert_array_equal(res.doc_ids, ref.doc_ids)
    finally:
        sess.close()
        union.close()


def test_hedge_per_query_opt_in_without_router_policy(tmp_path):
    """hedging=True arms the default policy even when the router was
    built without one; counters land in the shared registry."""
    cfg = smoke()
    obs = Obs(registry=MetricsRegistry())
    corpus, sess, union = _cluster(tmp_path, cfg, obs=obs)
    try:
        assert sess.router.hedge_policy is None
        qi, qv = corpus_lib.make_query(corpus, 5, cfg.max_query_nnz)
        q = Query(qi[None], qv[None])
        sess.search_typed(q)
        sess.router._sessions[1][0] = _Slow(sess.router._sessions[1][0], 0.5)
        # default fallback is 50ms; the 0.5s straggler trips it
        res = sess.search_typed(q, options=QueryOptions(hedging=True))
        st = sess.last_stats
        assert st.hedges >= 1 and st.hedge_wins >= 1
        ref = union.search_typed(Query(qi[None], qv[None]))
        np.testing.assert_array_equal(res.doc_ids, ref.doc_ids)
        reg = obs.registry
        assert reg.counter("cluster_hedges_total").value >= 1
        assert reg.counter("cluster_hedge_wins_total").value >= 1
    finally:
        sess.close()
        union.close()
