"""Tiling strategies for the fused kernel (DESIGN.md §12.3): shape
selection, the per-bucket memoization that preserves the §7
compile-cache bound, and bit-identity of an AutoTiling engine with the
jnp reference."""
import math

import numpy as np
import pytest

from repro.configs.paper_search import SearchConfig
from repro.core.corpus import from_stream
from repro.core.engine import PatternSearchEngine
from repro.core.stream_format import encode
from repro.distributed.meshctx import single_device_ctx
from repro.kernels.tiling import (AutoTiling, DEFAULT_VMEM_BUDGET,
                                  FixedTiling, TileShape)


def test_fixed_tiling_returns_config_shapes():
    t = FixedTiling(64, 256)
    assert t.doc_tile(nnz_pad=4, n_docs=10) == 64
    assert t.doc_tile(nnz_pad=4096, n_docs=10**9) == 64
    assert t.query_tile(1) == 256 and t.query_tile(1024) == 256
    with pytest.raises(ValueError):
        FixedTiling(0, 256)


def test_query_tile_memoized_per_bucket():
    t = AutoTiling(64, 256)
    buckets = [1, 2, 4, 8, 8, 4, 2, 1, 16]
    shapes = [t.query_tile(b) for b in buckets]
    # revisiting a bucket returns the memoized choice — identical value,
    # no new entry — so the program count is bounded by distinct buckets
    assert shapes[0] == shapes[7] and shapes[2] == shapes[5]
    assert set(t.bucket_shapes) == {1, 2, 4, 8, 16}
    assert len(t.bucket_shapes) == 5


def test_auto_tiling_doc_side_shrinks_with_density():
    t = AutoTiling(1024, 256, vmem_budget=64 * 1024)
    wide = t.doc_tile(nnz_pad=4, n_docs=10**6)
    narrow = t.doc_tile(nnz_pad=512, n_docs=10**6)
    assert narrow < wide <= 1024
    assert wide & (wide - 1) == 0 and narrow & (narrow - 1) == 0
    assert narrow >= 8
    # never exceeds the config's static upper bound
    assert AutoTiling(16, 256).doc_tile(nnz_pad=1, n_docs=10**6) == 16


def test_auto_tiling_query_side_divides_block_query():
    bq = 384                       # non-power-of-two static shape
    t = AutoTiling(64, bq, vmem_budget=16 * 1024)
    for Lp in (1, 2, 4, 8, 64, 512):
        tq = t.query_tile(Lp)
        assert bq % tq == 0        # merged capacity (k * bq) stays divisible
        assert tq >= 8
    # wider buckets never get wider tiles
    picks = [t.query_tile(Lp) for Lp in (1, 4, 16, 64, 256)]
    assert picks == sorted(picks, reverse=True)
    assert picks[-1] < picks[0]    # the budget actually binds
    # a generous budget keeps the config shape
    assert AutoTiling(64, bq, vmem_budget=DEFAULT_VMEM_BUDGET).query_tile(1) \
        == bq


def test_tile_shape_is_frozen_value_type():
    s = TileShape(64, 256)
    assert (s.block_docs, s.block_query) == (64, 256)
    with pytest.raises(Exception):
        s.block_docs = 8


def test_engine_with_auto_tiling_matches_jnp_and_keeps_compile_bound():
    rng = np.random.default_rng(31)
    cfg = SearchConfig(name="tiling-test", vocab_size=128,
                       avg_nnz_per_doc=6, nnz_pad=8, top_k=4,
                       block_docs=32, block_query=64)
    docs = [(d, [(int(w), int(rng.integers(1, 9)))
                 for w in sorted(rng.choice(128, 5, replace=False))])
            for d in range(50)]
    corpus = from_stream(encode(docs), cfg.nnz_pad)
    ctx = single_device_ctx()
    ref = PatternSearchEngine(corpus, cfg, ctx, backend="jnp")
    tiling = AutoTiling(cfg.block_docs, cfg.block_query,
                        vmem_budget=32 * 1024)
    got = PatternSearchEngine(corpus, cfg, ctx, backend="pallas_fused",
                              tiling=tiling)
    max_batch = 8
    for L in range(1, max_batch + 1):
        qi = np.full((L, 4), -1, np.int32)
        qv = np.zeros((L, 4), np.float32)
        for l in range(L):
            w, _ = docs[(L * 7 + l) % 50][1][0]
            qi[l, 0], qv[l, 0] = w, 2.0
        r = ref.search(qi, qv)
        g = got.search(qi, qv)
        np.testing.assert_array_equal(r.doc_ids, g.doc_ids, err_msg=f"L={L}")
        np.testing.assert_array_equal(r.scores, g.scores, err_msg=f"L={L}")
    # the autotuner added no program shapes beyond the L buckets
    assert got.compile_stats["n_traces"] <= math.log2(max_batch) + 1
    assert len(tiling.bucket_shapes) <= math.log2(max_batch) + 1
