"""Telemetry HTTP server (DESIGN.md §8.5): endpoint well-formedness
over live sessions, the /healthz flip when a cluster replica is killed,
the telemetry-on differential (scraped mid-query vs Obs.disabled()),
atomic exporters, and the summary/timeline rendering edge cases."""
import json
import os
import shutil
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.cluster import FlashClusterSession
from repro.cluster.store import build_sharded_store
from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.obs import Obs, QueryTrace
from repro.obs.export import (render_summary, render_trace, write_metrics,
                              write_traces)
from repro.obs.server import TelemetryServer, aggregate_health
from repro.obs.slo import SLOMonitor, default_slos
from repro.storage import FlashSearchSession, FlashStore
from repro.storage.store import _corpus_docs

CFG = smoke()


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    corpus = corpus_lib.synthesize(400, CFG.vocab_size, CFG.avg_nnz_per_doc,
                                   CFG.nnz_pad, seed=11)
    root = str(tmp_path_factory.mktemp("srv") / "store")
    store = FlashStore.create(root, vocab_size=CFG.vocab_size,
                              docs_per_segment=100)
    store.append_corpus(corpus)
    return corpus, root


def _query(corpus, idx=7):
    qi, qv = corpus_lib.make_query(corpus, idx, CFG.max_query_nnz)
    return qi[None], qv[None]


def _get(url):
    """(status, body) — urllib raises on 4xx/5xx but the HTTPError *is*
    the response."""
    try:
        with urllib.request.urlopen(url, timeout=10) as resp:
            return resp.status, resp.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


# -- health aggregation ------------------------------------------------

def test_aggregate_health_worst_of():
    assert aggregate_health({}) == "ok"
    assert aggregate_health({"a": {"status": "ok"}}) == "ok"
    assert aggregate_health({"a": {"status": "ok"},
                             "b": {"status": "degraded"}}) == "degraded"
    assert aggregate_health({"a": {"status": "degraded"},
                             "b": {"status": "down"}}) == "down"
    assert aggregate_health({"a": {}}) == "down"          # missing status
    assert aggregate_health({"a": {"status": "garbage"}}) == "down"


# -- store session endpoints -------------------------------------------

def test_store_endpoints_well_formed(setup):
    corpus, root = setup
    obs = Obs(trace_sample=1)
    # threshold far above a cold first query (compile included), so the
    # endpoint assertions are about plumbing, not machine speed
    mon = SLOMonitor(obs, default_slos("store", latency_ms=60_000.0))
    sess = FlashSearchSession(FlashStore.open(root), CFG, obs=obs)
    srv = sess.start_telemetry(slo_monitor=mon)
    assert sess.start_telemetry() is srv       # idempotent
    assert sess.telemetry is srv
    qi, qv = _query(corpus)
    sess.search(qi, qv)

    code, body = _get(srv.url("/metrics"))
    assert code == 200
    assert "# TYPE repro_query_ms histogram" in body
    assert 'repro_queries_total{surface="store"} 1' in body
    assert 'stat="p99"' in body                # window gauges included

    code, body = _get(srv.url("/healthz"))
    health = json.loads(body)
    assert code == 200 and health["status"] == "ok"
    assert "ingest" in health["components"]    # store surface: WAL probe

    code, body = _get(srv.url("/slo"))
    slos = json.loads(body)["slos"]
    assert code == 200 and len(slos) == 2
    assert {s["kind"] for s in slos} == {"latency", "availability"}
    assert all(s["state"] == "ok" for s in slos)

    code, body = _get(srv.url("/debug/traces"))
    dump = json.loads(body)
    assert code == 200 and dump["schema"] == "repro-traces-v1"
    assert dump["traces"][0]["root"]["name"] == "query"

    code, body = _get(srv.url("/debug/profile"))
    assert code == 409                         # no profile_dir configured
    assert "profiling disabled" in json.loads(body)["error"]

    code, body = _get(srv.url("/nope"))
    assert code == 404
    assert "/metrics" in json.loads(body)["routes"]

    port = srv.port
    sess.close()                               # closes the server too
    assert sess.telemetry is None
    with pytest.raises(OSError):
        urllib.request.urlopen(f"http://127.0.0.1:{port}/metrics",
                               timeout=2)
    with pytest.raises(RuntimeError):
        sess.start_telemetry()                 # closed session refuses


# -- the killed-replica /healthz flip ----------------------------------

def test_cluster_healthz_flips_on_killed_replica(setup, tmp_path):
    corpus, _ = setup
    cl = build_sharded_store(str(tmp_path / "c"), _corpus_docs(corpus),
                             n_shards=2, replicas=2,
                             vocab_size=CFG.vocab_size, docs_per_segment=100)
    obs = Obs()
    sess = FlashClusterSession(cl, CFG, obs=obs)
    srv = sess.start_telemetry()
    qi, qv = _query(corpus)
    baseline = sess.search(qi, qv)

    code, body = _get(srv.url("/healthz"))
    health = json.loads(body)
    assert code == 200 and health["status"] == "ok"
    router = health["components"]["router"]
    assert router["shards"] == 2 and router["replicas_down"] == 0

    # kill shard 0 replica 0 on disk; the next query fails over to the
    # sibling, health-marks the dead replica, and /healthz degrades —
    # while results stay bit-identical (replicas are byte-wise copies)
    shutil.rmtree(cl.shard_path(0, 0))
    # drop the cached handles so the replica re-opens (and fails) — an
    # already-mmapped store would keep serving the unlinked bytes
    cl._open_stores.pop((0, 0), None)
    with sess.router._lock:
        stale, sess.router._sessions[0][0] = sess.router._sessions[0][0], \
            None
    if stale is not None:
        stale.close()
    r = sess.search(qi, qv)
    np.testing.assert_array_equal(r.doc_ids, baseline.doc_ids)
    np.testing.assert_array_equal(r.scores, baseline.scores)

    code, body = _get(srv.url("/healthz"))
    health = json.loads(body)
    assert code == 200                         # degraded still serves
    assert health["status"] == "degraded"
    router = health["components"]["router"]
    assert router["replicas_down"] == 1 and router["dead_shards"] == []
    assert router["failovers"] >= 1
    assert router["rotation"][0] == [False, True]

    # every replica of a shard out of rotation: down, and the HTTP code
    # flips to 503 so a load balancer can eject the node
    sess.router.mark_down(0, 1)
    code, body = _get(srv.url("/healthz"))
    health = json.loads(body)
    assert code == 503 and health["status"] == "down"
    assert health["components"]["router"]["dead_shards"] == [0]

    # /metrics and /slo stay well-formed while degraded
    code, body = _get(srv.url("/metrics"))
    assert code == 200 and "repro_cluster_shard_ms" in body
    code, body = _get(srv.url("/slo"))
    assert code == 200 and json.loads(body)["slos"] == []
    sess.close()


# -- the live-scrape differential --------------------------------------

def test_results_bit_identical_while_scraped(setup):
    # the §8 acceptance differential extended to the live plane: a
    # server being scraped concurrently with queries must not change
    # results vs Obs.disabled() with no server at all
    corpus, root = setup
    off = FlashSearchSession(FlashStore.open(root), CFG, obs=Obs.disabled())
    on = FlashSearchSession(FlashStore.open(root), CFG,
                            obs=Obs(trace_sample=1))
    srv = on.start_telemetry()

    stop = threading.Event()
    scrapes = [0]

    def scraper():
        while not stop.is_set():
            code, body = _get(srv.url("/metrics"))
            assert code == 200 and body.endswith("\n")
            scrapes[0] += 1
            _get(srv.url("/healthz"))
            stop.wait(0.005)

    t = threading.Thread(target=scraper, daemon=True)
    t.start()
    try:
        for idx in (0, 57, 123, 399):
            qi, qv = _query(corpus, idx)
            a, b = on.search(qi, qv), off.search(qi, qv)
            np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
            np.testing.assert_array_equal(a.scores, b.scores)
    finally:
        stop.set()
        t.join(timeout=10)
    assert scrapes[0] > 0
    on.close()
    off.close()


# -- atomic exporters --------------------------------------------------

def test_exporters_are_atomic_no_tmp_residue(setup, tmp_path):
    corpus, root = setup
    obs = Obs(trace_sample=1)
    sess = FlashSearchSession(FlashStore.open(root), CFG, obs=obs)
    qi, qv = _query(corpus)
    sess.search(qi, qv)
    mpath = str(tmp_path / "metrics.prom")
    tpath = str(tmp_path / "traces.json")
    for _ in range(3):                         # overwrite path too
        write_metrics(obs, mpath)
        assert write_traces(obs, tpath) >= 1
    assert not os.path.exists(mpath + ".tmp")
    assert not os.path.exists(tpath + ".tmp")
    assert "repro_query_ms" in open(mpath).read()
    assert json.load(open(tpath))["schema"] == "repro-traces-v1"
    sess.close()


# -- rendering edge cases ----------------------------------------------

def test_render_summary_zero_queries_is_complete():
    class Bare:
        pass
    out = render_summary(Bare(), Obs())
    assert "== observability summary ==" in out
    assert "no queries served" in out          # not a bare header


def test_render_summary_includes_window_and_slo_lines(setup):
    corpus, root = setup
    obs = Obs()
    mon = SLOMonitor(obs, default_slos("store", latency_ms=60_000.0))
    sess = FlashSearchSession(FlashStore.open(root), CFG, obs=obs)
    qi, qv = _query(corpus)
    sess.search(qi, qv)
    out = render_summary(sess, obs, slo_monitor=mon)
    assert "last 60s: n=1" in out              # the rolling-window line
    assert "slo store-latency: ok" in out
    assert "slo store-availability: ok" in out
    sess.close()


def test_render_trace_sub_100us_spans_in_microseconds():
    tr = QueryTrace("query", surface="test")
    with tr.root.child("merge") as m:
        m.set(docs=0)
    tr.finish()
    d = tr.to_dict()["root"]
    d["children"][0]["dur_ms"] = 0.0123        # a 12.3 µs no-op merge
    d["dur_ms"] = 1.5

    class Fake:
        def to_dict(self):
            return {"root": d}

    out = render_trace(Fake())
    assert "12.3µs" in out                     # not 0.000ms
    assert "1.500ms" in out
