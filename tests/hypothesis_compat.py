"""Property-test shim: real hypothesis when installed, else a minimal
random-sampling fallback implementing the subset this suite uses
(``given``/``settings`` decorators; ``integers``/``tuples``/``lists``
strategies). The container image does not ship hypothesis, and the
repo must not install new packages."""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

except ModuleNotFoundError:
    import functools
    import inspect
    import zlib

    import numpy as np

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module surface
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(len(options)))])

        @staticmethod
        def tuples(*elems):
            return _Strategy(
                lambda rng: tuple(e.example(rng) for e in elems))

        @staticmethod
        def lists(elem, min_size=0, max_size=10, unique_by=None):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                out, seen, tries = [], set(), 0
                while len(out) < n and tries < 20 * (n + 1):
                    tries += 1
                    x = elem.example(rng)
                    if unique_by is not None:
                        key = unique_by(x)
                        if key in seen:
                            continue
                        seen.add(key)
                    out.append(x)
                return out
            return _Strategy(sample)

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = getattr(run, "_max_examples", 20)
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)
            run._max_examples = 20
            # hide the drawn params from pytest's fixture resolution
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strats]
            del run.__wrapped__
            run.__signature__ = sig.replace(parameters=params)
            return run
        return deco

    def settings(max_examples=20, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
