"""Property test (ISSUE 5 acceptance): with the device slab cache
enabled — and sized small enough to evict constantly — ANY interleaving
of append / seal / compact / search / crash must stay bit-identical to
a from-scratch store over the same documents (DESIGN.md §4.2). A stale
or cross-generation slab served from the cache would show up here as a
score diff; eviction-under-churn and crash-reopen (new store instance,
new cache token) are exercised on the same shared cache object.

Runs under real hypothesis when installed (CI) and under the
``tests/hypothesis_compat`` random-sampling fallback otherwise. No
pytest fixtures inside the ``@given`` test (hypothesis's
function-scoped-fixture health check); temp dirs are managed inline.
"""
import shutil
import tempfile

import numpy as np

from hypothesis_compat import given, settings, strategies as st

from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.storage import FlashSearchSession, FlashStore, SlabCache
from repro.storage.store import _corpus_docs

CFG = smoke()
_CORPUS = corpus_lib.synthesize(120, CFG.vocab_size, CFG.avg_nnz_per_doc,
                                CFG.nnz_pad, seed=43)
_POOL = _corpus_docs(_CORPUS)

# "append" dominates so sequences actually grow state; "search" runs
# twice back-to-back (cold-ish then warm) so the second pass scores
# cached slabs; "crash" reopens through WAL replay with a NEW store
# instance sharing the OLD cache object — the token discipline under test
_OP = st.sampled_from(["append", "append", "append", "append", "append",
                       "append", "seal", "compact", "search", "crash"])
_MAX_CHECKS = 3          # fresh reference stores are the expensive part


def _live_session(root, created, cache):
    store = FlashStore.create(root, vocab_size=CFG.vocab_size,
                              docs_per_segment=8) if not created \
        else FlashStore.open(root)
    sess = FlashSearchSession(store, CFG, slab_cache=cache)
    sess.enable_ingest(seal_docs=6, fold_min_segments=2, auto_compact=False)
    return sess


def _reference_result(tmp, docs, qi, qv, tag):
    store = FlashStore.create(f"{tmp}/ref-{tag}", vocab_size=CFG.vocab_size,
                              docs_per_segment=8)
    if docs:
        store.append_docs(docs)
    with FlashSearchSession(store, CFG, cache_bytes=0) as ref:
        return ref.search(qi, qv)


@settings(max_examples=8, deadline=None)
@given(ops=st.lists(_OP, min_size=4, max_size=28))
def test_any_interleaving_matches_fresh_store_with_cache(ops):
    tmp = tempfile.mkdtemp(prefix="cache-prop-")
    # ~3 slabs of this shape: constant eviction churn under the ops
    cache = SlabCache(max_bytes=3 * 8 * (CFG.nnz_pad * 8 + 8) + 256)
    sess = None
    try:
        root = f"{tmp}/live"
        sess = _live_session(root, created=False, cache=cache)
        appended = []
        checks = 0
        nxt = iter(_POOL)
        for op in ops + ["search"]:          # always verify the end state
            if op == "append":
                d, p = next(nxt)
                sess.append(d, p)
                appended.append((d, p))
            elif op == "seal":
                sess.flush_ingest()
            elif op == "compact":
                sess.ingest.compact_once()
            elif op == "crash":
                sess.ingest.close(seal=False)
                sess.store.close()
                sess = _live_session(root, created=True, cache=cache)
            elif op == "search" and checks < _MAX_CHECKS:
                checks += 1
                probe = appended[-1] if appended else _POOL[0]
                qi = np.full((1, CFG.max_query_nnz), -1, np.int32)
                qv = np.zeros((1, CFG.max_query_nnz), np.float32)
                for j, (w, c) in enumerate(probe[1][:CFG.max_query_nnz]):
                    qi[0, j] = w
                    qv[0, j] = c
                want = _reference_result(tmp, appended, qi, qv, checks)
                got_cold = sess.search(qi, qv)
                got_warm = sess.search(qi, qv)   # scores cached slabs
                for got in (got_cold, got_warm):
                    np.testing.assert_array_equal(got.doc_ids, want.doc_ids)
                    np.testing.assert_array_equal(got.scores, want.scores)
        assert cache.nbytes <= cache.max_bytes
    finally:
        if sess is not None:
            sess.close()
        shutil.rmtree(tmp, ignore_errors=True)
