"""Validate the HLO static analyzer against programs with known costs."""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.hlo_analysis import HloCostModel, analyze, shape_bytes


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    text = _hlo(lambda a, b: a @ b, a, b)
    got = analyze(text)["flops"]
    want = 2 * 128 * 256 * 64
    assert got == want, (got, want)


def test_while_loop_multiplies():
    w = jnp.zeros((64, 64), jnp.float32)

    def fn(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    text = _hlo(fn, jnp.zeros((32, 64), jnp.float32))
    got = analyze(text)["flops"]
    want = 7 * 2 * 32 * 64 * 64
    assert got == want, (got, want)


def test_nested_scan_multiplies():
    w = jnp.zeros((16, 16), jnp.float32)

    def fn(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    text = _hlo(fn, jnp.zeros((8, 16), jnp.float32))
    got = analyze(text)["flops"]
    want = 15 * 2 * 8 * 16 * 16
    assert got == want, (got, want)


def test_shape_bytes():
    assert shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert shape_bytes("bf16[2,3,4]") == 24 * 2
    assert shape_bytes("(f32[8], s8[16])") == 32 + 16
    assert shape_bytes("pred[]") == 1
