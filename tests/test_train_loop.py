"""Training substrate: loss goes down, checkpoint restart is bit-identical,
int8 optimizer states track fp32, preemption recovery works."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig, TrainConfig
from repro.configs.registry import get_smoke_config
from repro.distributed.meshctx import single_device_ctx
from repro.train.loop import Trainer
from repro.train import optimizer as opt_lib


def _tc(tmp, arch="qwen2-0.5b", **opt_kw):
    cfg = get_smoke_config(arch)
    return TrainConfig(
        model=cfg, opt=OptimizerConfig(lr=1e-3, warmup_steps=5,
                                       total_steps=100, **opt_kw),
        seq_len=32, global_batch=4, checkpoint_every=5,
        checkpoint_dir=str(tmp), keep_checkpoints=2, seed=0)


def test_loss_decreases(tmp_path):
    t = Trainer(_tc(tmp_path / "a"), single_device_ctx(), log_fn=lambda s: None)
    first = None
    m = t.run(30)
    # measure loss at start vs end via fresh runs of the metric
    t2 = Trainer(_tc(tmp_path / "b"), single_device_ctx(),
                 log_fn=lambda s: None)
    m0 = t2.run(1)
    assert m["loss"] < m0["loss"], (m["loss"], m0["loss"])


def test_checkpoint_restart_bit_identical(tmp_path):
    d = tmp_path / "ck"
    # run 10 steps straight
    t1 = Trainer(_tc(d / "x"), single_device_ctx(), log_fn=lambda s: None)
    m1 = t1.run(10)
    # run 5, "die", restart (auto-restores), run 5 more
    t2 = Trainer(_tc(d / "y"), single_device_ctx(), log_fn=lambda s: None)
    t2.run(5)  # checkpoint_every=5 -> checkpoint at step 4 (+1 = 5)
    t2.ckpt.wait()
    del t2
    t3 = Trainer(_tc(d / "y"), single_device_ctx(), log_fn=lambda s: None)
    assert t3.start_step == 5, t3.start_step
    m3 = t3.run(5)
    np.testing.assert_allclose(m1["loss"], m3["loss"], rtol=1e-6,
                               err_msg="restart not deterministic")


def test_int8_optimizer_tracks_fp32():
    """Blockwise-int8 Adam tracks fp32 in the mean; per-coordinate error is
    bounded by the quantum floor (coords tiny relative to their 128-block
    absmax update less — the standard 8-bit-Adam tradeoff)."""
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (64, 256)) * 0.1}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 256)) * 0.01}
    cfg32 = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=10)
    cfg8 = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                           int8_states=True)
    s32 = opt_lib.init_state(cfg32, params)
    s8 = opt_lib.init_state(cfg8, params)
    p32, p8 = params, params
    for _ in range(5):
        p32, s32, _ = opt_lib.apply_updates(cfg32, p32, grads, s32)
        p8, s8, _ = opt_lib.apply_updates(cfg8, p8, grads, s8)
    diff = np.abs(np.asarray(p32["w"]) - np.asarray(p8["w"]))
    upd = np.abs(np.asarray(p32["w"]) - np.asarray(params["w"]))
    assert diff.mean() < 0.10 * upd.max(), (diff.mean(), upd.max())
    assert diff.max() < 1.0 * upd.max()
    # directional agreement: int8 must never move a coord the wrong way
    d32 = np.asarray(p32["w"]) - np.asarray(params["w"])
    d8 = np.asarray(p8["w"]) - np.asarray(params["w"])
    agree = np.sign(d32) == np.sign(d8)
    assert agree.mean() > 0.99


def test_int8_training_converges(tmp_path):
    tc = _tc(tmp_path / "i8", int8_states=True)
    t = Trainer(tc, single_device_ctx(), log_fn=lambda s: None)
    m_end = t.run(30)
    t0 = Trainer(_tc(tmp_path / "i8b", int8_states=True),
                 single_device_ctx(), log_fn=lambda s: None)
    m_start = t0.run(1)
    assert m_end["loss"] < m_start["loss"]


def test_quantize_roundtrip_property():
    from hypothesis_compat import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**20),
           shape=st.sampled_from([(128,), (3, 128), (5, 7), (2, 3, 256)]))
    def inner(seed, shape):
        x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 3.0
        q = opt_lib.quantize_block(x)
        back = opt_lib.dequantize_block(q)
        absmax = float(jnp.abs(x).max())
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   atol=absmax / 127 + 1e-6)
    inner()


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(opt_lib.lr_schedule(cfg, jnp.int32(s)))
           for s in [0, 5, 10, 55, 100, 200]]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 <= lrs[3] <= 1.0 and abs(lrs[4] - 0.1) < 1e-6
    assert abs(lrs[5] - 0.1) < 1e-6


def test_grad_clip_applied():
    params = {"w": jnp.ones((8, 8))}
    grads = {"w": jnp.full((8, 8), 100.0)}
    cfg = OptimizerConfig(lr=1.0, warmup_steps=0, total_steps=10,
                          grad_clip=1.0, weight_decay=0.0)
    s = opt_lib.init_state(cfg, params)
    _, _, m = opt_lib.apply_updates(cfg, params, grads, s)
    assert float(m["grad_norm"]) == pytest.approx(800.0)
