"""Flash (custom-vjp blockwise) attention vs naive softmax: values + grads."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import blockwise_attention, decode_attention


def naive_attention(q, k, v, causal=True, window=0, softcap=0.0):
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qh = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    kh = k.astype(jnp.float32)
    vh = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qh, kh) / np.sqrt(hd)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vh)
    return out.reshape(B, Sq, H, hd)


CASES = [
    dict(B=2, Sq=32, Sk=32, H=4, KV=2, hd=16, causal=True, window=0, cap=0.0),
    dict(B=1, Sq=64, Sk=64, H=4, KV=4, hd=8, causal=True, window=16, cap=0.0),
    dict(B=2, Sq=16, Sk=48, H=6, KV=2, hd=8, causal=False, window=0, cap=0.0),
    dict(B=1, Sq=32, Sk=32, H=2, KV=1, hd=16, causal=True, window=0, cap=30.0),
]


@pytest.mark.parametrize("c", CASES)
def test_flash_matches_naive(c):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (c["B"], c["Sq"], c["H"], c["hd"]), jnp.float32)
    k = jax.random.normal(kk, (c["B"], c["Sk"], c["KV"], c["hd"]), jnp.float32)
    v = jax.random.normal(kv, (c["B"], c["Sk"], c["KV"], c["hd"]), jnp.float32)
    got = blockwise_attention(q, k, v, causal=c["causal"], window=c["window"],
                              softcap=c["cap"], block_q=16, block_kv=16)
    want = naive_attention(q, k, v, c["causal"], c["window"], c["cap"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("c", CASES)
def test_flash_grads_match_naive(c):
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (c["B"], c["Sq"], c["H"], c["hd"]), jnp.float32)
    k = jax.random.normal(kk, (c["B"], c["Sk"], c["KV"], c["hd"]), jnp.float32)
    v = jax.random.normal(kv, (c["B"], c["Sk"], c["KV"], c["hd"]), jnp.float32)

    def loss_flash(q, k, v):
        o = blockwise_attention(q, k, v, causal=c["causal"],
                                window=c["window"], softcap=c["cap"],
                                block_q=16, block_kv=16)
        return jnp.sum(jnp.sin(o))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(naive_attention(q, k, v, c["causal"],
                                               c["window"], c["cap"])))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_decode_matches_prefill_last_token():
    key = jax.random.PRNGKey(2)
    B, S, H, KV, hd = 2, 24, 4, 2, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, KV, hd), jnp.float32)
    full = blockwise_attention(q, k, v, causal=True, block_q=8, block_kv=8)
    dec = decode_attention(q[:, -1:], k, v, jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-5, atol=2e-5)


def test_decode_window():
    key = jax.random.PRNGKey(3)
    B, S, H, KV, hd, w = 1, 32, 2, 2, 8, 8
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, KV, hd), jnp.float32)
    got = decode_attention(q, k, v, jnp.int32(S - 1), window=w)
    # zero out everything outside the window: result must be unchanged
    mask = (jnp.arange(S) >= S - w)[None, :, None, None]
    got2 = decode_attention(q, k * mask, v * mask, jnp.int32(S - 1), window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got2), rtol=1e-5,
                               atol=1e-5)


def test_banded_matches_windowed_flash():
    from repro.models.layers import banded_attention
    key = jax.random.PRNGKey(5)
    B, S, H, KV, hd, w = 2, 128, 4, 2, 16, 32
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, KV, hd), jnp.float32)
    want = blockwise_attention(q, k, v, causal=True, window=w,
                               block_q=16, block_kv=16)
    got = banded_attention(q, k, v, window=w, block=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_banded_window_not_multiple_of_block():
    from repro.models.layers import banded_attention
    key = jax.random.PRNGKey(6)
    B, S, H, KV, hd, w = 1, 96, 2, 2, 8, 24
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, KV, hd), jnp.float32)
    want = blockwise_attention(q, k, v, causal=True, window=w,
                               block_q=16, block_kv=16)
    got = banded_attention(q, k, v, window=w, block=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
