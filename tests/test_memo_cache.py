"""Recurrent-query memo cache (DESIGN.md §15.3): fingerprint
normalization (order/pad-insensitive, collision-free in practice), LRU
bounds, and — the acceptance bar — the generation-bump differential: a
memoized result must never be served once the store's view changes."""
import dataclasses

import numpy as np
import pytest

from hypothesis_compat import given, settings, strategies as st

from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.serve.api import Query, QueryOptions
from repro.storage import (FlashSearchSession, FlashStore, MemoCache,
                           query_fingerprint)
from repro.storage.memo import memo_key
from repro.storage.store import _corpus_docs

CFG = smoke()


def _build(root, docs, docs_per_segment=64):
    store = FlashStore.create(str(root), vocab_size=CFG.vocab_size,
                              docs_per_segment=docs_per_segment)
    store.append_docs(docs)
    return store


def _query(corpus, idx):
    qi, qv = corpus_lib.make_query(corpus, idx, CFG.max_query_nnz)
    return qi[None], qv[None]


@pytest.fixture(scope="module")
def corpus():
    return corpus_lib.synthesize(300, CFG.vocab_size, CFG.avg_nnz_per_doc,
                                 CFG.nnz_pad, seed=31)


# ---------------------------------------------------------------------------
# fingerprint normalization
# ---------------------------------------------------------------------------
def test_memo_fingerprint_pad_and_order_insensitive():
    ids = np.asarray([[5, 9, 2, -1]], np.int32)
    vals = np.asarray([[1.0, 3.0, 2.0, 0.0]], np.float32)
    # same pairs, different order and wider padding
    ids2 = np.asarray([[2, -1, 5, -1, 9, -1]], np.int32)
    vals2 = np.asarray([[2.0, 7.0, 1.0, 0.0, 3.0, 9.9]], np.float32)
    assert query_fingerprint(ids, vals) == query_fingerprint(ids2, vals2)
    # a changed value or id must change the digest
    vals3 = vals.copy()
    vals3[0, 0] = 1.5
    assert query_fingerprint(ids, vals) != query_fingerprint(ids, vals3)
    ids4 = ids.copy()
    ids4[0, 0] = 6
    assert query_fingerprint(ids, vals) != query_fingerprint(ids4, vals)


def test_memo_fingerprint_row_structure_matters():
    # the same multiset of pairs split across different rows is a
    # different batch — digests must differ
    one = query_fingerprint(np.asarray([[1, 2]]), np.asarray([[1.0, 2.0]]))
    two = query_fingerprint(np.asarray([[1, -1], [2, -1]]),
                            np.asarray([[1.0, 0.0], [2.0, 0.0]]))
    assert one != two


@settings(max_examples=30, deadline=None)
@given(pairs=st.lists(st.tuples(st.integers(0, 63), st.integers(1, 9)),
                      min_size=1, max_size=8))
def test_memo_fingerprint_permutation_property(pairs):
    """Property: any permutation + any pad widening of the same valid
    pairs fingerprints identically."""
    pairs = sorted(set(pairs))
    ids = np.asarray([[w for w, _ in pairs]], np.int32)
    vals = np.asarray([[float(c) for _, c in pairs]], np.float32)
    perm = np.random.default_rng(sum(w for w, _ in pairs)).permutation(
        len(pairs))
    pad = np.full((1, len(pairs) + 3), -1, np.int32)
    padv = np.zeros((1, len(pairs) + 3), np.float32)
    pad[0, :len(pairs)] = ids[0, perm]
    padv[0, :len(pairs)] = vals[0, perm]
    assert query_fingerprint(ids, vals) == query_fingerprint(pad, padv)


# ---------------------------------------------------------------------------
# cache mechanics
# ---------------------------------------------------------------------------
def test_memo_lru_eviction_and_drop():
    mc = MemoCache(max_entries=2)
    q = lambda i: (np.asarray([[i]]), np.asarray([[1.0]]))
    keys = [memo_key("tokA", (0, None), "ell", 4, "exact", 0, *q(i))
            for i in range(3)]
    mc.put(keys[0], "r0")
    mc.put(keys[1], "r1")
    assert mc.get(keys[0]) == "r0"        # refresh 0 -> 1 is LRU
    mc.put(keys[2], "r2")                 # evicts key 1
    assert mc.get(keys[1]) is None
    assert mc.get(keys[0]) == "r0" and mc.get(keys[2]) == "r2"
    st = mc.stats_snapshot()
    assert st.evictions == 1 and st.entries == 2
    roomy = MemoCache(max_entries=8)
    for k in (keys[0], keys[2]):
        roomy.put(k, "rA")
    other = memo_key("tokB", (0, None), "ell", 4, "exact", 0, *q(9))
    roomy.put(other, "rB")
    assert roomy.drop_store("tokA") == 2  # purges only tokA's entries
    assert roomy.get(other) == "rB"
    with pytest.raises(ValueError):
        MemoCache(max_entries=0)


def test_memo_key_separates_scoring_knobs():
    qi, qv = np.asarray([[3]]), np.asarray([[1.0]])
    base = memo_key("t", (0, None), "ell", 4, "exact", 0, qi, qv)
    assert base != memo_key("t", (0, None), "ell", 4, "approx", 16, qi, qv)
    assert base != memo_key("t", (0, None), "ell", 2, "exact", 0, qi, qv)
    assert base != memo_key("t", (1, None), "ell", 4, "exact", 0, qi, qv)
    assert base != memo_key("t", (0, "m1"), "ell", 4, "exact", 0, qi, qv)


# ---------------------------------------------------------------------------
# session integration + the generation-bump differential
# ---------------------------------------------------------------------------
def test_memo_hit_repeats_result_bit_identical(tmp_path, corpus):
    docs = _corpus_docs(corpus)
    sess = FlashSearchSession(_build(tmp_path / "s", docs), CFG,
                              memo_entries=32)
    qi, qv = _query(corpus, 11)
    first = sess.search(Query(qi, qv))
    assert sess.last_stats.memo_hits == 0
    second = sess.search(Query(qi, qv))
    assert sess.last_stats.memo_hits == 1
    np.testing.assert_array_equal(first.doc_ids, second.doc_ids)
    np.testing.assert_array_equal(first.scores, second.scores)
    # pad-widened encoding of the same logical query also hits
    wide_i = np.full((1, qi.shape[1] + 4), -1, np.int32)
    wide_v = np.zeros((1, qi.shape[1] + 4), np.float32)
    wide_i[0, :qi.shape[1]] = qi[0]
    wide_v[0, :qi.shape[1]] = qv[0]
    third = sess.search(Query(wide_i, wide_v))
    assert sess.last_stats.memo_hits == 1
    np.testing.assert_array_equal(first.doc_ids, third.doc_ids)
    ms = sess.memo_stats
    assert ms.hits == 2 and ms.entries >= 1
    sess.close()


def test_memo_never_serves_across_generation_bump(tmp_path, corpus):
    """The acceptance differential: memoize a result, change the corpus
    (generation bump), and the next identical query must re-score and
    reflect the new documents — and match a memo-less session on the
    same store, bit for bit."""
    docs = _corpus_docs(corpus)
    store = _build(tmp_path / "s", docs)
    memod = FlashSearchSession(store, CFG, memo_entries=32)
    plain = FlashSearchSession(store, CFG)
    qi, qv = _query(corpus, 42)
    stale = memod.search(Query(qi, qv))
    memod.search(Query(qi, qv))
    assert memod.last_stats.memo_hits == 1   # memoized and hot
    # craft a new doc proportional to the query: by Cauchy-Schwarz the
    # cosine-style score dot(q, c)/||c|| is maximal exactly for c ∝ q,
    # so the new doc must enter the fresh top-k
    pairs = [(int(w), int(v)) for w, v in zip(qi[0], qv[0]) if w >= 0]
    store.append_docs([(len(docs) + 7, pairs)])
    fresh = memod.search(Query(qi, qv))
    assert memod.last_stats.memo_hits == 0   # new generation: no serve
    ref = plain.search(Query(qi, qv))
    np.testing.assert_array_equal(fresh.doc_ids, ref.doc_ids)
    np.testing.assert_array_equal(fresh.scores, ref.scores)
    assert len(docs) + 7 in np.asarray(fresh.doc_ids)[0]
    # and the stale answer is provably different from the fresh one
    assert not np.array_equal(stale.doc_ids, fresh.doc_ids)
    memod.close()
    plain.close()


def test_memo_generation_property(tmp_path, corpus):
    """Property form: across an append/search interleaving, a memoized
    result is only ever served when the store generation is unchanged
    since it was stored."""
    docs = _corpus_docs(corpus)
    store = _build(tmp_path / "p", docs)
    sess = FlashSearchSession(store, CFG, memo_entries=32)
    rng = np.random.default_rng(77)
    gen_at_store = {}
    next_id = len(docs)
    for step in range(30):
        idx = int(rng.integers(0, 50))
        qi, qv = _query(corpus, idx)
        if rng.random() < 0.3:
            pairs = [(int(w), int(rng.integers(1, 9)))
                     for w in qi[0][:4] if w >= 0]
            store.append_docs([(next_id, pairs)])
            next_id += 1
        sess.search(Query(qi, qv))
        hit = sess.last_stats.memo_hits == 1
        key = (idx, store.generation)
        assert hit == (key in gen_at_store), (
            f"step {step}: memo hit across a generation boundary")
        gen_at_store[key] = True
    sess.close()


def test_memo_default_off(tmp_path, corpus):
    docs = _corpus_docs(corpus)
    sess = FlashSearchSession(_build(tmp_path / "s", docs), CFG)
    assert sess.memo_stats is None
    qi, qv = _query(corpus, 3)
    sess.search(Query(qi, qv))
    sess.search(Query(qi, qv))
    assert sess.last_stats.memo_hits == 0
    sess.close()


def test_memo_distinct_modes_do_not_alias(tmp_path, corpus):
    docs = _corpus_docs(corpus)
    sess = FlashSearchSession(_build(tmp_path / "s", docs), CFG,
                              cache_bytes=0, memo_entries=32)
    qi, qv = _query(corpus, 25)
    sess.search(Query(qi, qv))
    # same query under approx scoring must not reuse the exact memo
    sess.search(Query(qi, qv), options=QueryOptions(mode="approx",
                                                    candidates=8))
    assert sess.last_stats.memo_hits == 0
    sess.search(Query(qi, qv), options=QueryOptions(mode="approx",
                                                    candidates=8))
    assert sess.last_stats.memo_hits == 1
    sess.close()
