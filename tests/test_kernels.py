"""Pallas sparse_match kernel vs pure-jnp oracle: shape/dtype sweeps +
property-based invariants (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.core import corpus as corpus_lib
from repro.kernels import ops, ref
from repro.kernels.sparse_match import sparse_match


def _mk(D, K, Qn, L, vocab, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    ids = np.full((D, K), -1, np.int32)
    vals = np.zeros((D, K), dtype)
    for d in range(D):
        k = rng.integers(1, K + 1)
        ids[d, :k] = np.sort(rng.choice(vocab, k, replace=False))
        vals[d, :k] = rng.integers(1, 20, k)
    qid = np.full((L, Qn), -1, np.int32)
    qval = np.zeros((L, Qn), np.float32)
    for l in range(L):
        q = rng.integers(1, Qn + 1)
        qid[l, :q] = np.sort(rng.choice(vocab, q, replace=False))
        qval[l, :q] = rng.integers(1, 20, q)
    mi, mv = ops.merge_queries(qid, qval)
    return ids, vals, mi, mv


SWEEP = [
    # (D, K, Qn, L, vocab, block_docs, block_query)
    (8, 8, 8, 1, 64, 8, 8),
    (16, 16, 32, 2, 256, 8, 16),
    (32, 8, 16, 3, 128, 16, 16),
    (64, 32, 64, 1, 1024, 32, 64),
    (128, 16, 24, 4, 512, 64, 32),
    (24, 8, 8, 2, 64, 8, 8),          # D not a multiple of the block
]


@pytest.mark.parametrize("case", SWEEP)
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_kernel_matches_oracle(case, dtype):
    D, K, Qn, L, vocab, bd, bq = case
    ids, vals, mi, mv = _mk(D, K, Qn, L, vocab,
                            seed=hash(case) % 2**31, dtype=np.float32)
    vals = vals.astype(np.float32 if dtype == np.int32 else dtype)
    got = ops.correlate(jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(mi),
                        jnp.asarray(mv), backend="pallas",
                        block_docs=bd, block_query=bq)
    want = ref.sparse_match_ref(jnp.asarray(ids), jnp.asarray(vals),
                                jnp.asarray(mi), jnp.asarray(mv), vocab)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_kernel_bf16_vals():
    ids, vals, mi, mv = _mk(32, 16, 32, 2, 256, seed=7)
    got = ops.correlate(jnp.asarray(ids), jnp.asarray(vals, jnp.bfloat16),
                        jnp.asarray(mi), jnp.asarray(mv), backend="pallas",
                        block_docs=16, block_query=16)
    want = ref.sparse_match_ref(jnp.asarray(ids), jnp.asarray(vals),
                                jnp.asarray(mi), jnp.asarray(mv), 256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_sentinels_never_match():
    """Doc padding (-1) and query padding (-2) must contribute nothing."""
    ids = np.full((8, 8), -1, np.int32)
    vals = np.ones((8, 8), np.float32) * 100
    mi = np.full((8,), -2, np.int32)
    mv = np.ones((8, 1), np.float32) * 100
    out = ops.correlate(jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(mi),
                        jnp.asarray(mv), backend="pallas",
                        block_docs=8, block_query=8)
    assert np.all(np.asarray(out) == 0)


def test_cosine_self_similarity_is_one():
    c = corpus_lib.synthesize(64, 512, 12, 16, seed=3)
    qi, qv = corpus_lib.make_query(c, 5, 16)
    mi, mv = ops.merge_queries(qi[None], qv[None])
    corr = ops.correlate(jnp.asarray(c.ids), jnp.asarray(c.vals),
                         jnp.asarray(mi), jnp.asarray(mv), backend="pallas",
                         block_docs=16, block_query=16)
    qn = jnp.asarray([np.sqrt((qv ** 2).sum())])
    cos = ops.cosine_scores(corr, jnp.asarray(c.norms), qn)
    assert np.argmax(np.asarray(cos)[:, 0]) == 5
    np.testing.assert_allclose(np.asarray(cos)[5, 0], 1.0, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(2, 24), k=st.integers(2, 12), qn=st.integers(2, 16),
    l=st.integers(1, 3), seed=st.integers(0, 2**20),
)
def test_property_kernel_equals_oracle(d, k, qn, l, seed):
    ids, vals, mi, mv = _mk(d, k, qn, l, 128, seed=seed)
    got = ops.correlate(jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(mi),
                        jnp.asarray(mv), backend="pallas",
                        block_docs=8, block_query=8)
    want = ref.sparse_match_ref(jnp.asarray(ids), jnp.asarray(vals),
                                jnp.asarray(mi), jnp.asarray(mv), 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_property_query_batching_linear(seed):
    """Scoring L queries in one batched call == L separate calls (the
    paper's K*L parallelization is exact, not approximate)."""
    rng = np.random.default_rng(seed)
    ids, vals, _, _ = _mk(16, 8, 8, 1, 64, seed=seed)
    qid = np.full((3, 8), -1, np.int32)
    qval = np.zeros((3, 8), np.float32)
    for l in range(3):
        q = rng.integers(1, 9)
        qid[l, :q] = np.sort(rng.choice(64, q, replace=False))
        qval[l, :q] = rng.integers(1, 9, q)
    mi, mv = ops.merge_queries(qid, qval)
    batched = ops.correlate(jnp.asarray(ids), jnp.asarray(vals),
                            jnp.asarray(mi), jnp.asarray(mv),
                            backend="pallas", block_docs=8, block_query=8)
    for l in range(3):
        mi1, mv1 = ops.merge_queries(qid[l:l + 1], qval[l:l + 1])
        single = ops.correlate(jnp.asarray(ids), jnp.asarray(vals),
                               jnp.asarray(mi1), jnp.asarray(mv1),
                               backend="pallas", block_docs=8, block_query=8)
        np.testing.assert_allclose(np.asarray(batched[:, l]),
                                   np.asarray(single[:, 0]), rtol=1e-5,
                                   atol=1e-5)
